"""Shared pytest wiring: the opt-in concurrency-sanitizer lane (PR 10).

With ``REPRO_SANITIZE=1`` in the environment, every test in the tier-1
concurrency suites (``SANITIZED_MODULES``) runs with a fresh
:class:`repro.analysis.sanitizer.Sanitizer` installed on the
``repro.core.instrument`` seam.  After each test:

* **error-tier** findings (the known-clean rule set: SAN-RACE,
  SAN-LOCK-ORDER, SAN-FUT-LEAK, SAN-TRIAL-SUMMARY) fail the test;
* **warn-tier** findings (rules new this PR, e.g. SAN-SELF-DEADLOCK)
  surface as pytest warnings — visible in CI, not yet gating;
* event counts are accumulated per backend (from the test's ``backend``
  param when it has one) and written to ``REPRO_SANITIZE_REPORT`` (a JSON
  path; default ``sanitizer-counts.json``) at session end, which the CI
  ``analysis`` job folds into its step summary.

Without the env var this file costs nothing: the fixture yields
immediately and no analysis module is ever imported.
"""
import json
import os
import warnings
from collections import Counter, defaultdict

import pytest

# Tier-1 concurrency suites that must stay sanitizer-clean (the CI
# analysis lane runs exactly these with REPRO_SANITIZE=1).
SANITIZED_MODULES = {
    "test_backends",
    "test_fiber_scheduler",
    "test_completion_ring",
    "test_faults",
}

_counts_by_backend = defaultdict(Counter)


def _sanitize_enabled() -> bool:
    return os.environ.get("REPRO_SANITIZE", "") not in ("", "0")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "sanitizer_allow(rule, ...): suppress the named concurrency-"
        "sanitizer rules for this test (the dynamic analogue of the lint "
        "pass's `# repro: allow[RULE]` comment) — for tests that "
        "*deliberately* construct the flagged condition.")


@pytest.fixture(autouse=True)
def _concurrency_sanitizer(request):
    """Attach the sanitizer around sanitized-suite tests (opt-in via env)."""
    if not _sanitize_enabled() \
            or request.module.__name__ not in SANITIZED_MODULES:
        yield
        return
    from repro.analysis.sanitizer import Sanitizer
    from repro.core import instrument

    san = Sanitizer()
    instrument.install(san)
    try:
        yield
    finally:
        instrument.uninstall()
        findings = san.check()
        backend = "none"
        callspec = getattr(request.node, "callspec", None)
        if callspec is not None:
            backend = str(callspec.params.get("backend", "none"))
        _counts_by_backend[backend].update(san.counts)
        allowed = set()
        for mark in request.node.iter_markers("sanitizer_allow"):
            allowed.update(mark.args)
        for f in findings:
            if f.severity == "warn":
                warnings.warn(f"sanitizer (warn tier): {f}")
        errors = [f for f in findings
                  if f.severity == "error" and f.rule not in allowed]
        if errors:
            pytest.fail("concurrency sanitizer findings:\n"
                        + "\n".join(str(f) for f in errors))


def pytest_sessionfinish(session, exitstatus):
    """Write the per-backend sanitizer event-count report (sanitize lane)."""
    if not _sanitize_enabled() or not _counts_by_backend:
        return
    path = os.environ.get("REPRO_SANITIZE_REPORT", "sanitizer-counts.json")
    report = {backend: dict(counts)
              for backend, counts in sorted(_counts_by_backend.items())}
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
