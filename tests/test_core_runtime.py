"""Unit + property tests for the async-RPC substrate (threads vs fibers).

The property tests use ``hypothesis`` when it is installed; a deterministic
seeded fallback covers the same invariants otherwise, so the module always
collects (the suite must not die on an optional dev dependency).
"""
import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False

from repro.core import (App, AsyncRpc, Compute, Future, ServiceSpec, Sleep,
                        SpawnLocal, Wait, WaitAll, sync_rpc)

BACKENDS = ("thread", "fiber")


# ----------------------------------------------------------------- futures
def test_future_set_then_wait():
    f = Future()
    f.set_result(41)
    assert f.wait() == 41
    assert f.done


def test_future_wait_blocks_until_set():
    f = Future()
    threading.Timer(0.05, lambda: f.set_result("x")).start()
    assert f.wait(timeout=2.0) == "x"


def test_future_exception_propagates():
    f = Future()
    f.set_exception(ValueError("boom"))
    with pytest.raises(ValueError):
        f.wait()


def test_future_double_set_raises():
    f = Future()
    f.set_result(1)
    with pytest.raises(Exception):
        f.set_result(2)


def test_future_callback_after_resolution_fires_immediately():
    f = Future()
    f.set_result(7)
    seen = []
    f.add_done_callback(lambda fut: seen.append(fut.result()))
    assert seen == [7]


# ------------------------------------------------------------ mini services
def _echo(svc, payload):
    yield Compute(1e-6)
    return payload


def _adder(svc, payload):
    a = yield from sync_rpc("echo", "echo", payload["a"])
    b = yield from sync_rpc("echo", "echo", payload["b"])
    return a + b


def _fanout(svc, payload):
    futs = []
    for i in range(payload["n"]):
        f = yield AsyncRpc("echo", "echo", i)
        futs.append(f)
    vals = yield WaitAll(futs)
    return sum(vals)


def _sleeper(svc, payload):
    yield Sleep(payload)
    return "slept"


def _raiser(svc, payload):
    yield Compute(1e-6)
    raise RuntimeError("handler failure")


def _calls_raiser(svc, payload):
    f = yield AsyncRpc("raiser", "go", None)
    val = yield Wait(f)
    return val


def _local_spawn(svc, payload):
    def sub(x):
        yield Sleep(0.001)
        return x * 2
    f = yield SpawnLocal(sub, (payload,))
    return (yield Wait(f))


def _mini_app(backend: str) -> App:
    app = App(backend=backend)
    app.add_service(ServiceSpec("echo", {"echo": _echo}, n_workers=2))
    app.add_service(ServiceSpec("adder", {"add": _adder}, n_workers=2))
    app.add_service(ServiceSpec("fan", {"fanout": _fanout}, n_workers=2))
    app.add_service(ServiceSpec("sleepy", {"nap": _sleeper}, n_workers=1))
    app.add_service(ServiceSpec("raiser", {"go": _raiser}, n_workers=1))
    app.add_service(ServiceSpec("caller", {"call": _calls_raiser}, n_workers=1))
    app.add_service(ServiceSpec("local", {"go": _local_spawn}, n_workers=1))
    return app


@pytest.mark.parametrize("backend", BACKENDS)
def test_echo_roundtrip(backend):
    with _mini_app(backend) as app:
        assert app.send("echo", "echo", 123).wait(timeout=5) == 123


@pytest.mark.parametrize("backend", BACKENDS)
def test_nested_sync_rpc(backend):
    with _mini_app(backend) as app:
        assert app.send("adder", "add", {"a": 2, "b": 3}).wait(timeout=5) == 5


@pytest.mark.parametrize("backend", BACKENDS)
def test_fanout_waitall(backend):
    with _mini_app(backend) as app:
        assert app.send("fan", "fanout", {"n": 10}).wait(timeout=5) == sum(range(10))


@pytest.mark.parametrize("backend", BACKENDS)
def test_sleep_overlap(backend):
    """Two concurrent 100 ms sleeps must overlap, not serialize."""
    with _mini_app(backend) as app:
        t0 = time.perf_counter()
        f1 = app.send("sleepy", "nap", 0.1)
        f2 = app.send("sleepy", "nap", 0.1)
        f1.wait(timeout=5), f2.wait(timeout=5)
        elapsed = time.perf_counter() - t0
        # fiber backend: 1 scheduler interleaves both sleeps; thread backend:
        # 1 dispatcher serializes — but each nap is its own request, so with
        # n_workers=1 the thread backend serializes.  Fibers must NOT.
        if backend == "fiber":
            assert elapsed < 0.18, f"fiber sleeps serialized: {elapsed:.3f}s"
        assert elapsed < 0.4


@pytest.mark.parametrize("backend", BACKENDS)
def test_handler_exception_propagates(backend):
    with _mini_app(backend) as app:
        with pytest.raises(RuntimeError, match="handler failure"):
            app.send("raiser", "go", None).wait(timeout=5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_remote_exception_propagates_through_rpc(backend):
    with _mini_app(backend) as app:
        with pytest.raises(RuntimeError, match="handler failure"):
            app.send("caller", "call", None).wait(timeout=5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_spawn_local(backend):
    with _mini_app(backend) as app:
        assert app.send("local", "go", 21).wait(timeout=5) == 42


def test_unknown_service_errors():
    with _mini_app("fiber") as app:
        with pytest.raises(KeyError):
            app.send("nope", "x", None).wait(timeout=5)


def test_unknown_method_errors():
    with _mini_app("fiber") as app:
        with pytest.raises(KeyError):
            app.send("echo", "nope", None).wait(timeout=5)


def test_mixed_backends_interoperate():
    """Paper's migration story: some services fiber, others thread."""
    app = App(backend="thread")
    app.add_service(ServiceSpec("echo", {"echo": _echo}, n_workers=2,
                                backend="fiber"))
    app.add_service(ServiceSpec("adder", {"add": _adder}, n_workers=2,
                                backend="thread"))
    with app:
        assert app.send("adder", "add", {"a": 1, "b": 2}).wait(timeout=5) == 3


# ---------------------------------------------------------- property tests
def _check_all_requests_complete_correctly(values, backend):
    """Invariant: every request completes with its own payload (no
    cross-request interference), under arbitrary interleavings."""
    with _mini_app(backend) as app:
        futs = [app.send("echo", "echo", v) for v in values]
        got = [f.wait(timeout=10) for f in futs]
        assert got == values


def _check_fanout_sum(n, backend):
    with _mini_app(backend) as app:
        assert app.send("fan", "fanout", {"n": n}).wait(timeout=10) == n * (n - 1) // 2


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(st.lists(st.integers(min_value=0, max_value=1000), min_size=1,
                    max_size=40),
           st.sampled_from(BACKENDS))
    def test_property_all_requests_complete_correctly(values, backend):
        _check_all_requests_complete_correctly(values, backend)

    @settings(max_examples=6, deadline=None)
    @given(st.integers(min_value=1, max_value=30),
           st.sampled_from(BACKENDS))
    def test_property_fanout_sum(n, backend):
        _check_fanout_sum(n, backend)
else:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_property_all_requests_complete_correctly_fallback(backend):
        rng = np.random.default_rng(0)
        for _ in range(4):
            size = int(rng.integers(1, 41))
            values = rng.integers(0, 1001, size=size).tolist()
            _check_all_requests_complete_correctly(values, backend)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_property_fanout_sum_fallback(backend):
        for n in (1, 2, 7, 30):
            _check_fanout_sum(n, backend)


# ----------------------------------------------------- fiber scheduler unit
def test_fiber_spawn_counts():
    """The zero-handoff fast path inlines every cooperative call (no carrier
    fibers); with the fast path disabled the PR 3 carrier-per-call
    accounting must come back."""
    with _mini_app("fiber") as app:
        app.send("fan", "fanout", {"n": 8}).wait(timeout=5)
        st = app.backend_stats()
        assert app.total_spawns() == 0       # no carriers on the fast path
        assert st.inline_calls >= 8          # every async call inlined
    app = _mini_app("fiber")
    app.inline_budget = 0                    # restore the carrier path
    with app:
        app.send("fan", "fanout", {"n": 8}).wait(timeout=5)
        assert app.total_spawns() >= 8  # one carrier fiber per async call
        assert app.backend_stats().inline_calls == 0


def test_thread_spawn_counts():
    with _mini_app("thread") as app:
        app.send("fan", "fanout", {"n": 8}).wait(timeout=5)
        assert app.total_spawns() >= 8  # one kernel thread per async call
