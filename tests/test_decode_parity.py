"""Decode/prefill parity: for every architecture, decoding token S after a
prefill of S tokens must reproduce the logits of a full (S+1)-token prefill.
This exercises KV caches (full/MLA/window/ring), recurrent states, and
position handling end-to-end, in fp32 for tight tolerances."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import Model

B, S = 2, 12  # decode the (S+1)-th token


def _fp32(cfg):
    # fp32 for tight tolerances; huge MoE capacity so no tokens are dropped
    # (capacity dropping legitimately differs between prefill lengths)
    return cfg.with_(param_dtype="float32", compute_dtype="float32",
                     remat=False, moe_capacity_factor=16.0)


def _inputs(model, rng, seq):
    cfg = model.cfg
    tok = jax.random.randint(rng, (B, seq), 0, cfg.vocab_size)
    inp = {"tokens": tok}
    if cfg.family == "vlm":
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (B, seq))
        inp["positions"] = jnp.stack([pos, pos, pos])
    if cfg.is_encdec:
        src = jax.random.normal(jax.random.fold_in(rng, 1),
                                (B, 16, cfg.d_model), jnp.float32) * 0.02
        inp = {"src": src, "tokens": tok}
    return inp


def _pad_seq_caches(cfg, cache, max_len):
    if cfg.family in ("ssm", "hybrid"):
        return cache

    def pad(x, axis=2):
        n = max_len - x.shape[axis]
        if n <= 0:
            return x
        w = [(0, 0)] * x.ndim
        w[axis] = (0, n)
        return jnp.pad(x, w)

    if cfg.is_encdec:
        return {"self": {k: pad(v) for k, v in cache["self"].items()},
                "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
    return {k: pad(v) for k, v in cache.items()}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_prefill(arch):
    cfg = _fp32(get_smoke_config(arch))
    model = Model(cfg)
    rng = jax.random.PRNGKey(42)
    params = model.init(rng)

    full = _inputs(model, rng, S + 1)
    prefix = dict(full)
    prefix["tokens"] = full["tokens"][:, :S]
    if "positions" in full:
        prefix["positions"] = full["positions"][:, :, :S]

    # reference: last-token logits from a full (S+1)-length prefill
    ref_logits, _ = jax.jit(model.prefill)(params, full)

    # candidate: prefill S tokens, then decode token S from the cache
    _, cache = jax.jit(model.prefill)(params, prefix)
    cache = _pad_seq_caches(cfg, cache, S + 4)
    tok = full["tokens"][:, S:S + 1]
    pos = jnp.full((B,), S, jnp.int32)
    dec_logits, _ = jax.jit(model.decode_step)(params, cache, tok, pos)

    a = np.asarray(ref_logits, np.float32)
    b = np.asarray(dec_logits, np.float32)
    np.testing.assert_allclose(a, b, atol=2e-3, rtol=2e-3,
                               err_msg=f"{arch}: decode != prefill")


@pytest.mark.parametrize("arch", ["rwkv6-3b", "recurrentgemma-9b"])
def test_long_context_state_is_bounded(arch):
    """Sub-quadratic archs: decode-state byte size is independent of the
    context length (the long_500k feasibility property)."""
    cfg = get_smoke_config(arch)
    model = Model(cfg)

    def nbytes(max_len):
        cache = model.init_cache(1, max_len, abstract=True)
        return sum(np.prod(x.shape) * x.dtype.itemsize
                   for x in jax.tree.leaves(cache))

    assert nbytes(1 << 10) == nbytes(1 << 19)
