"""Shared-timer-wheel contract + deterministic new-backend scheduling tests.

The TimerWheel (repro.core.timers) is the one timed-park structure for every
cooperative backend: FiberScheduler (fiber/fiber-steal), BatchFiberScheduler
(fiber-batch flush deadlines) and EventLoopExecutor.  These tests pin its
ordering guarantees directly, then assert the *backends* inherit them: the
event loop must resume sleepers in exactly the order a FiberScheduler does,
and the batch scheduler's three flush triggers (size / join / timeout) must
each fire deterministically.
"""
import threading
import time

import pytest

from repro.core import (App, AsyncRpc, Future, ServiceSpec, Sleep, SpawnLocal,
                        Wait, WaitAll)
from repro.core.eventloop import EventLoopExecutor
from repro.core.executor import FiberExecutor
from repro.core.fiber import BatchFiberScheduler, FiberScheduler
from repro.core.timers import TimerWheel


# ---------------------------------------------------------------- TimerWheel
def test_wheel_pops_in_deadline_order():
    w = TimerWheel()
    w.push(3.0, "c")
    w.push(1.0, "a")
    w.push(2.0, "b")
    assert w.pop_due(2.5) == ["a", "b"]
    assert len(w) == 1
    assert w.pop_due(10.0) == ["c"]
    assert not w


def test_wheel_equal_deadlines_pop_fifo():
    """Identical deadlines tie-break by push order; without the internal
    sequence field heapq would compare the (unorderable) payloads."""
    w = TimerWheel()
    for i in range(5):
        w.push(1.0, ("payload", i))  # tuples of equal prefix would compare
    assert w.pop_due(1.0) == [("payload", i) for i in range(5)]


def test_wheel_next_deadline_and_sleep_budget():
    w = TimerWheel()
    assert w.next_deadline() is None
    assert w.seconds_until_next(0.0) is None
    w.push(5.0, "x")
    assert w.next_deadline() == 5.0
    assert w.seconds_until_next(3.0) == 2.0
    assert w.seconds_until_next(7.0) == 0.0  # overdue clamps, never negative


def test_wheel_pop_due_leaves_future_entries():
    w = TimerWheel()
    w.push(1.0, "due")
    w.push(9.0, "later")
    assert w.pop_due(1.0) == ["due"]
    assert w.next_deadline() == 9.0


# ----------------------------------------- event-loop vs fiber timer parity
def _napper(order, tag, seconds):
    yield Sleep(seconds)
    order.append(tag)


NAP_PLAN = [("slow", 0.06), ("fast", 0.01), ("mid", 0.03)]
NAP_ORDER = ["fast", "mid", "slow"]  # deadline order, not spawn order


def test_event_loop_timers_fire_in_deadline_order():
    ex = EventLoopExecutor(app=None, name="el")
    ex.start()
    order = []
    try:
        futs = []
        for tag, seconds in NAP_PLAN:
            fut = Future()
            ex.deliver(_napper(order, tag, seconds), fut)
            futs.append(fut)
        for f in futs:
            f.wait(timeout=5)
    finally:
        ex.stop()
    assert order == NAP_ORDER


def test_fiber_and_event_loop_agree_on_timer_order():
    """Same sleep program, both cooperative backends, identical resume
    order — the contract the shared TimerWheel exists to guarantee."""
    orders = {}

    sched = FiberScheduler(app=None, name="tw-fib")
    sched.start()
    try:
        orders["fiber"] = []
        for f in [sched.spawn_external(_napper(orders["fiber"], tag, s))
                  for tag, s in NAP_PLAN]:
            f.wait(timeout=5)
    finally:
        sched.stop()

    ex = EventLoopExecutor(app=None, name="tw-el")
    ex.start()
    try:
        orders["event-loop"] = []
        futs = []
        for tag, s in NAP_PLAN:
            fut = Future()
            ex.deliver(_napper(orders["event-loop"], tag, s), fut)
            futs.append(fut)
        for f in futs:
            f.wait(timeout=5)
    finally:
        ex.stop()

    assert orders["fiber"] == orders["event-loop"] == NAP_ORDER


# ------------------------------------------------------- event-loop basics
def test_event_loop_is_single_carrier():
    """Every continuation — handlers and their async spawns — runs on the
    one loop thread; n_workers is accepted and ignored."""
    ex = EventLoopExecutor(app=None, name="solo", n_workers=8)
    ex.start()
    ran_on = []
    lock = threading.Lock()

    def _leaf(i):
        with lock:
            ran_on.append(threading.current_thread().name)
        return i
        yield  # pragma: no cover - marks this as a generator

    def _fan(n):
        futs = []
        for i in range(n):
            f = yield SpawnLocal(_leaf, (i,))
            futs.append(f)
        vals = yield WaitAll(futs)
        with lock:
            ran_on.append(threading.current_thread().name)
        return vals

    try:
        reply = Future()
        ex.deliver(_fan(10), reply)
        assert reply.wait(timeout=10) == list(range(10))
    finally:
        ex.stop()
    assert set(ran_on) == {"solo-loop"}
    st = ex.stats()
    assert st.spawns == 10          # one continuation per async call
    assert st.switches >= 11        # the handler + each leaf ran
    assert st.queue_depth_hwm >= 2  # the fan-out piled up on the run queue


def test_event_loop_exception_propagates():
    ex = EventLoopExecutor(app=None, name="boom")
    ex.start()

    def _boom():
        yield Sleep(0.001)
        raise ValueError("event-loop boom")

    try:
        fut = Future()
        ex.deliver(_boom(), fut)
        with pytest.raises(ValueError, match="event-loop boom"):
            fut.wait(timeout=5)
    finally:
        ex.stop()


def test_event_loop_parks_on_external_future():
    """A Wait on a future resolved from another thread goes through the
    inbox injection path, not a blocking join."""
    ex = EventLoopExecutor(app=None, name="park")
    ex.start()
    gate = Future()
    parked = threading.Event()

    def _waiter():
        parked.set()
        val = yield Wait(gate)
        return val + 1

    try:
        fut = Future()
        ex.deliver(_waiter(), fut)
        assert parked.wait(timeout=5)
        gate.set_result(41)
        assert fut.wait(timeout=5) == 42
    finally:
        ex.stop()


def test_event_loop_stop_with_parked_continuation_returns_promptly():
    ex = EventLoopExecutor(app=None, name="stop")
    ex.start()
    parked = threading.Event()

    def _waiter():
        parked.set()
        yield Wait(Future())  # never resolves

    ex.deliver(_waiter(), Future())
    assert parked.wait(timeout=5)
    t0 = time.perf_counter()
    ex.stop()
    assert time.perf_counter() - t0 < 2.0
    assert not ex._thread.is_alive()


# -------------------------------------------------------- batch flush paths
def _echo(svc, payload):
    return payload
    yield  # pragma: no cover - marks this as a generator


@pytest.fixture
def echo_app():
    """Minimal transport target for AsyncRpc effects; the executors under
    test are driven directly, so the service backend is irrelevant."""
    app = App(backend="thread")
    app.add_service(ServiceSpec("echo", {"go": _echo}, n_workers=2))
    with app:
        yield app


def _batch_exec(app, **kw):
    return FiberExecutor(app, "batch-test", n_workers=1, batch=True, **kw)


def test_batch_flushes_on_size(echo_app):
    ex = _batch_exec(echo_app, batch_size=4, flush_after=60.0)

    def _fan():
        futs = []
        for i in range(4):
            f = yield AsyncRpc("echo", "go", i)
            futs.append(f)
        vals = yield WaitAll(futs)
        return vals

    ex.start()
    try:
        reply = Future()
        ex.deliver(_fan(), reply)
        assert reply.wait(timeout=10) == list(range(4))
    finally:
        ex.stop()
    st = ex.stats()
    assert st.flushes_size == 1        # ring hit batch_size exactly
    assert st.flushes_join == 0        # nothing left for the join to flush
    assert st.flushes_timeout == 0     # deadline set far in the future
    assert st.batched_calls == 4
    assert st.ring_hwm == 4
    assert ex.spawns == 1              # ONE batch carrier for 4 calls


def test_batch_flushes_on_join(echo_app):
    ex = _batch_exec(echo_app, batch_size=1000, flush_after=60.0)

    def _fan():
        futs = []
        for i in range(3):
            f = yield AsyncRpc("echo", "go", i)
            futs.append(f)
        vals = yield WaitAll(futs)  # ring below size: the join must flush
        return vals

    ex.start()
    try:
        reply = Future()
        ex.deliver(_fan(), reply)
        assert reply.wait(timeout=10) == [0, 1, 2]
    finally:
        ex.stop()
    st = ex.stats()
    assert st.flushes_join == 1
    assert st.flushes_size == 0
    assert st.batched_calls == 3
    assert st.ring_hwm == 3


def test_batch_flushes_on_timeout(echo_app):
    """Fire-and-forget: the handler finishes without ever joining, so only
    the flush deadline (on the shared TimerWheel) gets the call out."""
    ex = _batch_exec(echo_app, batch_size=1000, flush_after=0.02)

    def _fire():
        f = yield AsyncRpc("echo", "go", 7)
        return f  # hand the reply future out without waiting on it

    ex.start()
    try:
        reply = Future()
        ex.deliver(_fire(), reply)
        inner = reply.wait(timeout=10)
        assert inner.wait(timeout=10) == 7  # resolves only after the flush
        st = ex.stats()
        assert st.flushes_timeout == 1
        assert st.flushes_size == 0
        assert st.flushes_join == 0
        assert st.batched_calls == 1
    finally:
        ex.stop()


def test_batch_wait_on_buffered_reply_does_not_deadlock(echo_app):
    """The awaited future IS a buffered submission's reply: the join-flush
    must put it on the wire before the fiber parks."""
    ex = _batch_exec(echo_app, batch_size=1000, flush_after=60.0)

    def _call():
        f = yield AsyncRpc("echo", "go", "ping")
        val = yield Wait(f)
        return val

    ex.start()
    try:
        reply = Future()
        ex.deliver(_call(), reply)
        assert reply.wait(timeout=10) == "ping"
    finally:
        ex.stop()
    assert ex.stats().flushes_join == 1


def test_batch_exception_propagates_through_ring(echo_app):
    """A reply that resolves exceptionally must surface through the chained
    per-call future exactly as it does on the unbatched backends."""
    ex = _batch_exec(echo_app, batch_size=1000, flush_after=60.0)

    def _call():
        f = yield AsyncRpc("echo", "nope", None)  # no such method
        val = yield Wait(f)
        return val

    ex.start()
    try:
        reply = Future()
        ex.deliver(_call(), reply)
        with pytest.raises(KeyError):
            reply.wait(timeout=10)
    finally:
        ex.stop()


def test_batch_scheduler_rejects_steal_group():
    with pytest.raises(ValueError, match="owner-thread-only"):
        FiberExecutor(None, "bad", n_workers=2, steal=True, batch=True)


def test_batch_scheduler_amortizes_nested_fanout(echo_app):
    """A two-level fan-out: every level's same-tick submissions share one
    carrier, so total carriers ~= number of flushes, not number of calls."""
    sched_calls = 6

    def _mid(i):
        futs = []
        for j in range(2):
            f = yield AsyncRpc("echo", "go", (i, j))
            futs.append(f)
        vals = yield WaitAll(futs)
        return vals

    def _top():
        futs = []
        for i in range(3):
            f = yield SpawnLocal(_mid, (i,))
            futs.append(f)
        vals = yield WaitAll(futs)
        return vals

    ex = _batch_exec(echo_app, batch_size=1000, flush_after=60.0)
    ex.start()
    try:
        reply = Future()
        ex.deliver(_top(), reply)
        assert reply.wait(timeout=10) == [[(i, 0), (i, 1)] for i in range(3)]
    finally:
        ex.stop()
    st = ex.stats()
    assert st.batched_calls == sched_calls
    # 3 _mid fibers each join-flushed their 2-call ring... unless several
    # rings coalesced in one tick; either way: strictly fewer carriers than
    # batched async calls is the amortization being bought.
    total_flushes = st.flushes_size + st.flushes_join + st.flushes_timeout
    assert 1 <= total_flushes <= 3
    assert st.ring_hwm == 2


def test_batch_scheduler_direct_flush_counters():
    """Unit-level: drive a BatchFiberScheduler without transport and watch
    the ring counters (no App: AsyncRpc is not used here)."""
    s = BatchFiberScheduler(app=None, name="unit", batch_size=2,
                            flush_after=60.0)
    assert s.batch_size == 2
    assert s.flush_after == 60.0
    # an empty flush is a no-op and counts nothing
    s._flush("timeout")
    assert (s.flushes_timeout, s.batched_calls, s.ring_hwm) == (0, 0, 0)


def test_batch_stale_flush_timer_does_not_truncate_next_ring():
    """Regression: a flush deadline armed by ring generation N must be a
    no-op once N has size/join-flushed — otherwise every generation's
    leftover timer prematurely flushes its successor and batch sizes
    collapse under sustained load.  Scheduler not started: ring and timer
    plumbing are driven directly."""
    from repro.core.fiber import Fiber, _FLUSH

    s = BatchFiberScheduler(app=None, name="gen", batch_size=10,
                            flush_after=60.0)
    fib = Fiber(iter(()))
    s._interpret(fib, AsyncRpc("svc", "m", 1))   # gen-0 ring, timer armed
    s._flush("size")                             # gen-0 flushed early
    s._interpret(fib, AsyncRpc("svc", "m", 2))   # gen-1 ring
    s._on_timer((_FLUSH, 0))                     # gen-0's stale deadline
    assert len(s._ring) == 1, "stale timer flushed the successor ring"
    assert s.flushes_timeout == 0
    s._on_timer((_FLUSH, 1))                     # gen-1's own deadline
    assert s._ring == []
    assert s.flushes_timeout == 1
    assert s.batched_calls == 2
