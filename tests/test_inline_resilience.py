"""Breaker-aware zero-handoff inlining + bulkheads (PR 7).

Two properties anchor this file:

* **Decision parity** — the inline fast path feeds the *same* per-edge
  breaker windows and retry budget as the carrier path, so running the same
  deterministic fault script with inlining on (default budget) and off
  (``inline_budget=0``) must produce identical breaker state traces,
  identical open counts, and identical resilience counters.  If inlined
  calls bypassed (or double-counted) the windows, the traces diverge.
* **Bulkheads** — the caller-side per-destination attempt cap is enforced
  at admission on every backend (it lives in ``App``, not the executors),
  and on the inline path too.
"""
import threading
import time

import pytest

from repro.core import (BACKEND_NAMES, App, AsyncRpc, Bulkhead,
                        CircuitOpenError, Rejected, ResiliencePolicy,
                        RetryPolicy, ServiceSpec, Wait)
from repro.core.future import Future

# The cooperative backends that take the zero-handoff inline fast path
# (batch-family backends intercept AsyncRpc in their submission rings).
INLINE_BACKENDS = ("fiber", "fiber-steal", "event-loop", "event-loop-shard")


# --------------------------------------------------------------- app helpers
def _chain_app(backend: str, leaf, resilience, inline_budget: int = 4) -> App:
    """client -> root --rpc--> leaf, with the leaf handler injected."""
    def root(svc, payload):
        f = yield AsyncRpc("leaf", "get", payload)
        return (yield Wait(f))

    app = App(backend=backend, net_latency=0.0, resilience=resilience,
              inline_budget=inline_budget)
    app.add_service(ServiceSpec("leaf", {"get": leaf}, n_workers=1))
    app.add_service(ServiceSpec("root", {"get": root}, n_workers=1))
    return app


def _scripted_leaf(script):
    """Leaf that fails or succeeds per the fault script (index = call #)."""
    calls = []

    def leaf(svc, payload):
        i = len(calls)
        calls.append(payload)
        if i < len(script) and not script[i]:
            raise RuntimeError(f"scripted failure #{i}")
        return ("ok", payload)
        yield  # make it a generator

    return leaf, calls


def _run_script(backend: str, inline_budget: int, n_sends: int = 40):
    """Drive the fault script sequentially; trace breaker decisions."""
    # fail the first 12 leaf calls, then heal — enough to trip the edge
    # (min_volume=4) and, after breaker_reset, close it via a probe.
    script = [False] * 12 + [True] * 200
    leaf, calls = _scripted_leaf(script)
    pol = ResiliencePolicy(
        deadline=5.0, breakers=True, breaker_threshold=0.5,
        breaker_window=8, breaker_min_volume=4, breaker_reset=0.05,
        # jitter=0 keeps the retry schedule deterministic
        retry=RetryPolicy(max_attempts=2, base_backoff=0.001,
                          max_backoff=0.001, jitter=0.0,
                          budget_initial=64.0, budget_ratio=0.0))
    app = _chain_app(backend, leaf, pol, inline_budget=inline_budget)
    trace = []
    outcomes = []
    with app:
        for i in range(n_sends):
            try:
                app.send("root", "get", i).wait(timeout=5.0)
                outcomes.append("ok")
            except CircuitOpenError:
                outcomes.append("open")
            except RuntimeError:
                outcomes.append("err")
            leaf_br = app._breakers.get(("leaf", "get"))
            trace.append(leaf_br.state if leaf_br is not None else None)
            if outcomes[-1] != "ok" and trace[-1] == "open":
                # let the reset timeout elapse so the script makes progress
                # through open -> half-open -> closed instead of spinning
                # fail-fast forever (same wait on both sides of the parity)
                time.sleep(0.06)
        stats = app.backend_stats()
        opens = {d: b.opens for d, b in app._breakers.items()}
        final = {d: b.state for d, b in app._breakers.items()}
    counters = dict(retries=stats.retries, breaker_opens=stats.breaker_opens,
                    rejections=stats.rejections,
                    bulkhead_rejections=stats.bulkhead_rejections)
    return dict(trace=trace, outcomes=outcomes, opens=opens, final=final,
                counters=counters, leaf_calls=len(calls),
                inline_calls=stats.inline_calls)


# ------------------------------------------------------------ decision parity
@pytest.mark.parametrize("backend", ["fiber", "event-loop"])
def test_breaker_decision_parity_inline_vs_carrier(backend):
    """Same fault script, inlining on vs off: identical breaker-state trace,
    open/close transitions, outcome sequence, and resilience counters —
    proving inlined attempts feed the same windows as carrier attempts."""
    on = _run_script(backend, inline_budget=4)
    off = _run_script(backend, inline_budget=0)
    assert on["inline_calls"] > 0       # the fast path actually engaged
    assert off["inline_calls"] == 0     # ...and was actually off
    assert on["trace"] == off["trace"]
    assert on["outcomes"] == off["outcomes"]
    assert on["opens"] == off["opens"]
    assert on["final"] == off["final"]
    assert on["counters"] == off["counters"]
    assert on["leaf_calls"] == off["leaf_calls"]
    # the script must have exercised real transitions, not a flat trace
    assert "open" in on["trace"]
    assert on["trace"][-1] == "closed"
    assert on["counters"]["breaker_opens"] >= 1


@pytest.mark.parametrize("backend", INLINE_BACKENDS)
def test_inline_fast_path_survives_resilience_policy(backend):
    """Acceptance gate: with breakers + retry (+ bulkhead) and zero net
    latency, the cooperative backends still inline — the policy adds
    bookkeeping, it no longer forces the carrier path."""
    leaf, _ = _scripted_leaf([])
    pol = ResiliencePolicy(deadline=1.0, breakers=True, bulkhead=64,
                           retry=RetryPolicy(max_attempts=3))
    app = _chain_app(backend, leaf, pol)
    with app:
        for i in range(30):
            assert app.send("root", "get", i).wait(timeout=5.0) == ("ok", i)
        stats = app.backend_stats()
    assert stats.inline_calls > 0, stats
    assert stats.bulkhead_rejections == 0


def test_mailbox_bound_still_disables_inlining():
    """A bounded mailbox is the one policy the fast path cannot honour
    (an inlined call never occupies a mailbox slot), so it must force the
    carrier path."""
    leaf, _ = _scripted_leaf([])
    pol = ResiliencePolicy(deadline=1.0, breakers=True, mailbox_bound=64)
    app = _chain_app("fiber", leaf, pol)
    with app:
        for i in range(10):
            assert app.send("root", "get", i).wait(timeout=5.0) == ("ok", i)
        stats = app.backend_stats()
    assert stats.inline_calls == 0, stats


def test_inline_open_circuit_fails_fast_without_running_handler():
    """Once the leaf edge is open, an inlined attempt must fail fast at
    admission — the handler body never runs (no half-open probe burned,
    no work done behind an open circuit)."""
    leaf, calls = _scripted_leaf([False] * 500)
    pol = ResiliencePolicy(deadline=5.0, breakers=True, breaker_window=8,
                           breaker_min_volume=4, breaker_reset=30.0)
    app = _chain_app("fiber", leaf, pol)
    with app:
        for i in range(10):
            try:
                app.send("root", "get", i).wait(timeout=5.0)
            except RuntimeError:  # includes CircuitOpenError
                pass
        assert app._breakers[("leaf", "get")].state == "open"
        ran_before = len(calls)
        for i in range(10):
            with pytest.raises(RuntimeError):
                app.send("root", "get", i).wait(timeout=5.0)
        assert len(calls) == ran_before  # fail-fast: handler never entered
        stats = app.backend_stats()
    assert stats.inline_calls > 0


# ------------------------------------------------------------------ bulkheads
def test_bulkhead_unit():
    bh = Bulkhead(2)
    assert bh.try_acquire() and bh.try_acquire()
    assert not bh.try_acquire()            # at the cap
    assert bh.inflight == 2
    bh.release()
    assert bh.try_acquire()                # slot freed
    bh.release(), bh.release()
    assert bh.inflight == 0


@pytest.mark.parametrize("backend", BACKEND_NAMES)
def test_bulkhead_saturation_rejects_on_every_backend(backend):
    """Park `limit` requests inside a gated handler; every further send to
    that destination must be refused at admission with Rejected — on all 8
    backends — and tick the caller-side bulkhead counter (distinct from
    mailbox rejections, which stay zero)."""
    gate = Future()
    entered = threading.Semaphore(0)

    def hold(svc, payload):
        entered.release()
        return (yield Wait(gate))

    pol = ResiliencePolicy(deadline=30.0, breakers=False, bulkhead=2)
    app = App(backend=backend, net_latency=0.0, resilience=pol)
    app.add_service(ServiceSpec("gated", {"get": hold}, n_workers=4))
    with app:
        admitted = [app.send("gated", "get") for _ in range(2)]
        # both admitted attempts are inside the handler (bulkhead slots held)
        assert entered.acquire(timeout=5.0)
        assert entered.acquire(timeout=5.0)
        rejected = [app.send("gated", "get") for _ in range(4)]
        for f in rejected:
            with pytest.raises(Rejected, match="bulkhead full"):
                f.wait(timeout=5.0)
        gate.set_result("open")
        for f in admitted:
            assert f.wait(timeout=5.0) == "open"
        # the slots are released with the reply: a fresh send is admitted
        deadline = time.monotonic() + 5.0
        while True:
            try:
                assert app.send("gated", "get").wait(timeout=5.0) == "open"
                break
            except Rejected:
                assert time.monotonic() < deadline
                time.sleep(0.005)
        stats = app.backend_stats()
    assert stats.bulkhead_rejections == 4, stats
    assert stats.rejections == 0, stats    # the mailbox never refused


def test_bulkhead_enforced_on_inline_path():
    """An inlined call that suspends holds its bulkhead slot until the
    reply resolves; a concurrent inlined attempt over the same edge is
    refused at admission without entering the handler."""
    gate = Future()
    entered = threading.Semaphore(0)

    def hold(svc, payload):
        entered.release()
        return (yield Wait(gate))

    def root(svc, payload):
        f = yield AsyncRpc("leaf", "get", payload)
        return (yield Wait(f))

    pol = ResiliencePolicy(deadline=30.0, breakers=False, bulkhead=1)
    app = App(backend="fiber", net_latency=0.0, resilience=pol)
    app.add_service(ServiceSpec("leaf", {"get": hold}, n_workers=1))
    app.add_service(ServiceSpec("root", {"get": root}, n_workers=2))
    with app:
        first = app.send("root", "get", 0)
        assert entered.acquire(timeout=5.0)   # inlined attempt holds the slot
        second = app.send("root", "get", 1)
        with pytest.raises(Rejected, match="bulkhead full"):
            second.wait(timeout=5.0)
        gate.set_result("open")
        assert first.wait(timeout=5.0) == "open"
        stats = app.backend_stats()
    assert stats.inline_calls >= 1, stats
    assert stats.bulkhead_rejections >= 1, stats


def test_bulkhead_rejection_is_retryable_but_not_breaker_evidence():
    """A bulkhead rejection may be retried (the slot can free up), and it
    must NOT be recorded against the edge's breaker — the destination was
    never exercised, so it is not evidence of destination health."""
    gate = Future()
    entered = threading.Semaphore(0)

    def hold(svc, payload):
        entered.release()
        return (yield Wait(gate))

    pol = ResiliencePolicy(
        deadline=30.0, breakers=True, breaker_window=8,
        breaker_min_volume=2, breaker_reset=30.0, bulkhead=1,
        retry=RetryPolicy(max_attempts=8, base_backoff=0.01,
                          max_backoff=0.02, jitter=0.0))
    app = App(backend="fiber", net_latency=0.0, resilience=pol)
    app.add_service(ServiceSpec("gated", {"get": hold}, n_workers=2))
    with app:
        first = app.send("gated", "get")
        assert entered.acquire(timeout=5.0)
        second = app.send("gated", "get")   # rejected now, retried later
        time.sleep(0.05)                    # let a few retries be refused
        gate.set_result("open")
        assert first.wait(timeout=5.0) == "open"
        assert second.wait(timeout=5.0) == "open"   # a retry got the slot
        stats = app.backend_stats()
        assert app._breakers[("gated", "get")].state == "closed"
    assert stats.retries >= 1, stats
    assert stats.bulkhead_rejections >= 1, stats
    assert stats.breaker_opens == 0, stats  # rejections are not failures
