"""The CI bench-smoke lane installs *only numpy* — no jax.

Everything on the smoke path (repro.core, repro.apps, the smoke harness and
the trend comparator) must therefore import cleanly when jax does not exist
at all.  This test runs that import in a subprocess with a meta-path finder
that makes any ``import jax`` raise, which is stronger than checking the
current environment (where jax IS installed and a stray import would pass
silently).

Since PR 10 the *static* half of this contract is owned by lint rule A103
(``python -m repro.analysis.lint`` — see docs/ANALYSIS.md): it walks the
module-level import closure of every ``repro.core``/``repro.apps`` module
and names the offending chain, catching a stray jax import even in a
module the smoke path never loads.  The CI bench lane runs that lint in
its numpy-only environment; this file keeps the *runtime* half — proving
the import machinery actually executes jax-free — plus a cross-check that
the delegation target exists and holds.
"""
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

_PROBE = r"""
import importlib.abc
import sys


class _JaxBlocker(importlib.abc.MetaPathFinder):
    def find_spec(self, fullname, path=None, target=None):
        if fullname == "jax" or fullname.startswith(("jax.", "jaxlib")):
            raise ImportError(f"jax is not installed in the smoke lane "
                              f"(blocked import of {fullname!r})")
        return None


sys.meta_path.insert(0, _JaxBlocker())

# the full smoke-lane import surface
import repro.core            # noqa: E402,F401
import repro.apps            # noqa: E402,F401
import benchmarks.run        # noqa: E402,F401
import benchmarks.bench_smoke  # noqa: E402,F401
import benchmarks.trend      # noqa: E402,F401
from repro.apps import build_bench_app  # noqa: E402

# belt and braces: nothing smuggled jax in before the blocker either
leaked = [m for m in sys.modules
          if m == "jax" or m.startswith(("jax.", "jaxlib"))]
assert not leaked, f"jax modules leaked into the smoke path: {leaked}"

# and the matrix is actually buildable without jax (wiring only, no start)
app = build_bench_app("socialnetwork", "event-loop")
assert app.services
print("smoke path is jax-free")
"""


def test_smoke_path_imports_without_jax():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _PROBE], cwd=str(REPO),
                          env=env, capture_output=True, text=True,
                          timeout=120)
    assert proc.returncode == 0, (
        f"smoke-path import pulled in jax (or failed outright):\n"
        f"stdout: {proc.stdout}\nstderr: {proc.stderr}")
    assert "smoke path is jax-free" in proc.stdout


def test_static_import_closure_delegated_to_lint():
    """The import-graph half of the contract: rule A103 exists in the lint
    pass and finds no ``repro.core``/``repro.apps`` -> jax chain in the
    shipped tree (the runtime subprocess above can only see modules the
    smoke path actually loads; A103 sees every module on disk)."""
    from repro.analysis.lint import RULES, lint_paths
    assert "A103" in RULES
    findings = [f for f in lint_paths([str(REPO / "src" / "repro")])
                if f.rule == "A103"]
    assert findings == [], "\n".join(f.render() for f in findings)
