"""Training substrate tests: optimizer, grad accumulation, checkpointing,
data pipeline, loss-goes-down integration."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import Model
from repro.training import (AdamWConfig, CheckpointManager, Prefetcher,
                            SyntheticDataset, TrainSettings, adamw_init,
                            make_train_step)


def _setup(arch="qwen2-0.5b", accum=1):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, decay_steps=50)
    opt_state = adamw_init(params, opt_cfg)
    step_fn = jax.jit(make_train_step(
        model, opt_cfg, TrainSettings(accum_steps=accum)))
    data = SyntheticDataset(cfg, batch=4, seq_len=32, seed=1)
    return model, params, opt_state, step_fn, data


def test_loss_decreases_over_steps():
    model, params, opt_state, step_fn, data = _setup()
    batch = data.batch_at(0)  # overfit one batch
    losses = []
    for _ in range(20):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]
    assert np.isfinite(losses[-1])


def test_grad_accum_matches_full_batch():
    """accum_steps=2 over batch 4 == single step over the same batch
    (up to accumulation-order fp noise)."""
    model, params, opt_state, _, data = _setup()
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, decay_steps=50)
    s1 = jax.jit(make_train_step(model, opt_cfg, TrainSettings(accum_steps=1)))
    s2 = jax.jit(make_train_step(model, opt_cfg, TrainSettings(accum_steps=2)))
    batch = data.batch_at(0)
    p1, _, m1 = s1(params, opt_state, batch)
    p2, _, m2 = s2(params, opt_state, batch)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        p1, p2)
    assert max(jax.tree.leaves(diffs)) < 5e-2


def test_bf16_optimizer_state():
    cfg = get_smoke_config("qwen2-0.5b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3, state_dtype="bfloat16")
    opt_state = adamw_init(params, opt_cfg)
    assert all(x.dtype == jnp.bfloat16
               for x in jax.tree.leaves(opt_state["m"]))
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    batch = SyntheticDataset(cfg, 2, 16).batch_at(0)
    params, opt_state, metrics = step_fn(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_checkpoint_roundtrip(tmp_path):
    model, params, opt_state, step_fn, data = _setup()
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    state = {"params": params, "opt": opt_state}
    fut = mgr.save_async(3, state)
    path = fut.wait(timeout=30)
    assert os.path.exists(os.path.join(path, "manifest.json"))

    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          state)
    step, restored = mgr.restore(target)
    assert step == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    mgr.close()


def test_checkpoint_rotation_and_commit(tmp_path):
    model, params, opt_state, _, _ = _setup()
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    state = {"params": params}
    for s in (1, 2, 3, 4):
        mgr.save_async(s, state).wait(timeout=30)
    names = mgr.list_checkpoints()
    assert names == ["step_00000003", "step_00000004"]
    assert mgr.latest_step() == 4
    # uncommitted dir (no manifest) must be ignored
    os.makedirs(os.path.join(str(tmp_path), "step_00000099"))
    assert mgr.latest_step() == 4
    mgr.close()


def test_restart_resumes_training(tmp_path):
    """Full fault-tolerance loop: train, checkpoint, 'crash', restore,
    continue — losses identical to an uninterrupted run."""
    model, params, opt_state, step_fn, data = _setup()
    mgr = CheckpointManager(str(tmp_path))

    # uninterrupted reference
    p, o = params, opt_state
    ref_losses = []
    for s in range(6):
        p, o, m = step_fn(p, o, data.batch_at(s))
        ref_losses.append(float(m["loss"]))

    # interrupted run: 3 steps, save, restore, 3 more
    p, o = params, opt_state
    for s in range(3):
        p, o, m = step_fn(p, o, data.batch_at(s))
    mgr.save_async(3, {"params": p, "opt": o}).wait(timeout=30)

    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          {"params": p, "opt": o})
    step, restored = mgr.restore(target)
    p2, o2 = restored["params"], restored["opt"]
    post = []
    for s in range(step, 6):
        p2, o2, m = step_fn(p2, o2, data.batch_at(s))
        post.append(float(m["loss"]))
    np.testing.assert_allclose(post, ref_losses[3:], rtol=1e-5)
    mgr.close()


def test_prefetcher():
    cfg = get_smoke_config("qwen2-0.5b")
    ds = SyntheticDataset(cfg, 2, 16)
    pf = Prefetcher(ds, depth=2)
    b1 = next(pf)
    b2 = next(pf)
    assert b1["tokens"].shape == (2, 16)
    assert not np.array_equal(b1["tokens"], b2["tokens"])
    pf.close()


def test_dataset_deterministic():
    cfg = get_smoke_config("qwen2-0.5b")
    a = SyntheticDataset(cfg, 2, 16, seed=7).batch_at(5)
    b = SyntheticDataset(cfg, 2, 16, seed=7).batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticDataset(cfg, 2, 16, seed=8).batch_at(5)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_adafactor_loss_decreases():
    from repro.training.optimizer import make_optimizer
    from repro.training import make_train_step, TrainSettings
    cfg = get_smoke_config("qwen2-0.5b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, decay_steps=50,
                          state_dtype="bfloat16")
    init_fn, _ = make_optimizer("adafactor", opt_cfg)
    opt_state = init_fn(params)
    # factored second moment: no full-size v
    import math
    m_bytes = sum(math.prod(x.shape) * x.dtype.itemsize
                  for x in jax.tree.leaves(opt_state["m"]))
    v_bytes = sum(math.prod(x.shape) * x.dtype.itemsize
                  for x in jax.tree.leaves(opt_state["vr"])) + \
        sum(math.prod(x.shape) * x.dtype.itemsize
            for x in jax.tree.leaves(opt_state["vc"]))
    assert v_bytes < m_bytes / 4, (v_bytes, m_bytes)
    step_fn = jax.jit(make_train_step(
        model, opt_cfg, TrainSettings(optimizer="adafactor",
                                      opt_state_dtype="bfloat16")))
    batch = SyntheticDataset(cfg, 4, 32, seed=1).batch_at(0)
    losses = []
    for _ in range(20):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.95, losses[:3] + losses[-3:]
