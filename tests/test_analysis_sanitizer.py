"""Self-tests for the dynamic concurrency sanitizer (``repro.analysis``).

Each detector gets a positive (seeded bug is flagged) and a negative
(properly-synchronized equivalent is clean) — plus the headline
acceptance check: a healthy app exercised across all 8 backends under the
sanitizer, with its locks proxy-tracked, produces zero findings.
"""
import threading
import time

import pytest

from repro.analysis.sanitizer import (Sanitizer, TrackedLock, attached,
                                      track_app_locks)
from repro.core import (BACKEND_NAMES, App, AsyncRpc, Compute, ServiceSpec,
                        SpawnLocal, Wait, instrument)


def _rules(findings):
    return {f.rule for f in findings}


# ------------------------------------------------------------------ SAN-RACE
def test_racy_counter_flagged():
    """Two threads hitting one shared counter with no synchronization edge
    between them is a race, even though the sanitizer saw the events in a
    serial order."""
    with attached() as san:
        def worker():
            instrument.hooks.access("stats.requests", write=True)

        t1 = threading.Thread(target=worker)
        t2 = threading.Thread(target=worker)
        t1.start(); t1.join()
        t2.start(); t2.join()
        findings = san.check()
    assert "SAN-RACE" in _rules(san.errors())
    assert any("stats.requests" in f.message for f in findings)


def test_channel_synchronized_counter_clean():
    """The same cross-thread counter handoff through a queue put/take edge
    (the runtime's mailbox pattern) is ordered — no race."""
    chan = object()
    with attached() as san:
        def producer():
            instrument.hooks.access("stats.requests", write=True)
            instrument.hooks.queue_put(chan)

        def consumer():
            instrument.hooks.queue_take(chan)
            instrument.hooks.access("stats.requests", write=True)

        t1 = threading.Thread(target=producer)
        t1.start(); t1.join()
        t2 = threading.Thread(target=consumer)
        t2.start(); t2.join()
        san.check()
    assert san.errors() == []


def test_concurrent_reads_clean_then_unordered_write_flagged():
    with attached() as san:
        def reader():
            instrument.hooks.access("stats.snapshot", write=False)

        t1 = threading.Thread(target=reader)
        t2 = threading.Thread(target=reader)
        t1.start(); t1.join()
        t2.start(); t2.join()
        assert san.check() == []          # readers never race readers
        t3 = threading.Thread(
            target=lambda: instrument.hooks.access("stats.snapshot",
                                                   write=True))
        t3.start(); t3.join()
        san.check()
    assert "SAN-RACE" in _rules(san.errors())


# ------------------------------------------------------------ SAN-LOCK-ORDER
def test_two_lock_inversion_flagged():
    """AB on one thread, BA on another: a deadlock-capable cycle even when
    this particular run got away with it."""
    a, b = threading.Lock(), threading.Lock()
    with attached() as san:
        ta = TrackedLock(a, "lock.A")
        tb = TrackedLock(b, "lock.B")

        def ab():
            with ta:
                with tb:
                    pass

        def ba():
            with tb:
                with ta:
                    pass

        t1 = threading.Thread(target=ab)
        t1.start(); t1.join()
        t2 = threading.Thread(target=ba)
        t2.start(); t2.join()
        san.check()
    errs = san.errors()
    assert "SAN-LOCK-ORDER" in _rules(errs)
    assert any("lock.A" in f.message and "lock.B" in f.message for f in errs)


def test_consistent_lock_order_clean():
    a, b = threading.Lock(), threading.Lock()
    with attached() as san:
        ta = TrackedLock(a, "lock.A")
        tb = TrackedLock(b, "lock.B")

        def ab():
            with ta:
                with tb:
                    pass

        for _ in range(2):
            t = threading.Thread(target=ab)
            t.start(); t.join()
        san.check()
    assert san.errors() == []


# -------------------------------------------------------- SAN-SELF-DEADLOCK
def test_same_carrier_self_deadlock_warned():
    """A handler blocking on a future whose only producer is a fiber parked
    behind it on the same single-carrier scheduler: the producer can never
    run.  Warn tier this PR (see docs/ANALYSIS.md)."""
    def child(svc, payload):
        yield Compute(1e-6)
        return "child"

    def bad(svc, payload):
        fut = yield SpawnLocal(lambda: child(svc, payload))
        try:
            fut.wait(timeout=0.05)    # blocking wait ON the carrier thread
        except TimeoutError:
            pass
        return "timed-out"

    app = App(backend="fiber")
    app.add_service(ServiceSpec("solo", {"bad": bad}, n_workers=1))
    with attached() as san:
        with app:
            assert app.send("solo", "bad").wait(timeout=5.0) == "timed-out"
        san.check()
    assert "SAN-SELF-DEADLOCK" in _rules(san.warnings())
    assert "SAN-SELF-DEADLOCK" not in _rules(san.errors())


# --------------------------------------------------------------- clean sweep
@pytest.mark.parametrize("backend", BACKEND_NAMES)
def test_healthy_app_sanitizer_clean(backend):
    """The acceptance bar: a healthy request chain on every backend, locks
    proxy-tracked, runs with zero sanitizer findings."""
    def leaf(svc, payload):
        yield Compute(5e-6)
        return payload * 2

    def root(svc, payload):
        f = yield AsyncRpc("leaf", "get", payload)
        return (yield Wait(f))

    app = App(backend=backend)
    app.add_service(ServiceSpec("leaf", {"get": leaf}, n_workers=2))
    app.add_service(ServiceSpec("root", {"get": root}, n_workers=2))
    with attached(app=app) as san:
        with app:
            futs = [app.send("root", "get", i) for i in range(32)]
            for i, f in enumerate(futs):
                assert f.wait(timeout=5.0) == 2 * i
        findings = san.check()
    assert san.errors() == [], [str(f) for f in findings]
    assert san.counts["future_set"] > 0   # the seam actually fired


def test_stop_phase_order_recorded():
    """App.stop's documented shutdown order is observable on the seam —
    the satellite-2 audit trail (timer drain last, after executors)."""
    def get(svc, payload):
        yield Compute(1e-6)
        return "ok"

    app = App(backend="fiber")
    app.add_service(ServiceSpec("svc", {"get": get}, n_workers=1))
    with attached() as san:
        with app:
            assert app.send("svc", "get").wait(timeout=5.0) == "ok"
        san.check()
    phases = san.stop_phases(app)
    assert phases == ["executor_stop", "offload_stop", "timer_stop"]
    assert san.errors() == []


def test_track_app_locks_restores():
    app = App(backend="fiber")
    app.add_service(ServiceSpec("svc", {}, n_workers=1))
    svc = app.services["svc"]
    orig = svc.lock
    restore = track_app_locks(app)
    assert isinstance(svc.lock, TrackedLock)
    restore()
    assert svc.lock is orig


def test_event_counts_accumulate():
    """The counts surface the CI job summary reads is populated per event."""
    san = Sanitizer()
    instrument.install(san)
    try:
        from repro.core.future import Future
        fut = Future()
        fut.set_result(1)
        assert fut.wait(timeout=1.0) == 1
    finally:
        instrument.uninstall()
    assert san.counts["future_set"] == 1
