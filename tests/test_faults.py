"""Deterministic fault injection: seeded traces, backend parity, teardown.

Covers the PR 9 fault layer (``repro.core.faults``): bit-reproducible
seeded schedules, identical fault *semantics* across all 8 backends
(injection happens after admission on both the mailbox/carrier path and
the zero-handoff inline path), crash→restart round trips riding the
restartable-executor contract, and the no-orphaned-waiters discipline for
blackholed replies at ``App.stop()``.
"""
import time

import pytest

from repro.core import (BACKEND_NAMES, App, AsyncRpc, Compute,
                        DeadlineExceeded, FaultPlan, FaultRule,
                        InjectedFault, ServiceCrashed, ServiceSpec, Sleep,
                        TrialResult, Wait, run_trial)


# --------------------------------------------------------------- app helpers
def _chain_app(backend: str, leaf_sleep: float = 2e-3) -> App:
    """root --rpc--> leaf: the fault target is always the (leaf, get)
    edge, reached through root so cooperative backends exercise the inline
    fast path and thread backends the carrier path."""
    def leaf(svc, payload):
        yield Compute(20e-6)
        yield Sleep(leaf_sleep)
        return "leaf"

    def root(svc, payload):
        f = yield AsyncRpc("leaf", "get", payload)
        return (yield Wait(f))

    app = App(backend=backend, net_latency=0.0)
    app.add_service(ServiceSpec("leaf", {"get": leaf}, n_workers=2))
    app.add_service(ServiceSpec("root", {"get": root}, n_workers=2))
    return app


def _install(app: App, rules, seed: int = 0) -> FaultPlan:
    plan = FaultPlan(rules, seed=seed)
    app.set_faults(plan)
    return plan


# ------------------------------------------------------------- rule validity
def test_rule_validation():
    with pytest.raises(ValueError):
        FaultRule(dest="leaf", kind="gremlins")
    with pytest.raises(ValueError):
        FaultRule(dest="leaf", kind="error", start=1.0, stop=1.0)


def test_unarmed_plan_injects_nothing():
    app = _chain_app("fiber")
    plan = _install(app, [FaultRule(dest="leaf", kind="error")])
    with app:
        assert not plan.armed
        f = app.send("root", "get")
        assert f.wait(timeout=5.0) == "leaf"
        assert plan.stats.injected == 0 and plan.trace == []


# ------------------------------------------------------ seeded determinism
def _run_seeded_scenario(seed: int):
    """30 sequential requests against a probabilistic plan; returns the
    injected-fault trace.  Sequential (one in flight at a time) so the RNG
    draw order is the request order — the determinism contract."""
    app = _chain_app("fiber")
    plan = _install(app, [
        FaultRule(dest="leaf", method="get", kind="error", error_rate=0.4,
                  stop=60.0),
        FaultRule(dest="leaf", method="get", kind="latency", latency=1e-4,
                  spike_prob=0.5, spike_latency=2e-3, stop=60.0),
    ], seed=seed)
    with app:
        plan.arm()
        for _ in range(30):
            f = app.send("root", "get")
            try:
                f.wait(timeout=5.0)
            except InjectedFault:
                pass
    return list(plan.trace)


def test_same_plan_same_seed_identical_trace():
    """Same plan + same seed ⇒ bit-identical injected-fault trace; a
    different seed produces a different one (the scenario is really being
    driven by the RNG, not by a constant)."""
    t1 = _run_seeded_scenario(seed=7)
    t2 = _run_seeded_scenario(seed=7)
    t3 = _run_seeded_scenario(seed=8)
    assert t1 == t2
    assert len(t1) > 5          # the probabilistic rules actually fired
    assert t1 != t3


def test_rearm_resets_the_schedule_and_rng():
    """Every arm() re-seeds the RNG and clears the trace, so one plan
    object replays bit-identically trial after trial."""
    app = _chain_app("fiber")
    plan = _install(app, [FaultRule(dest="leaf", kind="error",
                                    error_rate=0.5, stop=60.0)], seed=3)
    traces = []
    with app:
        for _ in range(2):
            plan.arm()
            for _ in range(20):
                f = app.send("root", "get")
                try:
                    f.wait(timeout=5.0)
                except InjectedFault:
                    pass
            traces.append(list(plan.trace))
    assert traces[0] == traces[1] and len(traces[0]) > 2


# ------------------------------------------------- 8-backend fault parity
@pytest.mark.parametrize("backend", BACKEND_NAMES)
def test_error_fault_parity(backend):
    """An injected error must surface as InjectedFault through the calling
    handler on every backend, and count identically (the injection point
    sits after admission on both the carrier and the inline path)."""
    app = _chain_app(backend)
    plan = _install(app, [FaultRule(dest="leaf", method="get", kind="error")])
    with app:
        plan.arm()
        for _ in range(5):
            f = app.send("root", "get")
            with pytest.raises(InjectedFault):
                f.wait(timeout=5.0)
    assert plan.stats.get("error") == 5
    assert plan.trace == [("error", "leaf", "get")] * 5
    assert app.backend_stats().faults_injected == 5


@pytest.mark.parametrize("backend", BACKEND_NAMES)
def test_latency_fault_parity(backend):
    """Injected latency delays the reply by at least the added amount on
    every backend (a leading Sleep the executor times like any other)."""
    app = _chain_app(backend, leaf_sleep=1e-4)
    plan = _install(app, [FaultRule(dest="leaf", kind="latency",
                                    latency=0.05)])
    with app:
        plan.arm()
        t0 = time.perf_counter()
        f = app.send("root", "get")
        assert f.wait(timeout=5.0) == "leaf"
        assert time.perf_counter() - t0 >= 0.045
    assert plan.stats.get("latency") == 1


@pytest.mark.parametrize("backend", BACKEND_NAMES)
def test_hang_fault_parity(backend):
    """A blackholed edge never replies: the caller's deadline machinery —
    not the destination — fails the request, on every backend."""
    app = _chain_app(backend)
    plan = _install(app, [FaultRule(dest="leaf", kind="hang")])
    with app:
        plan.arm()
        f = app.send("root", "get", deadline=time.monotonic() + 0.05)
        with pytest.raises(DeadlineExceeded):
            f.wait(timeout=5.0)
    assert plan.stats.get("hang") == 1


@pytest.mark.parametrize("backend", BACKEND_NAMES)
def test_brownout_fault_parity(backend):
    """Brownout scales the handler's yielded service time (Sleep and
    Compute) by the rule factor for the window — observable as wall time on
    every backend — and lifts cleanly when the window ends."""
    app = _chain_app(backend, leaf_sleep=5e-3)
    plan = _install(app, [FaultRule(dest="leaf", kind="brownout",
                                    factor=8.0, stop=0.4)])
    with app:
        plan.arm()
        t0 = time.perf_counter()
        f = app.send("root", "get")
        assert f.wait(timeout=5.0) == "leaf"
        sick = time.perf_counter() - t0
        assert sick >= 0.035            # 5ms sleep x8 = 40ms
        time.sleep(max(0.0, 0.4 - (time.perf_counter() - t0)) + 0.02)
        t0 = time.perf_counter()
        f = app.send("root", "get")
        assert f.wait(timeout=5.0) == "leaf"
        assert time.perf_counter() - t0 < 0.035   # window over: healthy
    assert plan.stats.get("brownout") == 1


@pytest.mark.parametrize("backend", BACKEND_NAMES)
def test_crash_restart_roundtrip(backend):
    """A crash rule stops the destination's executor for its window
    (deliveries fail fast with ServiceCrashed) and restarts it at the
    window end — the idempotent-restart contract every backend honours."""
    app = _chain_app(backend)
    plan = _install(app, [FaultRule(dest="leaf", kind="crash",
                                    start=0.0, stop=0.2)])
    with app:
        plan.arm()
        time.sleep(0.02)                # let the crash timer fire
        f = app.send("root", "get")
        with pytest.raises(ServiceCrashed):
            f.wait(timeout=5.0)
        time.sleep(0.25)                # past the window: restarted
        f = app.send("root", "get")
        assert f.wait(timeout=5.0) == "leaf"
    assert plan.stats.get("crash") >= 1


# ------------------------------------- blackhole settlement (satellite fix)
@pytest.mark.parametrize("backend", ["thread", "fiber", "event-loop"])
def test_stop_settles_blackholed_waiters(backend):
    """Regression: App.stop() during an in-flight hang must settle the
    blackholed reply with a resolved exception so no waiter is orphaned —
    the root request (deadline-less, blocked on the hung leaf) resolves at
    stop instead of hanging forever."""
    app = _chain_app(backend)
    plan = _install(app, [FaultRule(dest="leaf", kind="hang")])
    app.start()
    plan.arm()
    f = app.send("root", "get")         # no deadline: would wait forever
    time.sleep(0.08)
    assert not f.done                   # genuinely hung mid-flight
    app.stop()
    assert f.wait_done(timeout=5.0)
    assert isinstance(f.exception(), InjectedFault)


def test_disarm_settles_blackholes_and_restarts_crashed():
    app = _chain_app("fiber")
    plan = _install(app, [FaultRule(dest="leaf", kind="hang", stop=60.0),
                          FaultRule(dest="leaf", kind="crash", start=100.0,
                                    stop=200.0)])
    with app:
        plan.arm()
        f = app.send("root", "get")
        time.sleep(0.05)
        assert not f.done
        plan.disarm()
        assert f.wait_done(timeout=5.0)
        assert isinstance(f.exception(), InjectedFault)
        # plan disarmed: traffic is healthy again on the same app
        f = app.send("root", "get")
        assert f.wait(timeout=5.0) == "leaf"


# --------------------------------------------------- schedules & trial clock
def test_windows_respect_the_armed_clock():
    """A rule scheduled for [0.2, 0.4) injects nothing before 0.2s and
    nothing after 0.4s on the armed clock."""
    app = _chain_app("fiber")
    plan = _install(app, [FaultRule(dest="leaf", kind="error",
                                    start=0.2, stop=0.4)])
    with app:
        plan.arm()
        f = app.send("root", "get")
        assert f.wait(timeout=5.0) == "leaf"     # t~0: before the window
        time.sleep(0.25)
        f = app.send("root", "get")
        with pytest.raises(InjectedFault):       # t~0.25: inside
            f.wait(timeout=5.0)
        time.sleep(0.2)
        f = app.send("root", "get")
        assert f.wait(timeout=5.0) == "leaf"     # t~0.45: after
    assert plan.stats.get("error") == 1


def test_run_trial_arms_installed_plan():
    """loadgen.run_trial arms an installed plan on the trial clock (default:
    only when unarmed; arm_faults=False leaves it alone)."""
    app = _chain_app("fiber")
    plan = _install(app, [FaultRule(dest="leaf", kind="error", stop=60.0)])

    def make_request(rng):
        return ("root", "get", None)

    with app:
        tr = run_trial(app, make_request, rate=200.0, duration=0.2, seed=1,
                       arm_faults=False)
        assert not plan.armed and tr.errors == 0
        tr = run_trial(app, make_request, rate=200.0, duration=0.2, seed=1)
        assert plan.armed
        assert tr.errors > 0            # every leaf call injected
        assert tr.backend_stats["faults_error"] > 0
        assert tr.backend_stats["faults_injected"] > 0


def test_faults_surface_in_trial_row():
    row = TrialResult(offered_rps=100.0, achieved_rps=90.0, duration=1.0,
                      p50=0.001, p99=0.002, mean=0.001, completed=90,
                      shed=0, errors=10,
                      backend_stats={"faults_injected": 12,
                                     "faults_error": 8,
                                     "faults_hang": 4}).row()
    assert "flt=12" in row and "err=8" in row and "hang=4" in row


# -------------------------------------------- faults as breaker evidence
@pytest.mark.parametrize("backend", ["thread", "fiber"])
def test_injected_errors_are_breaker_evidence(backend):
    """Injected errors feed the per-edge circuit breaker exactly like real
    failures — through the carrier path (thread) and the inline fast path
    (fiber) alike — and only the sick edge trips: the healthy method of
    the same service stays closed (per-edge blast radius)."""
    from repro.core import CircuitOpenError, ResiliencePolicy

    def leaf_get(svc, payload):
        yield Sleep(1e-4)
        return "get"

    def leaf_read(svc, payload):
        yield Sleep(1e-4)
        return "read"

    def root_sick(svc, payload):
        f = yield AsyncRpc("leaf", "get", payload)
        return (yield Wait(f))

    def root_read(svc, payload):
        f = yield AsyncRpc("leaf", "read", payload)
        return (yield Wait(f))

    app = App(backend=backend,
              resilience=ResiliencePolicy(deadline=0.5, breakers=True))
    app.add_service(ServiceSpec("leaf", {"get": leaf_get, "read": leaf_read},
                                n_workers=2))
    app.add_service(ServiceSpec("root", {"sick": root_sick,
                                         "read": root_read}, n_workers=2))
    plan = _install(app, [FaultRule(dest="leaf", method="get", kind="error")])
    with app:
        plan.arm()
        tripped = False
        for _ in range(30):
            f = app.send("root", "sick")
            try:
                f.wait(timeout=5.0)
            except (CircuitOpenError, InjectedFault):
                pass
            g = app.send("root", "read")         # healthy sibling edge
            assert g.wait(timeout=5.0) == "read"
            if app.resilience_by_edge().get(("leaf", "get"),
                                            {}).get("opens", 0):
                tripped = True
                break
        assert tripped, "sick edge breaker never opened on injected errors"
        by_edge = app.resilience_by_edge()
        assert by_edge.get(("leaf", "read"), {}).get("opens", 0) == 0
        assert by_edge.get(("root", "read"), {}).get("opens", 0) == 0


# -------------------------------------------------- accumulation semantics
def test_latency_and_brownout_accumulate():
    """Wrap-kind rules on the same edge compose: added latencies sum,
    brownout factors multiply — one wrapped handler, both counters tick."""
    app = _chain_app("fiber", leaf_sleep=2e-3)
    plan = _install(app, [
        FaultRule(dest="leaf", kind="latency", latency=0.02),
        FaultRule(dest="leaf", kind="brownout", factor=10.0),
    ])
    with app:
        plan.arm()
        t0 = time.perf_counter()
        f = app.send("root", "get")
        assert f.wait(timeout=5.0) == "leaf"
        assert time.perf_counter() - t0 >= 0.035  # 20ms pre + 2ms x10
    assert plan.stats.get("latency") == 1
    assert plan.stats.get("brownout") == 1
    assert plan.stats.injected == 1     # one request, one injection
