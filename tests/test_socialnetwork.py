"""Integration tests: DeathStarBench SocialNetwork clone on both backends."""
import pytest

from repro.apps import WORKLOADS, build_socialnetwork, make_request_factory
from repro.core import run_trial

BACKENDS = ("thread", "fiber")


@pytest.mark.parametrize("backend", BACKENDS)
def test_compose_post(backend):
    with build_socialnetwork(backend) as app:
        out = app.send("frontend", "compose", {"text": "hi @u http://x"}).wait(timeout=10)
        assert out == {"post_id": 42}


@pytest.mark.parametrize("backend", BACKENDS)
def test_read_timelines(backend):
    with build_socialnetwork(backend) as app:
        home = app.send("frontend", "read_home", {}).wait(timeout=10)
        user = app.send("frontend", "read_user", {}).wait(timeout=10)
        assert len(home["posts"]) == 10
        assert len(user["posts"]) == 10


@pytest.mark.parametrize("workload", WORKLOADS)
def test_workload_factories(workload):
    import numpy as np
    f = make_request_factory(workload)
    rng = np.random.default_rng(0)
    for _ in range(5):
        req = f(rng)
        dest, method = req[0], req[1]
        assert dest == "frontend"
        assert method in ("compose", "read_home", "read_user", "cached")
        if workload == "cached":  # session-affine 4-tuple
            assert req[3].startswith("s")


@pytest.mark.parametrize("backend", BACKENDS)
def test_low_rate_trial_completes(backend):
    """At low rates both backends must achieve ~offered rate (paper: fiber
    is comparable to threads at low load)."""
    with build_socialnetwork(backend) as app:
        tr = run_trial(app, make_request_factory("mixed"), rate=100,
                       duration=0.8, seed=3)
        assert tr.achieved_rps > 50, tr.row()
        assert tr.errors == 0


def test_incremental_migration():
    """Paper: services can be migrated one at a time without interruption."""
    app = build_socialnetwork("thread", overrides={"frontend": "fiber",
                                                   "text": "fiber"})
    with app:
        out = app.send("frontend", "compose", {"text": "t"}).wait(timeout=10)
        assert out == {"post_id": 42}


def test_spawn_accounting():
    """ComposePost fans out 7 async calls + 2 in Text = 9 calls/request.
    On the zero-handoff fast path all 9 inline (no carriers); with the fast
    path disabled, the PR 3 carrier-per-call accounting must come back."""
    with build_socialnetwork("fiber") as app:
        base = app.backend_stats()
        app.send("frontend", "compose", {"text": "t"}).wait(timeout=10)
        from repro.core import BackendStats
        d = BackendStats.delta(base, app.backend_stats())
        assert d.inline_calls == 9
        # 8 of the 9 inlined handlers suspend on their I/O sleep and park as
        # continuation fibers; only unique_id completes without suspending,
        # so exactly one call is fully zero-object (a CompletedFuture).
        assert d.spawns == 8
        assert d.fast_futures == 9   # no inlined reply ever took a Condition
        # compose(d0) -> text(d1) -> url_shorten/user_mention(d2)
        assert app.backend_stats().inline_depth_hwm == 2
    app = build_socialnetwork("fiber")
    app.inline_budget = 0
    with app:
        base = app.total_spawns()
        app.send("frontend", "compose", {"text": "t"}).wait(timeout=10)
        assert app.total_spawns() - base == 9
