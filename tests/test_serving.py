"""Serving engine integration tests: continuous batching over the fiber
(and baseline thread) runtimes with a tiny model."""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import Model
from repro.serving import ServeConfig, build_llm_app
from repro.serving.engine import InferenceEngine

BACKENDS = ("fiber", "thread")


def _tiny_model(arch="qwen2-0.5b"):
    cfg = get_smoke_config(arch).with_(remat=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _stop(app):
    app.services["engine"].state["stop"] = True
    time.sleep(0.05)
    app.stop()


def test_engine_direct_generation():
    model, params = _tiny_model()
    scfg = ServeConfig(max_batch=2, max_len=96, prefill_bucket=16,
                       max_new_tokens=4)
    eng = InferenceEngine(model, params, scfg)
    done = eng.submit(np.arange(8, dtype=np.int32) % model.cfg.vocab_size)
    adm = eng.admit_one()
    assert adm is not None
    eng.do_prefill(adm[0])
    for _ in range(8):
        eng.do_decode_step()
        if done.done:
            break
    toks = done.wait(timeout=5)
    assert len(toks) == 4
    assert all(0 <= t < model.cfg.vocab_size for t in toks)


def test_engine_greedy_matches_sequential_decode():
    """Continuous batching must not change greedy outputs vs a plain
    prefill+decode loop on the same model."""
    model, params = _tiny_model()
    P = 16
    scfg = ServeConfig(max_batch=2, max_len=96, prefill_bucket=P,
                       max_new_tokens=4)
    prompt = (np.arange(8, dtype=np.int32) * 7 + 3) % model.cfg.vocab_size

    # engine path
    eng = InferenceEngine(model, params, scfg)
    done = eng.submit(prompt)
    eng.do_prefill(eng.admit_one()[0])
    while not done.done:
        eng.do_decode_step()
    engine_tokens = done.wait(timeout=5)

    # reference path: same padded prompt, manual greedy decode
    import jax.numpy as jnp
    padded = np.zeros((1, P), np.int32)
    padded[0, :len(prompt)] = prompt
    logits, cache = jax.jit(model.prefill)(params, {"tokens": padded})
    cache = jax.tree.map(
        lambda x: jnp.pad(x, [(0, 0), (0, 0), (0, 96 - x.shape[2])]
                          + [(0, 0)] * (x.ndim - 3)) if x.ndim >= 3 else x,
        cache)
    toks = [int(np.argmax(np.asarray(logits)[0]))]
    pos = P
    for _ in range(3):
        lg, cache = jax.jit(model.decode_step)(
            params, cache, jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.asarray([pos], jnp.int32))
        toks.append(int(np.argmax(np.asarray(lg)[0])))
        pos += 1
    assert engine_tokens == toks


@pytest.mark.parametrize("backend", BACKENDS)
def test_llm_app_end_to_end(backend):
    model, params = _tiny_model()
    scfg = ServeConfig(max_batch=2, max_len=64, prefill_bucket=16,
                       max_new_tokens=4)
    app = build_llm_app(model, params, scfg, backend=backend)
    with app:
        app.send("engine", "run", None)      # launch driver
        futs = [app.send("api", "generate",
                         {"text": f"hello world {i}", "max_new": 4})
                for i in range(4)]
        outs = [f.wait(timeout=60) for f in futs]
        for out in outs:
            assert len(out["tokens"]) == 4
            assert isinstance(out["text"], str)
        app.services["engine"].state["stop"] = True


def test_continuous_batching_concurrency():
    """More requests than slots: all complete, slots are recycled."""
    model, params = _tiny_model()
    scfg = ServeConfig(max_batch=2, max_len=64, prefill_bucket=16,
                       max_new_tokens=3)
    app = build_llm_app(model, params, scfg, backend="fiber")
    with app:
        app.send("engine", "run", None)
        futs = [app.send("api", "generate", {"text": f"req {i}"})
                for i in range(6)]
        outs = [f.wait(timeout=120) for f in futs]
        assert all(len(o["tokens"]) == 3 for o in outs)
        eng = app.services["engine"].state["engine"]
        assert eng.generated >= 6 * 2
        app.services["engine"].state["stop"] = True


def test_engine_ssm_family():
    """Recurrent family (rwkv6) serves through the same engine."""
    model, params = _tiny_model("rwkv6-3b")
    scfg = ServeConfig(max_batch=2, max_len=64, prefill_bucket=16,
                       max_new_tokens=3)
    eng = InferenceEngine(model, params, scfg)
    done = eng.submit(np.arange(8, dtype=np.int32))
    eng.do_prefill(eng.admit_one()[0])
    while not done.done:
        eng.do_decode_step()
    assert len(done.wait(timeout=5)) == 3
