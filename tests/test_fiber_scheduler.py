"""Deterministic FiberScheduler unit tests + FiberExecutor regression tests.

Drives a single scheduler directly (no App, no transport) so timer order,
exception propagation and shutdown behaviour are exact, not statistical.
"""
import threading
import time

import pytest

from repro.core import Future, Sleep, Wait, WaitAll
from repro.core.executor import FiberExecutor
from repro.core.fiber import Fiber, FiberScheduler, StealGroup


@pytest.fixture
def sched():
    s = FiberScheduler(app=None, name="test-sched")
    s.start()
    yield s
    s.stop()


# ------------------------------------------------------------ timer order
def test_timers_fire_in_deadline_order(sched):
    """Fibers spawned in one order but sleeping different durations must
    resume in deadline order."""
    order = []

    def napper(tag, seconds):
        yield Sleep(seconds)
        order.append(tag)

    futs = [sched.spawn_external(napper("slow", 0.06)),
            sched.spawn_external(napper("fast", 0.01)),
            sched.spawn_external(napper("mid", 0.03))]
    for f in futs:
        f.wait(timeout=5)
    assert order == ["fast", "mid", "slow"]


def test_equal_deadline_timers_fire_fifo():
    """The shared TimerWheel (repro.core.timers) tie-breaks *identical*
    deadlines by push sequence (without it, heapq would compare Fiber
    payloads and raise).  Entries are injected directly so the deadlines
    are exactly equal — Sleep-computed deadlines are always strictly
    increasing."""
    from repro.core.fiber import Fiber

    s = FiberScheduler(app=None, name="tie-test")
    order = []

    def body(tag):
        order.append(tag)
        return tag
        yield  # pragma: no cover - marks this as a generator

    deadline = time.monotonic() + 0.01
    fibs = [Fiber(body(i)) for i in range(5)]
    for fib in fibs:  # scheduler not started yet: safe to touch the wheel
        s._timers.push(deadline, (fib, None))
    s.start()
    try:
        for fib in fibs:
            fib.future.wait(timeout=5)
    finally:
        s.stop()
    assert order == list(range(5))


def test_sleep_zero_resumes(sched):
    def z():
        yield Sleep(0.0)
        return "done"
    assert sched.spawn_external(z()).wait(timeout=5) == "done"


# ------------------------------------------------- WaitAll exception paths
def test_waitall_exception_propagates_when_already_failed(sched):
    """Fast path: all futures resolved, one failed -> thrown into fiber."""
    ok, bad = Future(), Future()
    ok.set_result(1)
    bad.set_exception(ValueError("pre-failed"))

    def joiner():
        yield WaitAll([ok, bad])

    with pytest.raises(ValueError, match="pre-failed"):
        sched.spawn_external(joiner()).wait(timeout=5)


def test_waitall_exception_propagates_when_resolved_late(sched):
    """Slow path: fiber parks on WaitAll, a future fails afterwards."""
    a, b = Future(), Future()
    parked = threading.Event()

    def joiner():
        parked.set()
        yield WaitAll([a, b])

    fut = sched.spawn_external(joiner())
    assert parked.wait(timeout=5)
    a.set_result(1)
    b.set_exception(RuntimeError("late failure"))
    with pytest.raises(RuntimeError, match="late failure"):
        fut.wait(timeout=5)


def test_waitall_exception_is_catchable_inside_fiber(sched):
    bad = Future()
    bad.set_exception(KeyError("caught"))

    def joiner():
        try:
            yield WaitAll([bad])
        except KeyError:
            return "recovered"
        return "missed"

    assert sched.spawn_external(joiner()).wait(timeout=5) == "recovered"


# ------------------------------------------------------------ clean stop()
@pytest.mark.sanitizer_allow("SAN-FUT-LEAK")  # the abandoned park is the point
def test_stop_with_parked_fibers_returns_promptly():
    """stop() must join the scheduler thread even while fibers are parked
    on a never-resolved future (shutdown must not hang on live fibers)."""
    sched = FiberScheduler(app=None, name="stop-test")
    sched.start()
    parked = threading.Event()
    never = Future()

    def waiter():
        parked.set()
        yield Wait(never)

    sched.spawn_external(waiter())
    assert parked.wait(timeout=5)
    t0 = time.perf_counter()
    sched.stop()
    assert time.perf_counter() - t0 < 2.0
    assert not sched._thread.is_alive()


def test_stop_idle_scheduler():
    sched = FiberScheduler(app=None, name="idle-stop")
    sched.start()
    sched.stop()
    assert not sched._thread.is_alive()


# ------------------------------------------- FiberExecutor round-robin race
def test_deliver_round_robin_is_balanced_under_concurrency():
    """Regression: `self._rr += 1` was an unlocked read-modify-write, so
    concurrent deliver() calls lost ticket increments and piled fibers onto
    a subset of schedulers.  With an atomic counter the split is exact."""
    n_sched, n_threads, per_thread = 4, 8, 500
    ex = FiberExecutor(app=None, name="rr", n_workers=n_sched)
    counts = [0] * n_sched
    lock = threading.Lock()
    for i, s in enumerate(ex._scheds):
        def spy(gen, reply=None, name="", i=i):
            with lock:
                counts[i] += 1
        s.spawn_external = spy

    def hammer():
        for _ in range(per_thread):
            ex.deliver(iter(()), Future())

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    total = n_threads * per_thread
    assert sum(counts) == total
    # itertools.count() hands out each ticket exactly once, so every
    # scheduler gets exactly total / n_sched deliveries.
    assert counts == [total // n_sched] * n_sched


# ------------------------------------------------------------ work stealing
def test_idle_scheduler_steals_from_loaded_sibling():
    """Pre-load one scheduler of a steal group with many ready fibers whose
    bodies occupy its thread; the idle sibling must steal and run some."""
    group = StealGroup()
    a = FiberScheduler(app=None, name="steal-a", steal_group=group)
    b = FiberScheduler(app=None, name="steal-b", steal_group=group)
    ran_on = []
    lock = threading.Lock()

    def body(i):
        time.sleep(0.004)  # occupy the carrying thread, non-cooperatively
        with lock:
            ran_on.append((i, threading.current_thread().name))
        return i
        yield  # pragma: no cover - marks this as a generator

    fibs = [Fiber(body(i)) for i in range(40)]
    for fib in fibs:  # scheduler not started yet: safe to touch the deque
        a._ready.append((fib, None))
    a.start()
    b.start()
    try:
        results = [fib.future.wait(timeout=20) for fib in fibs]
    finally:
        a.stop()
        b.stop()
    assert results == list(range(40))
    threads = {t for _, t in ran_on}
    assert "steal-b" in threads, "idle sibling never stole"
    assert b.steals > 0
    assert a.steals + b.steals <= 40


def test_steal_mode_preserves_exception_propagation():
    group = StealGroup()
    scheds = [FiberScheduler(app=None, name=f"exc-{i}", steal_group=group)
              for i in range(2)]
    for s in scheds:
        s.start()

    def boom():
        yield Sleep(0.001)
        raise ValueError("steal-mode boom")

    try:
        futs = [scheds[i % 2].spawn_external(boom()) for i in range(8)]
        for f in futs:
            with pytest.raises(ValueError, match="steal-mode boom"):
                f.wait(timeout=10)
    finally:
        for s in scheds:
            s.stop()


def test_steal_executor_keeps_round_robin_placement():
    """Steal mode keeps boost-style naive rr placement (a least-loaded
    variant measurably herded bursts onto one scheduler); imbalance is
    corrected by stealing, not placement."""
    ex = FiberExecutor(app=None, name="rr-steal", n_workers=2, steal=True)
    counts = [0, 0]
    for i, s in enumerate(ex._scheds):
        def spy(gen, reply=None, name="", i=i):
            counts[i] += 1
        s.spawn_external = spy
    for _ in range(6):
        ex.deliver(iter(()), Future())
    assert counts == [3, 3]


def test_single_scheduler_steal_executor_degenerates_cleanly():
    """n_workers=1 + steal: no group is formed, nothing to steal from."""
    ex = FiberExecutor(app=None, name="solo", n_workers=1, steal=True)
    assert ex._scheds[0]._group is None
    ex.start()
    try:
        def one():
            yield Sleep(0.001)
            return "ok"
        fut = Future()
        ex.deliver(one(), fut)
        assert fut.wait(timeout=5) == "ok"
        assert ex.steals == 0
    finally:
        ex.stop()
