"""RequestContext: the first-class request carrier (PR 8).

Covers the carrier itself (hop algebra, zero-alloc plain path, stable
session keys), end-to-end propagation through every backend (depth,
deadline, session, trace — read back by handlers via the
``CurrentContext`` effect, through the inline fast path and the rings
alike), by-session shard pinning determinism across trials and restarts,
the per-edge ``(dest, method)`` resilience keying, cache hit/miss
accounting parity across the backend matrix, and the Zipfian session
workload's distribution sanity.
"""
import collections
import threading
import time

import numpy as np
import pytest

from repro.apps import get_app_def
from repro.apps._workload import make_zipf_factory
from repro.core import (App, AsyncRpc, BACKEND_NAMES, CircuitOpenError,
                        CurrentContext, Future, RequestContext,
                        ResiliencePolicy, ServiceSpec, Sleep, Wait,
                        session_key)
from repro.core.eventloop import ShardedEventLoopExecutor


# ------------------------------------------------------------- the carrier
def test_hop_plain_path_allocates_nothing():
    """No parent, no deadline -> hop returns None: plain sends never pay a
    context allocation anywhere on the hot path."""
    assert RequestContext.hop(None, None) is None


def test_hop_creates_depth_one_child_from_bare_deadline():
    ctx = RequestContext.hop(None, 123.0)
    assert ctx is not None
    assert ctx.deadline == 123.0
    assert ctx.depth == 1
    assert ctx.session is None


def test_hop_inherits_and_tightens():
    parent = RequestContext(session="s1", deadline=100.0)
    child = RequestContext.hop(parent, 90.0)
    assert child.session == "s1"
    assert child.trace_id == parent.trace_id
    assert child.depth == parent.depth + 1
    assert child.deadline == 90.0          # tightened
    looser = RequestContext.hop(parent, 200.0)
    assert looser.deadline == 100.0        # parent's bound wins
    nodl = RequestContext.hop(parent, None)
    assert nodl.deadline == 100.0
    assert nodl.depth == 1


def test_session_key_is_stable_across_types_and_processes():
    """crc32-based, so the same session id maps to the same shard in every
    process and every run (builtin hash() is per-process randomized)."""
    assert session_key("s1") == session_key("s1")
    assert session_key(b"s1") == session_key("s1")
    assert session_key(None) == 0
    assert session_key(7) == 7
    assert session_key(2**40 + 3) == (2**40 + 3) & 0xFFFFFFFF
    # a concrete pinned value: any drift would silently reshuffle every
    # session->shard mapping and invalidate recorded baselines
    import zlib
    assert session_key("s1") == zlib.crc32(b"s1")
    ctx = RequestContext(session="s1")
    assert ctx.session_shard(4) == session_key("s1") % 4


# -------------------------------------------- end-to-end context threading
def _context_probe_app(backend):
    """root -> mid -> leaf chain; the leaf reports its ambient context."""
    def leaf(svc, payload):
        ctx = yield CurrentContext()
        yield Sleep(0.0005)  # suspend so ctx must survive a park/resume
        ctx2 = yield CurrentContext()
        assert ctx2 is ctx or (ctx is None and ctx2 is None)
        if ctx is None:
            return {"ctx": None}
        return {"ctx": {"depth": ctx.depth, "session": ctx.session,
                        "deadline": ctx.deadline, "trace": ctx.trace_id}}

    def mid(svc, payload):
        f = yield AsyncRpc("leaf", "get", payload)
        return (yield Wait(f))

    def root(svc, payload):
        ctx = yield CurrentContext()
        f = yield AsyncRpc("mid", "get", payload)
        out = yield Wait(f)
        out["root_trace"] = None if ctx is None else ctx.trace_id
        return out

    app = App(backend=backend, net_latency=0.0)
    app.add_service(ServiceSpec("leaf", {"get": leaf}, n_workers=2))
    app.add_service(ServiceSpec("mid", {"get": mid}, n_workers=2))
    app.add_service(ServiceSpec("root", {"get": root}, n_workers=2))
    return app


@pytest.mark.parametrize("backend", BACKEND_NAMES)
def test_context_propagates_depth_session_deadline(backend):
    """A context minted at the edge arrives at the deepest handler with
    depth == hop count, the original session/trace, and the un-loosened
    deadline — identically on all 8 backends (inline fast path included)."""
    with _context_probe_app(backend) as app:
        t_dl = time.monotonic() + 30.0
        ctx = RequestContext(session="sess-42", deadline=t_dl)
        out = app.send("root", "get", {}, ctx=ctx).wait(timeout=10)
        got = out["ctx"]
        assert got["depth"] == 2          # root->mid, mid->leaf
        assert got["session"] == "sess-42"
        assert got["deadline"] == t_dl    # no hop loosened or dropped it
        assert got["trace"] == ctx.trace_id == out["root_trace"]


@pytest.mark.parametrize("backend", BACKEND_NAMES)
def test_plain_send_has_no_ambient_context(backend):
    """Bare send(dest, method, payload): every handler sees ctx None — the
    zero-overhead contract (nothing materializes a carrier)."""
    with _context_probe_app(backend) as app:
        out = app.send("root", "get", {}).wait(timeout=10)
        assert out["ctx"] is None
        assert out["root_trace"] is None


@pytest.mark.parametrize("backend", BACKEND_NAMES)
def test_per_call_deadline_tightens_inherited_context(backend):
    """An AsyncRpc(deadline=...) on an intermediate hop tightens the
    carried bound for its subtree without touching the parent's."""
    def leaf(svc, payload):
        ctx = yield CurrentContext()
        return ctx.deadline
        yield  # pragma: no cover

    def mid(svc, payload):
        f = yield AsyncRpc("leaf", "get", payload,
                           deadline=payload["tight"])
        return (yield Wait(f))

    app = App(backend=backend, net_latency=0.0)
    app.add_service(ServiceSpec("leaf", {"get": leaf}, n_workers=1))
    app.add_service(ServiceSpec("mid", {"get": mid}, n_workers=1))
    with app:
        loose = time.monotonic() + 60.0
        tight = time.monotonic() + 30.0
        ctx = RequestContext(deadline=loose)
        got = app.send("mid", "get", {"tight": tight},
                       ctx=ctx).wait(timeout=10)
        assert got == tight


def test_send_deadline_kwarg_shim_folds_into_context():
    """The legacy deadline kwarg still works and tightens any context."""
    def leaf(svc, payload):
        ctx = yield CurrentContext()
        return {"deadline": ctx.deadline, "session": ctx.session}

    app = App(backend="fiber", net_latency=0.0)
    app.add_service(ServiceSpec("leaf", {"get": leaf}, n_workers=1))
    with app:
        t1, t2 = time.monotonic() + 60.0, time.monotonic() + 30.0
        got = app.send("leaf", "get", None, deadline=t1).wait(timeout=10)
        assert got["deadline"] == t1 and got["session"] is None
        got = app.send("leaf", "get", None,
                       ctx=RequestContext(session="s9", deadline=t1),
                       deadline=t2).wait(timeout=10)
        assert got["deadline"] == t2      # kwarg tightened the context
        assert got["session"] == "s9"     # without dropping identity


# ------------------------------------------------------- session pinning
def _shard_probe_app(n_shards=4):
    def who(svc, payload):
        return threading.current_thread().name
        yield  # pragma: no cover

    app = App(backend="event-loop-shard", net_latency=0.0)
    app.add_service(ServiceSpec("who", {"get": who}, n_workers=n_shards))
    return app


def test_same_session_always_lands_on_same_shard():
    sessions = ["s%d" % i for i in range(32)]
    with _shard_probe_app() as app:
        placement = {}
        for rep in range(3):
            for s in sessions:
                thread = app.send("who", "get", None,
                                  ctx=RequestContext(session=s)
                                  ).wait(timeout=10)
                assert placement.setdefault(s, thread) == thread, \
                    f"session {s} migrated on repeat {rep}"
        # the mapping is the pure function shard_for(session_key(s), n)
        for s, thread in placement.items():
            want = ShardedEventLoopExecutor.shard_for(session_key(s), 4)
            assert thread.endswith(f"shard{want}-loop"), (s, thread)
        assert len(set(placement.values())) > 1, "all sessions herded"


def test_session_pinning_survives_app_restart():
    """Deterministic across App.start() cycles: per-session state cached
    on a shard is still owned by that shard after a restart."""
    app = _shard_probe_app()
    sessions = ["u%d" % i for i in range(16)]

    def snapshot():
        return {s: app.send("who", "get", None,
                            ctx=RequestContext(session=s)).wait(timeout=10)
                for s in sessions}

    with app:
        first = snapshot()
    with app:  # full stop + restart
        second = snapshot()
    assert first == second


def test_anonymous_ticket_placement_resets_on_restart():
    """Sessionless requests fall back to the ticket hash; the ticket resets
    on start(), so the Nth delivery after a restart lands where the Nth
    before it did (the cross-trial determinism bugfix)."""
    app = _shard_probe_app()

    def seq(n=24):
        return [app.send("who", "get", None).wait(timeout=10)
                for _ in range(n)]

    with app:
        first = seq()
    with app:
        second = seq()
    assert first == second
    assert len(set(first)) > 1


def test_shard_by_session_opt_out_uses_ticket_path():
    """app.shard_by_session = False forces ticket placement even for
    sessioned traffic (the benchmark A/B lever) — one hot session then
    spreads over every shard instead of pinning."""
    with _shard_probe_app() as app:
        app.shard_by_session = False
        threads = {app.send("who", "get", None,
                            ctx=RequestContext(session="hot")
                            ).wait(timeout=10)
                   for _ in range(32)}
        assert len(threads) > 1
        app.shard_by_session = True
        threads = {app.send("who", "get", None,
                            ctx=RequestContext(session="hot")
                            ).wait(timeout=10)
                   for _ in range(8)}
        assert len(threads) == 1


# --------------------------------------------------- per-edge resilience
def test_breakers_are_keyed_per_method():
    """A failing method trips only its own (dest, method) edge; a healthy
    method on the SAME destination keeps flowing."""
    def bad(svc, payload):
        raise RuntimeError("always fails")
        yield  # pragma: no cover

    def good(svc, payload):
        return "ok"
        yield  # pragma: no cover

    pol = ResiliencePolicy(deadline=2.0, breakers=True,
                           breaker_min_volume=4, breaker_window=8,
                           breaker_reset=30.0)
    app = App(backend="fiber", net_latency=0.0, resilience=pol)
    app.add_service(ServiceSpec("dual", {"bad": bad, "good": good},
                                n_workers=1))
    with app:
        tripped = False
        for _ in range(30):
            try:
                app.send("dual", "bad").wait(timeout=5.0)
            except CircuitOpenError:
                tripped = True
                break
            except RuntimeError:
                continue
        assert tripped
        assert app._breakers[("dual", "bad")].state == "open"
        # the sibling edge is unaffected: still closed, still serving
        assert app.send("dual", "good").wait(timeout=5.0) == "ok"
        good_br = app._breakers.get(("dual", "good"))
        assert good_br is None or good_br.state == "closed"
        report = app.resilience_by_edge()
        assert report[("dual", "bad")]["opens"] >= 1


# ------------------------------------------------------- cache accounting
def test_cache_accounting_parity_across_backends():
    """The same cached-workload request sequence produces identical
    hit/miss totals on every backend (the counters are app-level, fed by
    the shared cache service, so the executor must not change them)."""
    d = get_app_def("socialnetwork")
    factory = d.make_request_factory("cached")
    rng = np.random.default_rng(21)
    requests = [factory(rng) for _ in range(60)]
    totals = {}
    for backend in BACKEND_NAMES:
        with d.build(backend) as app:
            for req in requests:
                dest, method, payload = req[:3]
                app.send(dest, method, payload,
                         ctx=RequestContext(session=req[3])
                         ).wait(timeout=15)
            totals[backend] = (app.cache_stats.hits, app.cache_stats.misses)
            bs = app.backend_stats()
            assert (bs.cache_hits, bs.cache_misses) == totals[backend]
    assert len(set(totals.values())) == 1, totals
    hits, misses = totals["thread"]
    reads = sum(1 for r in requests if not r[2].get("write"))
    assert hits + misses == reads
    assert hits > 0 and misses > 0


def test_cached_workload_write_path_invalidates():
    """A write to a hot key forces the next read of that key to miss."""
    d = get_app_def("socialnetwork")
    with d.build("fiber") as app:
        def read(key):
            return app.send("frontend", "cached", {"key": key},
                            ctx=RequestContext(session="s0")
                            ).wait(timeout=10)
        assert read(5)["cached"] is False      # cold miss populates
        assert read(5)["cached"] is True       # now hot
        app.send("frontend", "cached", {"key": 5, "write": True},
                 ctx=RequestContext(session="s0")).wait(timeout=10)
        assert read(5)["cached"] is False      # invalidated by the write


# ------------------------------------------------------------ Zipf workload
def test_zipf_factory_distribution_sanity():
    fac = make_zipf_factory(frontend="fe", n_keys=256, alpha=1.1,
                            n_sessions=16, write_frac=0.1)
    rng = np.random.default_rng(3)
    keys = collections.Counter()
    sessions = set()
    writes = 0
    n = 4000
    for _ in range(n):
        dest, method, payload, session = fac(rng)
        assert dest == "fe" and method == "cached"
        assert 0 <= payload["key"] < 256
        assert session == "s%d" % (payload["key"] % 16)
        sessions.add(session)
        keys[payload["key"]] += 1
        if payload.get("write"):
            writes += 1
    # skew: the most popular key far exceeds the uniform share
    assert keys.most_common(1)[0][1] > 5 * (n / 256)
    # ...but the tail is populated too
    assert len(keys) > 64
    assert len(sessions) == 16
    assert 0.05 * n < writes < 0.2 * n


def test_zipf_factory_is_seed_deterministic():
    fac = make_zipf_factory(frontend="fe")
    a = [fac(np.random.default_rng(7)) for _ in range(20)]
    b = [fac(np.random.default_rng(7)) for _ in range(20)]
    assert a == b
