"""Distribution-layer tests: sharding rules, compression, fault tolerance.

Mesh-dependent tests run in a subprocess with 8 forced host devices so the
main test process keeps the real (1-device) topology.
"""
import json
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.distributed.fault_tolerance import HeartbeatMonitor

jax = pytest.importorskip("jax")

# The mesh-building helpers (repro.launch.mesh / repro.distributed.sharding)
# require jax.sharding.AxisType, which this environment's jax predates —
# version drift tracked in CHANGES.md.  Guard the mesh-dependent tests so
# tier-1 stays signal on either jax version.
needs_axistype = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax version drift: jax.sharding.AxisType unavailable "
           "(pre-existing, tracked in CHANGES.md)")


def _run_subprocess(code: str) -> str:
    """Run code with 8 fake devices; return stdout."""
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            + textwrap.dedent(code))
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=600,
                         env={**__import__('os').environ,
                              "PYTHONPATH": "src"})
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@needs_axistype
def test_sharding_rules_divisibility_degrade():
    out = _run_subprocess("""
    import jax, json
    from repro.distributed import use_sharding
    from repro.distributed.sharding import param_shardings
    from repro.models import Model
    from repro.configs import get_config

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    results = {}
    for arch in ("qwen2-0.5b", "olmoe-1b-7b", "grok-1-314b"):
        model = Model(get_config(arch))
        with use_sharding(mesh) as ctx:
            shards = param_shardings(ctx, model.abstract_params())
        if arch == "qwen2-0.5b":
            # merged q dim 896 divisible by 4 -> TP; embed vocab TP
            results["qwen2_wq"] = str(shards["blocks"]["attn"]["wq"].spec)
            results["qwen2_embed"] = str(shards["embed"].spec)
        else:
            # olmoe: 64 experts % 4 == 0 -> expert parallel
            # grok: 8 experts % 4 == 0 too at tp=4; d_ff gets nothing
            results[arch.split("-")[0] + "_wgate"] = \
                str(shards["blocks"]["mlp"]["w_gate"].spec)
    print(json.dumps(results))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert "model" in res["qwen2_wq"]          # TP applied
    assert "data" in res["qwen2_wq"]           # FSDP applied
    assert res["olmoe_wgate"].startswith("PartitionSpec(None, 'model'")
    assert res["grok_wgate"].startswith("PartitionSpec(None, 'model'")


@needs_axistype
def test_grok_expert_fallback_at_tp16():
    """At TP=8 (> n_experts would not divide), grok-1's 8 experts divide 8,
    but with mesh model=3 they cannot -> TP inside experts instead."""
    out = _run_subprocess("""
    import jax, json
    from repro.distributed import use_sharding
    from repro.distributed.sharding import param_shardings
    from repro.models import Model
    from repro.configs import get_config

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    # force non-dividing expert count by lying about experts: use olmoe with
    # 64 -> divides; emulate grok-at-16 with a reduced config instead
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("grok-1-314b").with_(n_experts=6)  # 6 % 4 != 0
    model = Model(cfg)
    with use_sharding(mesh) as ctx:
        shards = param_shardings(ctx, model.abstract_params())
    print(json.dumps({"wgate": str(shards["blocks"]["mlp"]["w_gate"].spec)}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    # experts degraded -> d_ff picks up "model" (TP inside experts)
    assert res["wgate"] == "PartitionSpec(None, None, 'data', 'model')"


@needs_axistype
def test_compressed_cross_pod_reduction():
    out = _run_subprocess("""
    import jax, jax.numpy as jnp, numpy as np, json
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.compression import make_pod_compressed_grad_fn

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)

    def loss(w, batch):
        x, y = batch["x"], batch["y"]
        pred = x @ w
        return jnp.mean((pred - y) ** 2)

    w = jnp.ones((16, 4), jnp.float32)
    x = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16) / 100
    y = jnp.ones((8, 4), jnp.float32)
    grad_fn = make_pod_compressed_grad_fn(loss, mesh)
    with jax.set_mesh(mesh):
        xb = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"))))
        yb = jax.device_put(y, NamedSharding(mesh, P(("pod", "data"))))
        l, g = jax.jit(grad_fn)(w, {"x": xb, "y": yb})
    # reference: plain global gradient
    lr, gr = jax.value_and_grad(loss)(w, {"x": x, "y": y})
    rel = float(np.max(np.abs(np.asarray(g) - np.asarray(gr)))
                / (np.max(np.abs(np.asarray(gr))) + 1e-9))
    print(json.dumps({"rel_err": rel, "loss_match":
                      abs(float(l) - float(lr)) < 1e-5}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["loss_match"]
    assert res["rel_err"] < 0.02       # int8 quantization noise only


@needs_axistype
def test_elastic_reshard_across_meshes():
    out = _run_subprocess("""
    import jax, jax.numpy as jnp, numpy as np, json
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.fault_tolerance import elastic_reshard

    devs = jax.devices()
    mesh8 = jax.make_mesh((8,), ("data",),
                          axis_types=(jax.sharding.AxisType.Auto,))
    mesh4 = jax.sharding.Mesh(np.array(devs[:4]), ("data",))
    x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    x8 = jax.device_put(x, NamedSharding(mesh8, P("data")))
    x4 = elastic_reshard(x8, NamedSharding(mesh4, P("data")))
    ok = bool(np.array_equal(np.asarray(x4), np.asarray(x)))
    n_shards = len(x4.addressable_shards)
    print(json.dumps({"ok": ok, "n_shards": n_shards}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["ok"] and res["n_shards"] == 4


# ------------------------------------------------------- fault tolerance
def test_heartbeat_detects_straggler_and_death():
    mon = HeartbeatMonitor(n_hosts=3, interval=0.05)
    transitions = []
    mon.on_transition(lambda h, old, new: transitions.append((h, old, new)))
    mon.start()
    time.sleep(0.4)
    assert all(s == "alive" for s in mon.statuses().values()), mon.statuses()

    mon.set_behavior(1, "straggler")
    time.sleep(0.8)
    # a straggler's beats are late every cycle: the monitor must have flagged
    # it at least once (status flaps back to alive when the late beat lands)
    assert any(h == 1 and new == "straggler" for h, _, new in transitions), \
        transitions

    mon.set_behavior(2, "dead")
    time.sleep(0.5)
    assert mon.statuses()[2] == "dead"
    assert any(h == 2 and new == "dead" for h, _, new in transitions)
    mon.stop()


def test_supervisor_restores_latest(tmp_path):
    import jax
    import jax.numpy as jnp
    from repro.training import CheckpointManager
    from repro.distributed.fault_tolerance import TrainSupervisor

    mgr = CheckpointManager(str(tmp_path))
    sup = TrainSupervisor(mgr, save_every=2)
    state = {"w": jnp.ones((4,))}
    sup.maybe_save(2, state)
    sup.finalize(3, {"w": jnp.full((4,), 3.0)})
    target = {"w": jax.ShapeDtypeStruct((4,), jnp.float32)}
    step, restored = sup.startup(lambda: state, target)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.full((4,), 3.0))
    mgr.close()
