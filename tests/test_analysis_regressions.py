"""Seeded regression reproducers: prove the sanitizer flags the bugs this
repo has already fixed, by reverting each fix *in memory* and running the
pre-fix ordering under the detector.

* PR 6 — the latency-summary race: ``run_trial`` used to read
  ``LatencyRecorder.summary()`` *before* severing the trial, racing late
  completion callbacks.  Reenacted on a real recorder -> SAN-TRIAL-SUMMARY.
* PR 9 — shutdown-mid-hang: ``App.stop`` settles blackholed replies
  before stopping executors; with that settlement disabled (monkeypatched
  to a no-op) a hung request's waiters are orphaned -> SAN-FUT-LEAK.
* PR 10 satellite — ``App.stop`` used to *drop* pending TimerThread
  entries, orphaning a retry-in-backoff's reply.  The fix
  (``TimerThread.stop(fire_pending=True)``) fires them early so the
  retry observes the stopped app and fails the reply; the reverted drop
  behaviour is flagged as SAN-FUT-LEAK.
"""
import time

import pytest

from repro.analysis.sanitizer import attached
from repro.core import (App, AsyncRpc, Compute, FaultPlan, FaultRule,
                        ResiliencePolicy, RetryPolicy, ServiceSpec, Wait)
from repro.core.metrics import LatencyRecorder


def _rules(findings):
    return {f.rule for f in findings}


# ------------------------------------------------------------------- PR 6
def test_pr6_summary_before_sever_flagged():
    """Pre-fix run_trial ordering on a real recorder: summarize while the
    trial is live, then a late completion records — the summary raced."""
    rec = LatencyRecorder()
    with attached() as san:
        rec.record(0.010)                 # completions during the window
        rec.record(0.012)
        rec.summary()                     # PRE-FIX: read before the sever
        rec.record(0.500)                 # late completion callback lands
        san.trial_sever(rec)              # sever arrives too late
        san.check()
    errs = san.errors()
    assert "SAN-TRIAL-SUMMARY" in _rules(errs)
    assert any("raced" in f.message for f in errs)


def test_pr6_fixed_ordering_clean():
    """The shipped ordering — freeze first, summarize after — is clean."""
    rec = LatencyRecorder()
    with attached() as san:
        rec.record(0.010)
        rec.record(0.012)
        san.trial_sever(rec)              # sever the trial...
        rec.summary()                     # ...then read the frozen recorder
        san.check()
    assert san.errors() == []


def test_write_after_sever_flagged():
    """The other half of the protocol: a write escaping the sever means the
    liveness check failed to freeze the recorder."""
    rec = LatencyRecorder()
    with attached() as san:
        rec.record(0.010)
        san.trial_sever(rec)
        rec.record(0.500)                 # escaped the liveness check
        san.check()
    assert "SAN-TRIAL-SUMMARY" in _rules(san.errors())


# ------------------------------------------------------------------- PR 9
def _hang_app():
    def leaf(svc, payload):
        yield Compute(20e-6)
        return "leaf"

    def root(svc, payload):
        f = yield AsyncRpc("leaf", "get", payload)
        return (yield Wait(f))

    app = App(backend="fiber")
    app.add_service(ServiceSpec("leaf", {"get": leaf}, n_workers=2))
    app.add_service(ServiceSpec("root", {"get": root}, n_workers=2))
    plan = FaultPlan([FaultRule(dest="leaf", kind="hang")])
    app.set_faults(plan)
    return app, plan


def test_pr9_stop_without_settlement_leaks(monkeypatch):
    """Fix reverted in memory: settle_blackholed no-ops, so stopping the
    app mid-hang orphans the cooperative waiter parked on the blackholed
    reply — the sanitizer reports the leaked future."""
    app, plan = _hang_app()
    monkeypatch.setattr(FaultPlan, "settle_blackholed", lambda self: None)
    with attached() as san:
        app.start()
        plan.arm()
        f = app.send("root", "get")       # root parks on the hung leaf
        time.sleep(0.08)
        assert not f.done
        app.stop()                        # pre-fix: waiters stay orphaned
        san.check()
    errs = san.errors()
    assert "SAN-FUT-LEAK" in _rules(errs)
    assert any("blackhole" in f.message for f in errs)
    assert not f.done                     # the reply really was orphaned


def test_pr9_fixed_stop_settles_cleanly():
    """With the shipped fix in place the same scenario leaves nothing
    leaked: stop settles the blackholed reply before executors die."""
    app, plan = _hang_app()
    with attached() as san:
        app.start()
        plan.arm()
        f = app.send("root", "get")
        time.sleep(0.08)
        assert not f.done
        app.stop()
        assert f.wait_done(timeout=5.0)
        san.check()
    assert "SAN-FUT-LEAK" not in _rules(san.errors())


# --------------------------------------------------- PR 10 satellite: stop()
def _retry_app():
    """A leaf that always fails + a retry policy with a backoff far longer
    than the test: any retry is guaranteed to be pending when stop runs."""
    def leaf(svc, payload):
        yield Compute(1e-6)
        raise RuntimeError("leaf down")

    app = App(backend="fiber",
              resilience=ResiliencePolicy(
                  deadline=None, breakers=False,
                  retry=RetryPolicy(max_attempts=3, base_backoff=30.0,
                                    max_backoff=30.0, jitter=0.0)))
    app.add_service(ServiceSpec("leaf", {"get": leaf}, n_workers=1))
    return app


def test_stop_fires_pending_retry_reply():
    """Regression for the shutdown inversion: a retry parked in backoff on
    the kernel TimerThread must resolve its reply at App.stop (the timer
    drain fires pending callbacks early; they observe the stopped app and
    fail fast) instead of being silently dropped."""
    app = _retry_app()
    app.start()
    f = app.send("leaf", "get")
    deadline = time.monotonic() + 5.0
    while not app._res_stats.retries and time.monotonic() < deadline:
        time.sleep(0.005)                 # first attempt failed, backoff armed
    assert app._res_stats.retries == 1
    assert not f.done                     # reply owed by the pending retry
    app.stop()
    assert f.wait_done(timeout=5.0), \
        "pending retry was dropped at stop; reply orphaned"
    assert isinstance(f.exception(), RuntimeError)
    assert "stopped while retrying" in str(f.exception())


def test_stop_dropping_pending_retry_flagged(monkeypatch):
    """Fix reverted in memory: restore the old drop-the-heap stop() and the
    sanitizer sees the orphaned reply (the caller awaited it)."""
    from repro.core.timers import TimerThread
    orig_stop = TimerThread.stop
    monkeypatch.setattr(
        TimerThread, "stop",
        lambda self, fire_pending=False: orig_stop(self, fire_pending=False))
    app = _retry_app()
    with attached() as san:
        app.start()
        f = app.send("leaf", "get")
        deadline = time.monotonic() + 5.0
        while not app._res_stats.retries and time.monotonic() < deadline:
            time.sleep(0.005)
        assert app._res_stats.retries == 1
        san.future_join(f)                # the caller's park on the reply
        app.stop()                        # pre-fix: pending entry dropped
        san.check()
    assert not f.done                     # orphaned for real
    assert "SAN-FUT-LEAK" in _rules(san.errors())
