"""benchmarks/trend.py unit tests — the CI trend gate's comparator.

The acceptance contract: identical artifacts pass, a synthetic 2x-slower
cell fails regardless of how noisy its trials claim to be, dips inside the
paired-trial noise band only warn, and un-diffable baselines (schema drift,
pre-records artifacts) pass vacuously instead of blocking CI.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from benchmarks import trend

REPO = Path(__file__).resolve().parent.parent


def _artifact(values, schema=trend.SCHEMA_VERSION, trials=None):
    """values: {'app/backend': rps}; trials optionally overrides per key."""
    records = []
    for key, v in values.items():
        app, backend = key.split("/")
        records.append({
            "key": key, "app": app, "backend": backend,
            "metric": "achieved_rps", "unit": "rps", "value": v,
            "trials": (trials or {}).get(key, [v, v]), "errors": 0,
        })
    return {
        "schema_version": schema,
        "apps": sorted({k.split("/")[0] for k in values}),
        "records": records,
    }


BASE = {"socialnetwork/thread": 290.0, "socialnetwork/fiber": 290.0,
        "mediaservice/event-loop": 285.0}


def test_identical_artifacts_pass_clean():
    report = trend.compare(_artifact(BASE), _artifact(BASE))
    assert report["comparable"]
    assert report["regressions"] == []
    assert report["warnings"] == []
    assert all(r["status"] == "ok" for r in report["rows"])


def test_synthetic_2x_slower_cell_fails():
    """The acceptance criterion: halving one cell's throughput must gate."""
    cur = dict(BASE)
    cur["socialnetwork/fiber"] = BASE["socialnetwork/fiber"] / 2
    report = trend.compare(_artifact(cur), _artifact(BASE))
    assert len(report["regressions"]) == 1
    assert "socialnetwork/fiber" in report["regressions"][0]
    (row,) = [r for r in report["rows"] if r["key"] == "socialnetwork/fiber"]
    assert row["status"] == "regression"
    assert row["ratio"] == pytest.approx(0.5)


def test_dip_inside_noise_band_only_warns():
    cur = dict(BASE)
    cur["socialnetwork/fiber"] = BASE["socialnetwork/fiber"] * 0.8
    report = trend.compare(_artifact(cur), _artifact(BASE))
    assert report["regressions"] == []
    assert len(report["warnings"]) == 1
    (row,) = [r for r in report["rows"] if r["key"] == "socialnetwork/fiber"]
    assert row["status"] == "warn"


def test_band_widens_with_observed_trial_spread():
    """A cell whose repeated trials disagree by 30% in *both* runs earns a
    wider band (capped), so a 0.55 ratio that would fail a quiet cell passes
    a noisy one as a warning."""
    key = "socialnetwork/thread"
    noisy = {key: [290.0, 203.0]}  # 30% relative spread
    base = _artifact(BASE, trials=noisy)
    cur_vals = dict(BASE)
    cur_vals[key] = BASE[key] * 0.56  # below quiet band, above capped band
    cur = _artifact(cur_vals, trials={key: [cur_vals[key],
                                            cur_vals[key] * 0.7]})
    report = trend.compare(cur, base)
    (row,) = [r for r in report["rows"] if r["key"] == key]
    assert row["band"] == trend.MAX_BAND  # spread sum clipped at the cap
    assert row["status"] == "warn"


def test_cap_means_2x_always_fails_even_with_wild_trials():
    """MAX_BAND < 0.5: no amount of claimed noise lets a halving through."""
    key = "socialnetwork/fiber"
    wild = {key: [290.0, 1.0]}  # ~100% spread in both runs
    cur_vals = dict(BASE)
    cur_vals[key] = BASE[key] / 2
    report = trend.compare(_artifact(cur_vals, trials=wild),
                           _artifact(BASE, trials=wild))
    assert len(report["regressions"]) == 1


def test_improvements_never_flag():
    cur = {k: v * 3 for k, v in BASE.items()}
    report = trend.compare(_artifact(cur), _artifact(BASE))
    assert report["regressions"] == [] and report["warnings"] == []


def test_new_cell_is_informational():
    cur = dict(BASE)
    cur["socialnetwork/fiber-batch"] = 300.0
    report = trend.compare(_artifact(cur), _artifact(BASE))
    assert report["regressions"] == []
    (row,) = [r for r in report["rows"]
              if r["key"] == "socialnetwork/fiber-batch"]
    assert row["status"] == "new"


def test_cell_missing_from_current_warns():
    cur = dict(BASE)
    cur.pop("socialnetwork/fiber")
    report = trend.compare(_artifact(cur), _artifact(BASE))
    assert report["regressions"] == []
    assert any("missing from current" in w for w in report["warnings"])


def test_legacy_baseline_passes_vacuously():
    """First run after a schema bump: the previous artifact cannot be
    compared, and the gate must not block CI for that."""
    legacy = {"backends": [], "cells": {}}  # pre-records artifact
    report = trend.compare(_artifact(BASE), legacy)
    assert not report["comparable"]
    assert report["regressions"] == []
    assert any("not comparable" in n for n in report["notes"])


def test_malformed_current_is_a_usage_error():
    with pytest.raises(trend.TrendError):
        trend.compare({"schema_version": 1}, _artifact(BASE))


def test_rel_spread_and_band_edges():
    assert trend.rel_spread(None) == 0.0
    assert trend.rel_spread([100.0]) == 0.0
    assert trend.rel_spread([100.0, 50.0]) == pytest.approx(0.5)
    assert trend.rel_spread([0.0, 0.0]) == 0.0  # degenerate, not a crash
    quiet = {"trials": [100.0, 100.0]}
    assert trend.noise_band(quiet, quiet) == trend.NOISE_FLOOR


def test_render_markdown_mentions_every_cell_and_verdict():
    cur = dict(BASE)
    cur["socialnetwork/fiber"] = BASE["socialnetwork/fiber"] / 2
    report = trend.compare(_artifact(cur), _artifact(BASE))
    md = trend.render_markdown(report)
    for key in cur:
        assert key in md
    assert "regression" in md
    assert "| cell |" in md


def test_cli_end_to_end(tmp_path):
    """The exact invocation CI makes, against real files, both verdicts."""
    cur_ok = tmp_path / "cur.json"
    base = tmp_path / "base.json"
    cur_bad = tmp_path / "bad.json"
    md = tmp_path / "trend.md"
    base.write_text(json.dumps(_artifact(BASE)))
    cur_ok.write_text(json.dumps(_artifact(BASE)))
    slow = dict(BASE)
    slow["socialnetwork/fiber"] = BASE["socialnetwork/fiber"] / 2
    cur_bad.write_text(json.dumps(_artifact(slow)))

    script = str(REPO / "benchmarks" / "trend.py")
    ok = subprocess.run([sys.executable, script, str(cur_ok), str(base),
                         "--md", str(md)], capture_output=True, text=True)
    assert ok.returncode == 0, ok.stderr
    assert md.exists() and "No regressions" in md.read_text()

    bad = subprocess.run([sys.executable, script, str(cur_bad), str(base)],
                         capture_output=True, text=True)
    assert bad.returncode == 1
    assert "REGRESSION" in bad.stderr


def test_cli_multi_baseline_gates_on_worst(tmp_path):
    """CI passes the previous run AND the committed baseline: a current run
    that matches a freshly ratcheted-down previous run must still fail
    against the stricter committed baseline (and a duplicated path — the
    fallback case — is deduped, not double-reported)."""
    committed = tmp_path / "committed.json"
    prev = tmp_path / "prev.json"
    cur = tmp_path / "cur.json"
    md = tmp_path / "trend.md"
    committed.write_text(json.dumps(_artifact(BASE)))
    ratcheted = {k: v / 2 for k, v in BASE.items()}  # drifted down over runs
    prev.write_text(json.dumps(_artifact(ratcheted)))
    cur.write_text(json.dumps(_artifact(ratcheted)))  # flat vs prev

    script = str(REPO / "benchmarks" / "trend.py")
    out = subprocess.run([sys.executable, script, str(cur), str(prev),
                          str(committed), "--md", str(md)],
                         capture_output=True, text=True)
    assert out.returncode == 1  # prev-run diff is clean; committed catches it
    assert "committed.json" in out.stderr

    # duplicated baseline path (prev-run lookup fell back to committed)
    dup = subprocess.run([sys.executable, script, str(cur), str(prev),
                          str(prev)], capture_output=True, text=True)
    assert dup.returncode == 0
    assert dup.stdout.count("cells compared") == 1


def test_update_baseline_rejects_partial_app_matrix():
    """run.py must refuse to rewrite the committed baseline from an --app
    subset: the omitted apps' cells would lose their baseline records and
    silently stop gating."""
    from benchmarks import run as bench_run
    with pytest.raises(SystemExit) as exc:
        bench_run.main(["--smoke", "--app", "socialnetwork",
                        "--update-baseline"])
    assert exc.value.code == 2  # argparse usage error, nothing ran


def test_committed_baseline_is_current_schema():
    """The fallback artifact CI ships with must itself be diffable."""
    path = REPO / "launch_results" / "baseline_smoke.json"
    baseline = json.loads(path.read_text())
    assert baseline["schema_version"] == trend.SCHEMA_VERSION
    assert baseline["records"], "committed baseline has no records"
    keys = {r["key"] for r in baseline["records"]}
    # full matrix: every registered app x backend cell
    from repro.apps import APP_NAMES, BENCH_BACKENDS
    assert keys == {f"{a}/{b}" for a in APP_NAMES for b in BENCH_BACKENDS}
    # self-diff passes trivially
    report = trend.compare(baseline, baseline)
    assert report["regressions"] == []
