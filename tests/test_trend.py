"""benchmarks/trend.py unit tests — the CI trend gate's comparator.

The acceptance contract: identical artifacts pass, a synthetic 2x-slower
cell fails regardless of how noisy its trials claim to be, dips inside the
paired-trial noise band only warn, and un-diffable baselines (schema drift,
pre-records artifacts) pass vacuously instead of blocking CI.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from benchmarks import trend

REPO = Path(__file__).resolve().parent.parent


def _artifact(values, schema=trend.SCHEMA_VERSION, trials=None):
    """values: {'app/backend': rps}; trials optionally overrides per key."""
    records = []
    for key, v in values.items():
        app, backend = key.split("/")
        records.append({
            "key": key, "app": app, "backend": backend,
            "metric": "achieved_rps", "unit": "rps", "value": v,
            "trials": (trials or {}).get(key, [v, v]), "errors": 0,
        })
    return {
        "schema_version": schema,
        "apps": sorted({k.split("/")[0] for k in values}),
        "records": records,
    }


BASE = {"socialnetwork/thread": 290.0, "socialnetwork/fiber": 290.0,
        "mediaservice/event-loop": 285.0}


def test_identical_artifacts_pass_clean():
    report = trend.compare(_artifact(BASE), _artifact(BASE))
    assert report["comparable"]
    assert report["regressions"] == []
    assert report["warnings"] == []
    assert all(r["status"] == "ok" for r in report["rows"])


def test_synthetic_2x_slower_cell_fails():
    """The acceptance criterion: halving one cell's throughput must gate."""
    cur = dict(BASE)
    cur["socialnetwork/fiber"] = BASE["socialnetwork/fiber"] / 2
    report = trend.compare(_artifact(cur), _artifact(BASE))
    assert len(report["regressions"]) == 1
    assert "socialnetwork/fiber" in report["regressions"][0]
    (row,) = [r for r in report["rows"] if r["key"] == "socialnetwork/fiber"]
    assert row["status"] == "regression"
    assert row["ratio"] == pytest.approx(0.5)


def test_dip_inside_noise_band_only_warns():
    cur = dict(BASE)
    cur["socialnetwork/fiber"] = BASE["socialnetwork/fiber"] * 0.8
    report = trend.compare(_artifact(cur), _artifact(BASE))
    assert report["regressions"] == []
    assert len(report["warnings"]) == 1
    (row,) = [r for r in report["rows"] if r["key"] == "socialnetwork/fiber"]
    assert row["status"] == "warn"


def test_band_widens_with_observed_trial_spread():
    """A cell whose repeated trials disagree by 30% in *both* runs earns a
    wider band (capped), so a 0.55 ratio that would fail a quiet cell passes
    a noisy one as a warning."""
    key = "socialnetwork/thread"
    noisy = {key: [290.0, 203.0]}  # 30% relative spread
    base = _artifact(BASE, trials=noisy)
    cur_vals = dict(BASE)
    cur_vals[key] = BASE[key] * 0.56  # below quiet band, above capped band
    cur = _artifact(cur_vals, trials={key: [cur_vals[key],
                                            cur_vals[key] * 0.7]})
    report = trend.compare(cur, base)
    (row,) = [r for r in report["rows"] if r["key"] == key]
    assert row["band"] == trend.MAX_BAND  # spread sum clipped at the cap
    assert row["status"] == "warn"


def test_cap_means_2x_always_fails_even_with_wild_trials():
    """MAX_BAND < 0.5: no amount of claimed noise lets a halving through."""
    key = "socialnetwork/fiber"
    wild = {key: [290.0, 1.0]}  # ~100% spread in both runs
    cur_vals = dict(BASE)
    cur_vals[key] = BASE[key] / 2
    report = trend.compare(_artifact(cur_vals, trials=wild),
                           _artifact(BASE, trials=wild))
    assert len(report["regressions"]) == 1


def test_improvements_never_flag():
    cur = {k: v * 3 for k, v in BASE.items()}
    report = trend.compare(_artifact(cur), _artifact(BASE))
    assert report["regressions"] == [] and report["warnings"] == []


def test_new_cell_is_informational():
    cur = dict(BASE)
    cur["socialnetwork/fiber-batch"] = 300.0
    report = trend.compare(_artifact(cur), _artifact(BASE))
    assert report["regressions"] == []
    (row,) = [r for r in report["rows"]
              if r["key"] == "socialnetwork/fiber-batch"]
    assert row["status"] == "new"


def test_cell_missing_from_current_warns():
    cur = dict(BASE)
    cur.pop("socialnetwork/fiber")
    report = trend.compare(_artifact(cur), _artifact(BASE))
    assert report["regressions"] == []
    assert any("missing from current" in w for w in report["warnings"])


def test_legacy_baseline_passes_vacuously():
    """First run after a schema bump: the previous artifact cannot be
    compared, and the gate must not block CI for that."""
    legacy = {"backends": [], "cells": {}}  # pre-records artifact
    report = trend.compare(_artifact(BASE), legacy)
    assert not report["comparable"]
    assert report["regressions"] == []
    assert any("not comparable" in n for n in report["notes"])


def test_malformed_current_is_a_usage_error():
    with pytest.raises(trend.TrendError):
        trend.compare({"schema_version": 1}, _artifact(BASE))


def test_rel_spread_and_band_edges():
    assert trend.rel_spread(None) == 0.0
    assert trend.rel_spread([100.0]) == 0.0
    assert trend.rel_spread([100.0, 50.0]) == pytest.approx(0.5)
    assert trend.rel_spread([0.0, 0.0]) == 0.0  # degenerate, not a crash
    quiet = {"trials": [100.0, 100.0]}
    assert trend.noise_band(quiet, quiet) == trend.NOISE_FLOOR


def test_render_markdown_mentions_every_cell_and_verdict():
    cur = dict(BASE)
    cur["socialnetwork/fiber"] = BASE["socialnetwork/fiber"] / 2
    report = trend.compare(_artifact(cur), _artifact(BASE))
    md = trend.render_markdown(report)
    for key in cur:
        assert key in md
    assert "regression" in md
    assert "| cell |" in md


def test_cli_end_to_end(tmp_path):
    """The exact invocation CI makes, against real files, both verdicts."""
    cur_ok = tmp_path / "cur.json"
    base = tmp_path / "base.json"
    cur_bad = tmp_path / "bad.json"
    md = tmp_path / "trend.md"
    base.write_text(json.dumps(_artifact(BASE)))
    cur_ok.write_text(json.dumps(_artifact(BASE)))
    slow = dict(BASE)
    slow["socialnetwork/fiber"] = BASE["socialnetwork/fiber"] / 2
    cur_bad.write_text(json.dumps(_artifact(slow)))

    script = str(REPO / "benchmarks" / "trend.py")
    ok = subprocess.run([sys.executable, script, str(cur_ok), str(base),
                         "--md", str(md)], capture_output=True, text=True)
    assert ok.returncode == 0, ok.stderr
    assert md.exists() and "No regressions" in md.read_text()

    bad = subprocess.run([sys.executable, script, str(cur_bad), str(base)],
                         capture_output=True, text=True)
    assert bad.returncode == 1
    assert "REGRESSION" in bad.stderr


def test_cli_multi_baseline_gates_on_worst(tmp_path):
    """CI passes the previous run AND the committed baseline: a current run
    that matches a freshly ratcheted-down previous run must still fail
    against the stricter committed baseline (and a duplicated path — the
    fallback case — is deduped, not double-reported)."""
    committed = tmp_path / "committed.json"
    prev = tmp_path / "prev.json"
    cur = tmp_path / "cur.json"
    md = tmp_path / "trend.md"
    committed.write_text(json.dumps(_artifact(BASE)))
    ratcheted = {k: v / 2 for k, v in BASE.items()}  # drifted down over runs
    prev.write_text(json.dumps(_artifact(ratcheted)))
    cur.write_text(json.dumps(_artifact(ratcheted)))  # flat vs prev

    script = str(REPO / "benchmarks" / "trend.py")
    out = subprocess.run([sys.executable, script, str(cur), str(prev),
                          str(committed), "--md", str(md)],
                         capture_output=True, text=True)
    assert out.returncode == 1  # prev-run diff is clean; committed catches it
    assert "committed.json" in out.stderr

    # duplicated baseline path (prev-run lookup fell back to committed)
    dup = subprocess.run([sys.executable, script, str(cur), str(prev),
                          str(prev)], capture_output=True, text=True)
    assert dup.returncode == 0
    assert dup.stdout.count("cells compared") == 1


def test_update_baseline_rejects_partial_app_matrix():
    """run.py must refuse to rewrite the committed baseline from an --app
    subset: the omitted apps' cells would lose their baseline records and
    silently stop gating."""
    from benchmarks import run as bench_run
    with pytest.raises(SystemExit) as exc:
        bench_run.main(["--smoke", "--app", "socialnetwork",
                        "--update-baseline"])
    assert exc.value.code == 2  # argparse usage error, nothing ran


def test_committed_baseline_is_current_schema():
    """The fallback artifact CI ships with must itself be diffable."""
    path = REPO / "launch_results" / "baseline_smoke.json"
    baseline = json.loads(path.read_text())
    assert baseline["schema_version"] == trend.SCHEMA_VERSION
    assert baseline["records"], "committed baseline has no records"
    keys = {r["key"] for r in baseline["records"]}
    # full matrix: every registered app x backend cell contributes an rps
    # AND a p99 record plus a cached-workload hit-rate gauge, the rpc-path
    # micro one record per backend (plus a +resilient row per inline
    # backend), the overload probe its two paired goodput cells, the knee
    # probe its knee-multiple cell, the pinning probe its two paired
    # placement-policy peaks in both warm and cold-start modes, and the
    # sick-dependency faults probe its hard-gated breaker win plus the two
    # goodput context records behind it, and the instrumentation-seam
    # probe its warn-only +hooks toll cell per probed backend
    from benchmarks.bench_rpc_path import (HOOK_PROBE_BACKENDS,
                                           INLINE_BACKENDS)
    from benchmarks.bench_smoke import (FAULTS_PROBE_APP,
                                        FAULTS_PROBE_BACKEND,
                                        OVERLOAD_PROBE_APP,
                                        OVERLOAD_PROBE_BACKEND,
                                        PINNING_PROBE_APP,
                                        PINNING_PROBE_BACKEND)
    from repro.apps import APP_NAMES, BENCH_BACKENDS
    expected = {f"{a}/{b}" for a in APP_NAMES for b in BENCH_BACKENDS}
    expected |= {f"{a}/{b}/p99" for a in APP_NAMES for b in BENCH_BACKENDS}
    expected |= {f"{a}/{b}/cached/hit_rate"
                 for a in APP_NAMES for b in BENCH_BACKENDS}
    expected |= {f"rpc_path/{b}" for b in BENCH_BACKENDS}
    expected |= {f"rpc_path/{b}+resilient" for b in INLINE_BACKENDS}
    expected |= {f"rpc_path/{b}+hooks" for b in HOOK_PROBE_BACKENDS}
    expected |= {
        f"overload/{OVERLOAD_PROBE_APP}/{OVERLOAD_PROBE_BACKEND}/{label}"
        for label in ("breakers-off", "breakers-on", "knee")}
    expected |= {
        f"pinning/{PINNING_PROBE_APP}/{PINNING_PROBE_BACKEND}/{label}{mode}"
        for label in ("by-ticket", "by-session")
        for mode in ("", "/cold")}
    expected |= {
        f"faults/{FAULTS_PROBE_APP}/{FAULTS_PROBE_BACKEND}/{label}"
        for label in ("breaker_win", "goodput_on", "goodput_off")}
    assert keys == expected
    # self-diff passes trivially
    report = trend.compare(baseline, baseline)
    assert report["regressions"] == []


# ----------------------------------------------- lower-is-better direction
def _latency_artifact(values, trials=None, gate=None):
    """values: {'app/backend/p99': ms} — direction-lower records."""
    records = []
    for key, v in values.items():
        app, backend = key.split("/")[:2]
        rec = {
            "key": key, "app": app, "backend": backend,
            "metric": "p99_ms", "unit": "ms", "direction": "lower",
            "value": v, "trials": (trials or {}).get(key, [v, v]),
            "errors": 0,
        }
        if gate is not None:
            rec["gate"] = gate
        records.append(rec)
    return {"schema_version": trend.SCHEMA_VERSION,
            "apps": sorted({k.split("/")[0] for k in values}),
            "records": records}


P99_BASE = {"socialnetwork/fiber/p99": 2.0, "mediaservice/thread/p99": 4.0}


def test_lower_direction_regression_is_an_increase():
    """A p99 that *rises* past (1 + band) x baseline must gate; halving a
    latency (which would fail a higher-better cell) must pass clean."""
    cur = dict(P99_BASE)
    cur["socialnetwork/fiber/p99"] = P99_BASE["socialnetwork/fiber/p99"] * 2.5
    report = trend.compare(_latency_artifact(cur), _latency_artifact(P99_BASE))
    assert len(report["regressions"]) == 1
    assert "socialnetwork/fiber/p99" in report["regressions"][0]

    improved = {k: v / 2 for k, v in P99_BASE.items()}
    report = trend.compare(_latency_artifact(improved),
                           _latency_artifact(P99_BASE))
    assert report["regressions"] == [] and report["warnings"] == []


def test_lower_direction_dip_inside_band_warns():
    cur = dict(P99_BASE)
    cur["socialnetwork/fiber/p99"] = P99_BASE["socialnetwork/fiber/p99"] * 1.3
    report = trend.compare(_latency_artifact(cur), _latency_artifact(P99_BASE))
    assert report["regressions"] == []
    assert len(report["warnings"]) == 1


def test_lower_direction_cap_means_worse_than_2x_always_fails():
    key = "socialnetwork/fiber/p99"
    wild = {key: [2.0, 40.0]}  # 95% claimed spread in both runs
    cur = dict(P99_BASE)
    cur[key] = P99_BASE[key] * 2.01  # just past 1 + LOWER_MAX_BAND
    report = trend.compare(_latency_artifact(cur, trials=wild),
                           _latency_artifact(P99_BASE, trials=wild))
    assert len(report["regressions"]) == 1


def test_mixed_direction_artifact_gates_each_cell_its_own_way():
    """One artifact carrying rps (higher) and p99 (lower) records: an rps
    halving and a p99 tripling must both regress, independently."""
    def mixed(rps, p99):
        art = _artifact({"socialnetwork/fiber": rps})
        art["records"] += _latency_artifact(
            {"socialnetwork/fiber/p99": p99})["records"]
        return art
    report = trend.compare(mixed(145.0, 6.0), mixed(290.0, 2.0))
    assert len(report["regressions"]) == 2
    directions = {r["key"]: r.get("direction") for r in report["rows"]}
    assert directions["socialnetwork/fiber"] == "higher"
    assert directions["socialnetwork/fiber/p99"] == "lower"


def test_warn_only_cells_surface_loudly_but_never_fail():
    """Smoke p99 records carry gate: warn-only — a 5x out-of-band move
    must show up as a warning, not a regression (smoke-scale tails cannot
    support a hard gate)."""
    cur = dict(P99_BASE)
    cur["socialnetwork/fiber/p99"] = P99_BASE["socialnetwork/fiber/p99"] * 5
    report = trend.compare(_latency_artifact(cur, gate="warn-only"),
                           _latency_artifact(P99_BASE, gate="warn-only"))
    assert report["regressions"] == []
    assert any("warn-only" in w for w in report["warnings"])
    (row,) = [r for r in report["rows"]
              if r["key"] == "socialnetwork/fiber/p99"]
    assert row["status"] == "warn"


def test_overload_cells_get_wide_band_and_warn_only():
    """Goodput-past-peak cells: noise "overload" widens the band (a 0.45x
    drop that would fail an ordinary rps cell stays a warning), and the
    warn-only tag keeps even a collapse beyond the 0.90 cap from failing
    the run — bimodal breaker-trip behavior cannot support a hard gate."""
    def overload_art(value, gate=None):
        rec = {"key": "overload/socialnetwork/fiber/breakers-on",
               "app": "socialnetwork", "backend": "fiber",
               "metric": "goodput_rps", "unit": "rps",
               "direction": "higher", "noise": "overload",
               "value": value, "errors": 0}
        if gate:
            rec["gate"] = gate
        return {"schema_version": trend.SCHEMA_VERSION,
                "apps": ["socialnetwork"], "records": [rec]}

    # 0.55 ratio: outside the plain 0.35 floor, inside the overload 0.50
    report = trend.compare(overload_art(550.0), overload_art(1000.0))
    assert report["regressions"] == []
    assert len(report["warnings"]) == 1
    # 0.05 ratio: beyond even the 0.90 cap — warn-only still never fails
    report = trend.compare(overload_art(50.0, gate="warn-only"),
                           overload_art(1000.0, gate="warn-only"))
    assert report["regressions"] == []
    assert any("warn-only" in w for w in report["warnings"])
    # untagged collapse beyond the cap does fail (the band has a floor)
    report = trend.compare(overload_art(50.0), overload_art(1000.0))
    assert len(report["regressions"]) == 1


def test_smoke_overload_records_are_warn_only():
    """The committed baseline's overload cells must carry the warn-only
    tag bench_smoke writes."""
    path = REPO / "launch_results" / "baseline_smoke.json"
    records = json.loads(path.read_text())["records"]
    overload = [r for r in records if r["key"].startswith("overload/")]
    assert len(overload) == 3  # breakers-off, breakers-on, knee
    for r in overload:
        assert r.get("gate") == "warn-only", r["key"]
        assert r.get("noise") == "overload", r["key"]


def test_smoke_p99_records_are_warn_only_and_rpc_records_micro():
    """The artifact bench_smoke writes must tag its p99 cells warn-only and
    its rpc micro cells noise=micro — the committed baseline proves it."""
    path = REPO / "launch_results" / "baseline_smoke.json"
    records = json.loads(path.read_text())["records"]
    for r in records:
        if r["key"].endswith("/p99"):
            assert r.get("gate") == "warn-only", r["key"]
        elif r["key"].startswith("rpc_path/"):
            assert r.get("noise") == "micro", r["key"]
        else:
            assert r.get("direction") == "higher", r["key"]


def test_ns_micro_cells_get_the_machine_absolute_clamps():
    """rpc_path ns/call records: 2x slower (different hardware) passes,
    beyond 2.5x (the fast path actually lost) fails."""
    def micro(v):
        return {"schema_version": trend.SCHEMA_VERSION, "apps": [],
                "records": [{"key": "rpc_path/fiber", "app": "_rpc_path",
                             "backend": "fiber", "metric": "ns_per_call",
                             "unit": "ns", "direction": "lower",
                             "value": v, "trials": [v, v], "errors": 0}]}
    slow_hw = trend.compare(micro(9000.0), micro(4500.0))  # 2.0x
    assert slow_hw["regressions"] == []
    lost = trend.compare(micro(12000.0), micro(4500.0))    # 2.7x
    assert len(lost["regressions"]) == 1


# ------------------------------------------------------- full-bench CSV mode
CSV_ROWS = """name,us_per_call,derived
spawn_overhead/thread,250.00,req_us=2000.0
spawn_overhead/thread_over_fiber,12.50,x
rpc_path/fiber,5.20,ns=5200 inline=1472 spawns=0
rpc_path/fiber_fastpath_speedup,45.38,x_vs_noinline
peak_throughput/socialnetwork/mixed/fiber,450.00,rps=2222
peak_throughput/socialnetwork/mixed/fiber_gain,1.60,x
p99_latency/socialnetwork/mixed/fiber@500rps,3500.0,p50_us=900.0
p99_latency/ERROR,0,failed
# p99_latency took 12.0s
"""


def test_artifact_from_csv_ingests_measurements_not_ratios(tmp_path):
    p = tmp_path / "bench.csv"
    p.write_text(CSV_ROWS)
    art = trend.artifact_from_csv(str(p))
    recs = {r["key"]: r for r in art["records"]}
    assert set(recs) == {"spawn_overhead/thread", "rpc_path/fiber",
                        "peak_throughput/socialnetwork/mixed/fiber",
                        "p99_latency/socialnetwork/mixed/fiber@500rps"}
    assert all(r["direction"] == "lower" for r in art["records"])
    assert art["schema_version"] == trend.SCHEMA_VERSION
    # machine-absolute micro rows get the wide clamps; app-parameterized
    # rows keep the p99-style clamps and a real app segment
    assert recs["rpc_path/fiber"]["noise"] == "micro"
    assert recs["spawn_overhead/thread"]["noise"] == "micro"
    assert "noise" not in recs["p99_latency/socialnetwork/mixed/fiber@500rps"]
    assert recs["p99_latency/socialnetwork/mixed/fiber@500rps"]["app"] \
        == "socialnetwork"
    assert recs["rpc_path/fiber"]["app"] == "_rpc_path"
    # apps populated from the rows -> missing-cell warnings can fire
    assert "socialnetwork" in art["apps"] and "_rpc_path" in art["apps"]


def test_csv_mode_warns_on_cell_lost_from_current_run(tmp_path):
    """A bench that errors out of the current CSV (its row skipped) must
    produce a missing-cell warning, not silently drop out of the gate."""
    base = tmp_path / "base.csv"
    cur = tmp_path / "cur.csv"
    base.write_text(CSV_ROWS)
    cur.write_text(CSV_ROWS.replace(
        "p99_latency/socialnetwork/mixed/fiber@500rps,3500.0,p50_us=900.0",
        "p99_latency/ERROR,0,failed"))
    report = trend.compare(trend.artifact_from_csv(str(cur)),
                           trend.artifact_from_csv(str(base)))
    assert any("missing from current" in w and "p99_latency" in w
               for w in report["warnings"])


def test_csv_mode_cli_gates_p99_cells(tmp_path):
    """--from-csv: a 3x slower p99 cell in the current full-bench CSV fails
    against the baseline CSV; an identical CSV passes."""
    base = tmp_path / "base.csv"
    same = tmp_path / "same.csv"
    worse = tmp_path / "worse.csv"
    base.write_text(CSV_ROWS)
    same.write_text(CSV_ROWS)
    worse.write_text(CSV_ROWS.replace(
        "p99_latency/socialnetwork/mixed/fiber@500rps,3500.0",
        "p99_latency/socialnetwork/mixed/fiber@500rps,10500.0"))

    script = str(REPO / "benchmarks" / "trend.py")
    ok = subprocess.run([sys.executable, script, "--from-csv",
                         str(same), str(base)],
                        capture_output=True, text=True)
    assert ok.returncode == 0, ok.stderr
    bad = subprocess.run([sys.executable, script, "--from-csv",
                          str(worse), str(base)],
                         capture_output=True, text=True)
    assert bad.returncode == 1
    assert "p99_latency/socialnetwork/mixed/fiber@500rps" in bad.stderr
