"""CompletionRing + CQBatchFiberScheduler (fiber-batch-cq) tests.

The completion ring is the reply-side mirror of the submission ring: reply
resolutions fired on callee threads append resumptions to the caller
scheduler's ring instead of each paying an injected wakeup, and the ring
drains as one batch on size / timeout / idle.  These tests pin the ring
contract at the unit level (deterministic, no threads), then each flush
trigger and the exception path end-to-end.
"""
import threading

import pytest

from repro.core import (App, AsyncRpc, Future, ServiceSpec, Sleep, Wait,
                        WaitAll)
from repro.core.executor import FiberExecutor
from repro.core.fiber import _CQ_FLUSH, CompletionRing, CQBatchFiberScheduler, Fiber


# ------------------------------------------------------------ ring contract
def test_ring_append_reports_first_and_size():
    ring = CompletionRing(size=3)
    f = [Fiber(iter(())) for _ in range(4)]
    assert ring.append(f[0], 0) == (None, True)    # empty -> non-empty
    assert ring.append(f[1], 1) == (None, False)
    batch, first = ring.append(f[2], 2)            # fills to size
    assert not first
    assert batch == [(f[0], 0), (f[1], 1), (f[2], 2)]
    assert not ring                                # emptied by the flush
    assert ring.flushes_size == 1
    assert ring.completions_batched == 3
    assert ring.hwm == 3
    # the next append starts a fresh ring (and a fresh generation)
    gen_after_size = ring.gen
    assert ring.append(f[3], 3) == (None, True)
    assert ring.gen == gen_after_size


def test_ring_drain_counts_reason_and_bumps_generation():
    ring = CompletionRing(size=100)
    fib = Fiber(iter(()))
    assert ring.drain("idle") == []                # empty drain is a no-op
    assert ring.flushes_idle == 0 and ring.gen == 0
    ring.append(fib, "a")
    g = ring.gen
    assert ring.drain("timeout") == [(fib, "a")]
    assert ring.flushes_timeout == 1
    assert ring.gen == g + 1
    ring.append(fib, "b")
    assert ring.drain("idle") == [(fib, "b")]
    assert ring.flushes_idle == 1
    assert ring.completions_batched == 2


def test_scheduler_size_flush_injects_one_batch():
    """cq_size completions arriving from resolver threads must cross into
    the scheduler as ONE injected batch (scheduler not started: the inject
    queue is inspected directly)."""
    s = CQBatchFiberScheduler(app=None, name="unit", cq_size=3,
                              cq_flush_after=60.0)
    fibs = [Fiber(iter(())) for _ in range(3)]
    for i, fib in enumerate(fibs):
        s._complete(fib, i)
    assert list(s._injected) == [(fibs[0], 0), (fibs[1], 1), (fibs[2], 2)]
    assert s.cq_flushes_size == 1
    assert s.completions_batched == 3
    assert s.cq_hwm == 3
    assert not s._cq


def test_scheduler_timeout_drain_and_stale_generation():
    """A drain deadline armed for ring generation N must be a no-op once N
    has already flushed — otherwise every generation's leftover timer
    prematurely drains its successor (the same guard the submission ring's
    _FLUSH timers carry).  Scheduler not started: timers driven directly."""
    s = CQBatchFiberScheduler(app=None, name="gen", cq_size=100,
                              cq_flush_after=60.0)
    fib = Fiber(iter(()))
    s._complete(fib, 1)
    s._arm_completion_timer()
    assert s._cq_armed
    armed_gen = s._cq.gen
    s._on_timer((_CQ_FLUSH, armed_gen))            # due: drains to ready
    assert list(s._ready) == [(fib, 1)]
    assert s.cq_flushes_timeout == 1
    assert not s._cq_armed
    s._ready.clear()
    s._complete(fib, 2)                            # next generation's ring
    s._on_timer((_CQ_FLUSH, armed_gen))            # stale deadline: no-op
    assert len(s._cq) == 1
    assert s.cq_flushes_timeout == 1
    s._arm_completion_timer()
    s._on_timer((_CQ_FLUSH, s._cq.gen))            # its own deadline drains
    assert s.cq_flushes_timeout == 2
    assert list(s._ready) == [(fib, 2)]


def test_arm_is_idempotent_and_skips_empty_ring():
    s = CQBatchFiberScheduler(app=None, name="arm", cq_size=100,
                              cq_flush_after=60.0)
    s._arm_completion_timer()
    assert len(s._timers) == 0                     # nothing pending: no timer
    s._complete(Fiber(iter(())), 1)
    s._arm_completion_timer()
    s._arm_completion_timer()
    assert len(s._timers) == 1                     # armed exactly once


# --------------------------------------------------------- live flush paths
def test_idle_flush_resumes_parked_fiber():
    """An idle scheduler drains a freshly appended completion immediately
    (the single arming wakeup) instead of waiting out the flush deadline."""
    s = CQBatchFiberScheduler(app=None, name="idle", cq_size=100,
                              cq_flush_after=60.0)  # timeout can't be the one
    gate = Future()
    parked = threading.Event()

    def waiter():
        parked.set()
        v = yield Wait(gate)
        return v + 1

    s.start()
    try:
        fut = s.spawn_external(waiter())
        assert parked.wait(timeout=5)
        gate.set_result(41)                        # resolver: this thread
        assert fut.wait(timeout=5) == 42
    finally:
        s.stop()
    # two ring crossings: the spawn_external delivery (the ring is the
    # scheduler's only cross-thread doorbell) and the gate resumption
    assert s.completions_batched == 2
    assert s.cq_flushes_idle == 2
    assert s.cq_flushes_timeout == 0


def test_timeout_flush_fires_while_scheduler_stays_busy():
    """With the ready deque never emptying (two Sleep(0) spinners), pending
    completions can only leave the ring via the TimerWheel deadline."""
    s = CQBatchFiberScheduler(app=None, name="busy", cq_size=100,
                              cq_flush_after=0.002)
    stop_spinning = threading.Event()
    gate = Future()
    parked = threading.Event()

    def spinner():
        while not stop_spinning.is_set():
            yield Sleep(0)

    def waiter():
        parked.set()
        v = yield Wait(gate)
        return v * 2

    s.start()
    try:
        for _ in range(2):
            s.spawn_external(spinner())
        fut = s.spawn_external(waiter())
        assert parked.wait(timeout=5)
        gate.set_result(21)
        assert fut.wait(timeout=5) == 42
    finally:
        stop_spinning.set()
        s.stop()
    assert s.completions_batched >= 1
    assert s.cq_flushes_timeout >= 1, \
        "busy scheduler drained the ring without its deadline"


def test_exception_in_batched_completion_propagates():
    """A completion that resolves exceptionally travels the ring as a
    throw-resumption and surfaces in the parked fiber."""
    s = CQBatchFiberScheduler(app=None, name="boom", cq_size=100,
                              cq_flush_after=60.0)
    gate = Future()
    parked = threading.Event()
    recovered = []

    def waiter():
        parked.set()
        try:
            yield Wait(gate)
        except ValueError as exc:
            recovered.append(str(exc))
            return "recovered"
        return "missed"

    s.start()
    try:
        fut = s.spawn_external(waiter())
        assert parked.wait(timeout=5)
        gate.set_exception(ValueError("cq boom"))
        assert fut.wait(timeout=5) == "recovered"
    finally:
        s.stop()
    assert recovered == ["cq boom"]
    assert s.completions_batched == 2  # delivery + throw-resumption


# -------------------------------------------------------- executor-level e2e
def _echo(svc, payload):
    return payload
    yield  # pragma: no cover - marks this as a generator


@pytest.fixture
def echo_app():
    """Minimal transport target for AsyncRpc effects; replies resolve on the
    thread service's dispatcher threads — genuinely foreign resolver threads
    for the ring under test."""
    app = App(backend="thread")
    app.add_service(ServiceSpec("echo", {"go": _echo}, n_workers=2))
    with app:
        yield app


def _cq_exec(app, **kw):
    return FiberExecutor(app, "cq-test", n_workers=1, batch=True, cq=True,
                         **kw)


def test_fanout_join_costs_one_ring_completion(echo_app):
    """A 4-wide fan-out joined by one WaitAll is a single resumption: the
    countdown latch fires once, and that one completion crosses through the
    ring (the wakeup the CQ amortizes under load)."""
    ex = _cq_exec(echo_app, batch_size=1000, flush_after=60.0)

    def _fan():
        futs = []
        for i in range(4):
            f = yield AsyncRpc("echo", "go", i)
            futs.append(f)
        vals = yield WaitAll(futs)
        return vals

    ex.start()
    try:
        reply = Future()
        ex.deliver(_fan(), reply)
        assert reply.wait(timeout=10) == list(range(4))
    finally:
        ex.stop()
    st = ex.stats()
    assert st.batched_calls == 4          # submission ring still does its job
    assert st.flushes_join == 1
    # two ring crossings: the handler's delivery and ONE WaitAll-latch
    # resumption for the whole 4-wide fan-out
    assert st.completions_batched == 2
    assert st.cq_flushes_size == 0
    assert st.cq_hwm >= 1


def test_sequential_waits_all_travel_the_ring(echo_app):
    """Back-to-back sync RPCs park once per call; every resumption must
    come back through the completion ring, none via per-reply injection."""
    ex = _cq_exec(echo_app, batch_size=1000, flush_after=60.0)
    n = 5

    def _chain():
        acc = 0
        for i in range(n):
            f = yield AsyncRpc("echo", "go", i)
            acc += yield Wait(f)
        return acc

    ex.start()
    try:
        reply = Future()
        ex.deliver(_chain(), reply)
        assert reply.wait(timeout=10) == sum(range(n))
    finally:
        ex.stop()
    st = ex.stats()
    assert st.completions_batched == n + 1   # n resumptions + the delivery
    assert (st.cq_flushes_size + st.cq_flushes_timeout
            + st.cq_flushes_idle) >= 1


def test_missing_method_error_crosses_ring_and_chained_reply(echo_app):
    """The full fiber-batch-cq reply path — transport error, _chain_reply,
    completion ring — must surface the exception exactly like the unbatched
    backends do."""
    ex = _cq_exec(echo_app, batch_size=1000, flush_after=60.0)

    def _call():
        f = yield AsyncRpc("echo", "nope", None)   # no such method
        val = yield Wait(f)
        return val

    ex.start()
    try:
        reply = Future()
        ex.deliver(_call(), reply)
        with pytest.raises(KeyError):
            reply.wait(timeout=10)
    finally:
        ex.stop()
    # only the delivery crossed the ring: the missing-method reply resolves
    # synchronously on the batch carrier's own thread, so its throw-
    # resumption takes the same-thread bypass straight onto the ready deque
    assert ex.stats().completions_batched == 1
