"""PooledThreadExecutor + backend-registry unit tests.

Drives executors directly (deliver(gen, reply), no App transport) so pool
sizing, saturation accounting and the caller-runs fallback are exact.
"""
import threading
import time

import pytest

from repro.core import (App, BACKEND_NAMES, Compute, ServiceSpec, SpawnLocal,
                        Wait, WaitAll, make_executor, run_trial)
from repro.core.eventloop import EventLoopExecutor, ShardedEventLoopExecutor
from repro.core.executor import (FiberExecutor, PooledThreadExecutor,
                                 ThreadExecutor)
from repro.core.fiber import (BatchFiberScheduler, CQBatchFiberScheduler,
                              FiberScheduler)
from repro.core.future import Future


# --------------------------------------------------------------- registry
def test_backend_names_is_the_eight_backend_matrix():
    assert BACKEND_NAMES == ("thread", "thread-pool", "fiber", "fiber-steal",
                             "fiber-batch", "fiber-batch-cq", "event-loop",
                             "event-loop-shard")


def test_make_executor_resolves_every_registered_backend():
    types = {"thread": ThreadExecutor, "thread-pool": PooledThreadExecutor,
             "fiber": FiberExecutor, "fiber-steal": FiberExecutor,
             "fiber-batch": FiberExecutor, "fiber-batch-cq": FiberExecutor,
             "event-loop": EventLoopExecutor,
             "event-loop-shard": ShardedEventLoopExecutor}
    for backend in BACKEND_NAMES:
        ex = make_executor(backend, app=None, name="t", n_workers=2)
        assert isinstance(ex, types[backend]), backend
    assert make_executor("fiber-steal", None, "t", 2).steal
    assert not make_executor("fiber", None, "t", 2).steal
    batch = make_executor("fiber-batch", None, "t", 2)
    assert batch.batch and not batch.steal and not batch.cq
    assert all(isinstance(s, BatchFiberScheduler) for s in batch._scheds)
    assert not any(isinstance(s, CQBatchFiberScheduler)
                   for s in batch._scheds)
    cq = make_executor("fiber-batch-cq", None, "t", 2)
    assert cq.batch and cq.cq and not cq.steal
    assert all(isinstance(s, CQBatchFiberScheduler) for s in cq._scheds)
    plain = make_executor("fiber", None, "t", 2)
    assert not any(isinstance(s, BatchFiberScheduler) for s in plain._scheds)
    assert all(isinstance(s, FiberScheduler) for s in plain._scheds)
    shard = make_executor("event-loop-shard", None, "t", 2)
    assert shard.n_shards == 2


def test_completion_ring_requires_batch():
    with pytest.raises(ValueError, match="requires batch"):
        FiberExecutor(None, "bad", n_workers=1, cq=True)


def test_make_executor_unknown_backend_lists_registry():
    with pytest.raises(ValueError, match="thread-pool"):
        make_executor("asyncio", app=None, name="t", n_workers=2)


# ------------------------------------------------------------ pooled pool
def _spawner(n, gate=None):
    """Handler: fan out n local async carriers, join them all."""
    def _child(i):
        if gate is not None:
            yield Wait(gate)
        return i

    def _parent(payload=None):
        futs = []
        for i in range(n):
            f = yield SpawnLocal(_child, (i,))
            futs.append(f)
        vals = yield WaitAll(futs)
        return vals
    return _parent


def test_pool_is_pre_spawned_and_bounded():
    ex = PooledThreadExecutor(app=None, name="p", n_workers=2, pool_size=3)
    ex.start()
    try:
        assert len(ex._pool) == 3
        assert all(t.is_alive() for t in ex._pool)
        pool_idents = {t.ident for t in ex._pool}
        reply = Future()
        ex.deliver(_spawner(10)(), reply)
        assert reply.wait(timeout=10) == list(range(10))
        # all 10 carriers ran on the pre-spawned pool, no thread per call
        assert ex.spawns == 10
        assert {t.ident for t in ex._pool} == pool_idents
    finally:
        ex.stop()
    assert not any(t.is_alive() for t in ex._pool)


def test_pool_saturation_counts_stalls_and_queue_depth():
    """pool_size=1 + queue_bound=1: the second queued carrier fills the
    queue, further submissions stall (and fall back to caller-runs), and
    everything still completes once the gate opens."""
    gate = Future()
    ex = PooledThreadExecutor(app=None, name="p", n_workers=1, pool_size=1,
                              queue_bound=1, stall_timeout=0.05)
    ex.start()
    try:
        opener = threading.Timer(0.4, gate.set_result, args=(None,))
        opener.start()
        reply = Future()
        ex.deliver(_spawner(4, gate)(), reply)
        assert reply.wait(timeout=10) == list(range(4))
        opener.join()
        st = ex.stats()
        assert st.pool_stalls >= 1
        assert st.queue_depth_hwm >= 1
        assert st.spawns >= 1
    finally:
        ex.stop()


def test_pool_nested_fanout_does_not_deadlock():
    """A pool thread that fans out while the pool is saturated must run the
    carrier inline (caller-runs) instead of wedging the single pool slot."""
    def _leaf(i):
        return i
        yield  # pragma: no cover - marks this as a generator

    def _mid(payload=None):
        futs = []
        for i in range(3):
            f = yield SpawnLocal(_leaf, (i,))
            futs.append(f)
        vals = yield WaitAll(futs)
        return vals

    def _top(payload=None):
        futs = []
        for _ in range(3):
            f = yield SpawnLocal(_mid, ())
            futs.append(f)
        vals = yield WaitAll(futs)
        return vals

    ex = PooledThreadExecutor(app=None, name="p", n_workers=1, pool_size=1,
                              queue_bound=1, stall_timeout=0.05)
    ex.start()
    try:
        reply = Future()
        ex.deliver(_top(), reply)
        assert reply.wait(timeout=10) == [[0, 1, 2]] * 3
    finally:
        ex.stop()


def test_pool_wide_blocked_fanout_completes_without_recursion():
    """Regression: work-helping used to recurse one stack level per helped
    carrier that blocked, so a wide gate-blocked fan-out crashed with
    RecursionError; helped carriers now suspend instead."""
    gate = Future()
    ex = PooledThreadExecutor(app=None, name="p", n_workers=1, pool_size=1,
                              queue_bound=4096, stall_timeout=0.05)
    ex.start()
    try:
        opener = threading.Timer(0.5, gate.set_result, args=(None,))
        opener.start()
        reply = Future()
        ex.deliver(_spawner(1500, gate)(), reply)
        assert reply.wait(timeout=30) == list(range(1500))
        opener.join()
    finally:
        ex.stop()


# ----------------------------------------------------- stats aggregation
def test_app_backend_stats_aggregates_across_services():
    def _noop(svc, payload):
        yield Compute(0.0)
        return payload

    app = App(backend="thread-pool")
    app.add_service(ServiceSpec("a", {"go": _noop}, n_workers=1))
    app.add_service(ServiceSpec("b", {"go": _noop}, n_workers=1))
    with app:
        tr = run_trial(app, lambda rng: ("a", "go", 1), rate=100,
                       duration=0.2, seed=0)
    assert tr.errors == 0
    # TrialResult carries the per-trial delta of the aggregate counters
    for key in ("spawns", "pool_stalls", "queue_depth_hwm", "steals",
                "switches", "spawn_seconds", "stall_seconds",
                "batched_calls", "flushes_size", "flushes_join",
                "flushes_timeout", "ring_hwm", "inline_calls",
                "inline_depth_hwm", "fast_futures", "slow_futures"):
        assert key in tr.backend_stats
    agg = app.backend_stats()
    assert agg.spawns == app.total_spawns()


def test_trial_row_mentions_saturation_counters():
    from repro.core import TrialResult
    tr = TrialResult(offered_rps=1, achieved_rps=1, duration=1, p50=0.0,
                     p99=0.0, mean=0.0, completed=1, shed=0, errors=0,
                     backend_stats={"pool_stalls": 3, "queue_depth_hwm": 9,
                                    "steals": 2})
    row = tr.row()
    assert "stalls=3" in row and "qhwm=9" in row and "steals=2" in row


def test_trial_row_mentions_batch_counters():
    from repro.core import TrialResult
    tr = TrialResult(offered_rps=1, achieved_rps=1, duration=1, p50=0.0,
                     p99=0.0, mean=0.0, completed=1, shed=0, errors=0,
                     backend_stats={"batched_calls": 12, "flushes_size": 1,
                                    "flushes_join": 2, "flushes_timeout": 1,
                                    "ring_hwm": 6})
    row = tr.row()
    assert "batched=12/4fl" in row and "ringhwm=6" in row


def test_trial_row_mentions_inline_counters():
    from repro.core import TrialResult
    tr = TrialResult(offered_rps=1, achieved_rps=1, duration=1, p50=0.0,
                     p99=0.0, mean=0.0, completed=1, shed=0, errors=0,
                     backend_stats={"inline_calls": 42,
                                    "inline_depth_hwm": 2})
    assert "inline=42@d2" in tr.row()


def test_backend_stats_inline_depth_hwm_is_a_gauge():
    from repro.core import BackendStats
    before = BackendStats(inline_calls=5, inline_depth_hwm=3)
    after = BackendStats(inline_calls=9, inline_depth_hwm=3)
    d = BackendStats.delta(before, after)
    assert d.inline_calls == 4      # counter: per-trial delta
    assert d.inline_depth_hwm == 3  # gauge: high-water survives the delta
    agg = BackendStats(inline_depth_hwm=1).add(BackendStats(inline_depth_hwm=4))
    assert agg.inline_depth_hwm == 4


def test_backend_stats_ring_hwm_is_a_gauge():
    from repro.core import BackendStats
    before = BackendStats(batched_calls=10, ring_hwm=7)
    after = BackendStats(batched_calls=25, ring_hwm=7)
    d = BackendStats.delta(before, after)
    assert d.batched_calls == 15   # counter: per-trial delta
    assert d.ring_hwm == 7         # gauge: high-water survives the delta
    agg = BackendStats(ring_hwm=3).add(BackendStats(ring_hwm=9))
    assert agg.ring_hwm == 9       # aggregation takes the max


def test_trial_row_mentions_completion_ring_counters():
    from repro.core import TrialResult
    tr = TrialResult(offered_rps=1, achieved_rps=1, duration=1, p50=0.0,
                     p99=0.0, mean=0.0, completed=1, shed=0, errors=0,
                     backend_stats={"completions_batched": 24,
                                    "cq_flushes_size": 1,
                                    "cq_flushes_timeout": 2,
                                    "cq_flushes_idle": 3,
                                    "cq_hwm": 8, "shards": 4})
    row = tr.row()
    assert "cq=24/6fl" in row and "cqhwm=8" in row and "shards=4" in row


def test_backend_stats_cq_hwm_and_shards_are_gauges():
    from repro.core import BackendStats
    before = BackendStats(completions_batched=5, cq_hwm=6, shards=4)
    after = BackendStats(completions_batched=30, cq_hwm=6, shards=4)
    d = BackendStats.delta(before, after)
    assert d.completions_batched == 25  # counter: per-trial delta
    assert d.cq_hwm == 6                # gauge: high-water survives
    assert d.shards == 4                # gauge: configuration survives
    agg = BackendStats(cq_hwm=2, shards=1).add(
        BackendStats(cq_hwm=9, shards=4))
    assert agg.cq_hwm == 9 and agg.shards == 4  # aggregation takes the max
