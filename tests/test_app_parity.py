"""Cross-backend parity: every (app x workload) request must produce the
same result on every registered backend.

This is the contract the paper's migration story rests on: switching
``std::async`` -> ``boost::fiber::async`` (or to a pooled/work-stealing
variant) changes scheduling, never semantics.  Handlers are deterministic
functions of their payload, so the full response bodies must match
bit-for-bit across the whole backend matrix.
"""
import numpy as np
import pytest

from repro.apps import APP_NAMES, BENCH_BACKENDS, REGISTRY, get_app_def
from repro.core import RequestContext, run_trial

BACKENDS = BENCH_BACKENDS
CASES = [(name, wl) for name in APP_NAMES
         for wl in REGISTRY[name].workloads]


def _run_requests(app_name, requests, backend):
    """Serve a request sequence; a 4-tuple request carries a session id,
    sent as a RequestContext (the session-affine ``cached`` workload)."""
    d = get_app_def(app_name)
    out = []
    with d.build(backend) as app:
        for req in requests:
            dest, method, payload = req[:3]
            ctx = (RequestContext(session=req[3])
                   if len(req) > 3 else None)
            out.append(app.send(dest, method, payload,
                                ctx=ctx).wait(timeout=15))
    return out


@pytest.mark.parametrize("app_name,workload", CASES)
def test_backend_parity(app_name, workload):
    """Identical request sequence (same factory, same seed) on every
    backend -> identical results."""
    factory = get_app_def(app_name).make_request_factory(workload)
    rng = np.random.default_rng(12)
    requests = [factory(rng) for _ in range(3)]
    got = {b: _run_requests(app_name, requests, b) for b in BACKENDS}
    for b in BACKENDS:
        assert got[b] == got["thread"], f"{b} diverged from thread"
        assert len(got[b]) == len(requests)


# --------------------------------------------------------------- registry
def test_registry_has_all_three_apps():
    assert set(APP_NAMES) == {"socialnetwork", "hotelreservation",
                              "mediaservice"}


@pytest.mark.parametrize("app_name", APP_NAMES)
def test_registry_protocol(app_name):
    """Every app exposes five workloads incl. 'mixed' and the session-affine
    'cached', and its factories target the app's frontend with methods the
    frontend serves."""
    d = get_app_def(app_name)
    assert len(d.workloads) == 5
    assert "mixed" in d.workloads
    assert "cached" in d.workloads
    app = d.build("fiber")  # wiring only, never started
    frontend_methods = set(app.services[d.frontend].handlers)
    rng = np.random.default_rng(0)
    for wl in d.workloads:
        factory = d.make_request_factory(wl)
        for _ in range(8):
            req = factory(rng)
            dest, method = req[0], req[1]
            assert dest == d.frontend
            assert method in frontend_methods
            if wl == "cached":  # 4-tuple: session rides along
                assert isinstance(req[3], str)
    with pytest.raises(ValueError):
        d.make_request_factory("no_such_workload")


@pytest.mark.parametrize("app_name", APP_NAMES)
def test_incremental_migration(app_name):
    """Paper: services can migrate backends one at a time; a mixed-backend
    app (one override per registered backend, so all six coexist) must
    serve every workload's request unchanged."""
    d = get_app_def(app_name)
    factory = d.make_request_factory("mixed")
    rng = np.random.default_rng(5)
    requests = [factory(rng) for _ in range(3)]
    expected = _run_requests(app_name, requests, "fiber")
    overrides = {d.frontend: "fiber"}
    # spread the remaining backends over the first services of the graph
    others = [n for n in REGISTRY[app_name].build("fiber").services
              if n != d.frontend]
    migrated = [b for b in BACKENDS if b not in ("thread", "fiber")]
    for name, backend in zip(others, migrated):
        overrides[name] = backend
    assert len(others) >= len(migrated), \
        "app graph too small to host every backend at once"
    app = d.build("thread", overrides=overrides)
    with app:
        got = [app.send(dest, m, p).wait(timeout=15)
               for dest, m, p in requests]
    assert got == expected


# ------------------------------------------------------------ under load
@pytest.mark.slow
@pytest.mark.parametrize("app_name", APP_NAMES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_mixed_low_rate_trial_completes(app_name, backend):
    """At low rates both backends must achieve ~offered rate with zero
    errors on every app (paper: fiber is comparable to threads at low
    load; graph shape must not change that)."""
    d = get_app_def(app_name)
    with d.build(backend) as app:
        tr = run_trial(app, d.make_request_factory("mixed"), rate=80,
                       duration=0.8, seed=3)
        assert tr.errors == 0, tr.row()
        assert tr.achieved_rps > 40, tr.row()
