"""End-to-end launcher tests (subprocess, smoke configs)."""
import os
import subprocess
import sys

import pytest

jax = pytest.importorskip("jax")

# The dryrun launcher builds a production mesh via jax.sharding.AxisType,
# which this environment's jax predates — version drift tracked in
# CHANGES.md.  Guarded so tier-1 stays signal on either jax version.
needs_axistype = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax version drift: jax.sharding.AxisType unavailable "
           "(pre-existing, tracked in CHANGES.md)")


def _run(args, timeout=900):
    env = {**os.environ, "PYTHONPATH": "src"}
    out = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_train_launcher_runs_and_resumes(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    out = _run(["repro.launch.train", "--arch", "qwen2-0.5b", "--smoke",
                "--steps", "8", "--batch", "2", "--seq", "32",
                "--save-every", "4", "--log-every", "4",
                "--ckpt-dir", ckpt])
    assert "step     8" in out and "done" in out
    # resume: starts from step 8, ends immediately
    out2 = _run(["repro.launch.train", "--arch", "qwen2-0.5b", "--smoke",
                 "--steps", "8", "--batch", "2", "--seq", "32",
                 "--save-every", "4", "--ckpt-dir", ckpt])
    assert "start_step=8" in out2


def test_serve_launcher(tmp_path):
    out = _run(["repro.launch.serve", "--arch", "qwen2-0.5b", "--smoke",
                "--requests", "4", "--max-new", "3"])
    assert "rps=" in out and "p99=" in out


@needs_axistype
def test_dryrun_single_cell(tmp_path):
    out_json = str(tmp_path / "dry.json")
    out = _run(["repro.launch.dryrun", "--arch", "qwen2-0.5b",
                "--shape", "decode_32k", "--mesh", "pod1",
                "--out", out_json], timeout=1200)
    assert "1 ok" in out
    import json
    with open(out_json) as f:
        rec = json.load(f)["qwen2-0.5b|decode_32k|pod1"]
    assert rec["status"] == "ok"
    assert rec["n_chips"] == 256
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
