"""run_trial load-shedding coverage.

The open-loop generator caps concurrent in-flight requests at
``max_outstanding``.  These tests pin the three contractual behaviours:

* saturation increments ``shed`` instead of queueing unboundedly;
* shed requests are never issued, so they cannot contaminate the latency
  percentiles (which summarize *completed* requests only);
* the drain phase after the offered window completes the in-flight tail.

A gate service (every request parks on one externally-controlled future)
makes saturation deterministic: exactly ``max_outstanding`` requests get in,
everything else sheds, and nothing completes until the gate opens.

The regression tests at the bottom pin the two cross-trial bugs fixed in
the resilience PR: leftover in-flight requests from a drained-out trial
leaking into the next trial's ``BackendStats`` delta, and the latency
summary racing late completions.
"""
import math
import threading
import time

from repro.core import App, Compute, LatencyRecorder, ServiceSpec, Wait, \
    run_trial
from repro.core.future import Future


def _build_gated_app(gate: Future) -> App:
    def _hold(svc, payload):
        val = yield Wait(gate)
        return {"payload": payload, "gate": val}

    app = App(backend="fiber")
    app.add_service(ServiceSpec("gate", {"hold": _hold}, n_workers=1))
    return app


def _gate_factory(rng):
    return ("gate", "hold", 7)


def test_saturation_increments_shed():
    """With the gate closed, only max_outstanding requests enter; every
    later arrival sheds."""
    gate = Future()
    app = _build_gated_app(gate)
    with app:
        opener = threading.Timer(0.45, gate.set_result, args=("open",))
        opener.start()
        tr = run_trial(app, _gate_factory, rate=400, duration=0.3, seed=1,
                       max_outstanding=4, drain=5.0)
        opener.join()
    assert tr.shed > 0, tr.row()
    # offered ~120 arrivals in 0.3s at rate 400; all but the window shed
    assert tr.shed >= 50, tr.row()
    assert tr.errors == 0, tr.row()


def test_drain_completes_in_flight_requests():
    """At window end all admitted requests are still parked on the gate;
    opening it during the drain phase must complete exactly that window."""
    gate = Future()
    app = _build_gated_app(gate)
    with app:
        opener = threading.Timer(0.45, gate.set_result, args=("open",))
        opener.start()
        tr = run_trial(app, _gate_factory, rate=400, duration=0.3, seed=2,
                       max_outstanding=4, drain=5.0)
        opener.join()
    assert tr.completed == 4, tr.row()
    assert tr.errors == 0, tr.row()


def test_sheds_excluded_from_latency_percentiles():
    """Percentiles summarize completed requests only: every sample must
    carry the gate's hold time, which a shed 'sample' could not."""
    gate = Future()
    app = _build_gated_app(gate)
    with app:
        opener = threading.Timer(0.45, gate.set_result, args=("open",))
        opener.start()
        tr = run_trial(app, _gate_factory, rate=400, duration=0.3, seed=3,
                       max_outstanding=4, drain=5.0)
        opener.join()
    assert tr.shed > tr.completed, tr.row()
    # admitted requests waited for the gate (~0.45s after trial start); if
    # sheds leaked into the reservoir the low percentiles would be ~0.
    assert tr.p50 > 0.1, tr.row()
    assert tr.mean > 0.1, tr.row()


def test_no_shed_below_max_outstanding():
    """A fast handler at low rate never saturates the window."""
    def _fast(svc, payload):
        yield Compute(0.0)
        return payload

    app = App(backend="fiber")
    app.add_service(ServiceSpec("svc", {"go": _fast}, n_workers=1))
    with app:
        tr = run_trial(app, lambda rng: ("svc", "go", 1), rate=100,
                       duration=0.3, seed=4, max_outstanding=4096)
    assert tr.shed == 0, tr.row()
    assert tr.completed > 0, tr.row()
    assert tr.errors == 0, tr.row()


# --------------------------------------------------------------- regressions
def test_no_cross_trial_leakage():
    """Requests abandoned by a drained-out trial must not pollute the next
    trial's metrics.

    Trial 1 parks its requests on a closed gate and uses a drain window too
    short to outlast it, so it returns with leftovers in flight.  The gate
    then opens *while trial 2 runs*.  Pre-fix, the leftovers' completions
    landed inside trial 2's ``BackendStats`` delta (and their ``_done``
    callbacks decremented a stale counter); post-fix, trial 2's settle phase
    waits them out before its ``stats_before`` snapshot, and the severed
    callbacks are no-ops.
    """
    gate = Future()

    def _hold(svc, payload):
        val = yield Wait(gate)
        return val

    def _fast(svc, payload):
        yield Compute(0.0)
        return payload

    app = App(backend="fiber")
    app.add_service(ServiceSpec("gate", {"hold": _hold}, n_workers=1))
    app.add_service(ServiceSpec("fast", {"go": _fast}, n_workers=1))
    with app:
        tr1 = run_trial(app, _gate_factory, rate=200, duration=0.2, seed=5,
                        max_outstanding=8, drain=0.05)
        # the drain timed out: the admitted window is still parked
        assert tr1.abandoned == 8, tr1.row()
        assert tr1.completed == 0, tr1.row()
        opener = threading.Timer(0.15, gate.set_result, args=("open",))
        opener.start()
        tr2 = run_trial(app, lambda rng: ("fast", "go", 1), rate=200,
                        duration=0.4, seed=6)
        opener.join()
    assert tr2.errors == 0, tr2.row()
    bs = tr2.backend_stats
    # every completion classified inside trial 2's delta must be trial 2's
    # own (one reply future per request on this no-RPC app); the 8 leftover
    # gate requests completing mid-trial would show up as +8 here.
    classified = bs["fast_futures"] + bs["slow_futures"]
    assert tr2.completed - 1 <= classified <= tr2.completed + 1, \
        (classified, tr2.completed, tr2.row())


def test_summary_not_racing_late_completions(monkeypatch):
    """The latency summary and the completion counters must describe the
    same frozen state.

    Pre-fix, ``rec.summary()`` ran while leftover requests could still
    complete: a completion landing between the summary snapshot and the
    ``rec.completed`` read produced a TrialResult with ``completed > 0``
    but NaN percentiles.  The patched summary makes that interleaving
    deterministic by opening the gate (and waiting for the completions)
    inside the summary call itself.  Post-fix the trial is severed before
    the summary, so the late completions are counted as abandoned and the
    result stays self-consistent.
    """
    gate = Future()
    app = _build_gated_app(gate)
    real_summary = LatencyRecorder.summary

    def patched(self):
        s = real_summary(self)
        if not gate.done:
            gate.set_result("open")
            time.sleep(0.3)  # let the gated requests complete (pre-fix:
            #                  they mutate the recorder right here)
        return s

    monkeypatch.setattr(LatencyRecorder, "summary", patched)
    with app:
        tr = run_trial(app, _gate_factory, rate=100, duration=0.15, seed=7,
                       max_outstanding=4, drain=0.05)
    # consistency: completions reported must be the ones the percentiles
    # summarize (pre-fix: completed == 4 with p50 == NaN)
    if tr.completed:
        assert math.isfinite(tr.p50), tr.row()
    assert tr.completed + tr.abandoned == 4, tr.row()
    assert tr.abandoned == 4, tr.row()
