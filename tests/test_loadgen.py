"""run_trial load-shedding coverage.

The open-loop generator caps concurrent in-flight requests at
``max_outstanding``.  These tests pin the three contractual behaviours:

* saturation increments ``shed`` instead of queueing unboundedly;
* shed requests are never issued, so they cannot contaminate the latency
  percentiles (which summarize *completed* requests only);
* the drain phase after the offered window completes the in-flight tail.

A gate service (every request parks on one externally-controlled future)
makes saturation deterministic: exactly ``max_outstanding`` requests get in,
everything else sheds, and nothing completes until the gate opens.
"""
import threading

from repro.core import App, Compute, ServiceSpec, Wait, run_trial
from repro.core.future import Future


def _build_gated_app(gate: Future) -> App:
    def _hold(svc, payload):
        val = yield Wait(gate)
        return {"payload": payload, "gate": val}

    app = App(backend="fiber")
    app.add_service(ServiceSpec("gate", {"hold": _hold}, n_workers=1))
    return app


def _gate_factory(rng):
    return ("gate", "hold", 7)


def test_saturation_increments_shed():
    """With the gate closed, only max_outstanding requests enter; every
    later arrival sheds."""
    gate = Future()
    app = _build_gated_app(gate)
    with app:
        opener = threading.Timer(0.45, gate.set_result, args=("open",))
        opener.start()
        tr = run_trial(app, _gate_factory, rate=400, duration=0.3, seed=1,
                       max_outstanding=4, drain=5.0)
        opener.join()
    assert tr.shed > 0, tr.row()
    # offered ~120 arrivals in 0.3s at rate 400; all but the window shed
    assert tr.shed >= 50, tr.row()
    assert tr.errors == 0, tr.row()


def test_drain_completes_in_flight_requests():
    """At window end all admitted requests are still parked on the gate;
    opening it during the drain phase must complete exactly that window."""
    gate = Future()
    app = _build_gated_app(gate)
    with app:
        opener = threading.Timer(0.45, gate.set_result, args=("open",))
        opener.start()
        tr = run_trial(app, _gate_factory, rate=400, duration=0.3, seed=2,
                       max_outstanding=4, drain=5.0)
        opener.join()
    assert tr.completed == 4, tr.row()
    assert tr.errors == 0, tr.row()


def test_sheds_excluded_from_latency_percentiles():
    """Percentiles summarize completed requests only: every sample must
    carry the gate's hold time, which a shed 'sample' could not."""
    gate = Future()
    app = _build_gated_app(gate)
    with app:
        opener = threading.Timer(0.45, gate.set_result, args=("open",))
        opener.start()
        tr = run_trial(app, _gate_factory, rate=400, duration=0.3, seed=3,
                       max_outstanding=4, drain=5.0)
        opener.join()
    assert tr.shed > tr.completed, tr.row()
    # admitted requests waited for the gate (~0.45s after trial start); if
    # sheds leaked into the reservoir the low percentiles would be ~0.
    assert tr.p50 > 0.1, tr.row()
    assert tr.mean > 0.1, tr.row()


def test_no_shed_below_max_outstanding():
    """A fast handler at low rate never saturates the window."""
    def _fast(svc, payload):
        yield Compute(0.0)
        return payload

    app = App(backend="fiber")
    app.add_service(ServiceSpec("svc", {"go": _fast}, n_workers=1))
    with app:
        tr = run_trial(app, lambda rng: ("svc", "go", 1), rate=100,
                       duration=0.3, seed=4, max_outstanding=4096)
    assert tr.shed == 0, tr.row()
    assert tr.completed > 0, tr.row()
    assert tr.errors == 0, tr.row()
