"""Static lint pass tests: each rule fires on its fixture, the suppression
comment silences it, the real tree is clean, and the CLI contract (exit
code + rule id + fix hint on stdout) holds."""
import subprocess
import sys
from pathlib import Path

from repro.analysis.lint import HINTS, RULES, lint_paths

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "lint"
SRC = REPO / "src" / "repro"


def _by_rule(findings):
    out = {}
    for f in findings:
        out.setdefault(f.rule, []).append(f)
    return out


def test_fixture_tree_flags_every_rule():
    findings = lint_paths([str(FIXTURES)])
    by_rule = _by_rule(findings)
    assert set(by_rule) == set(RULES), sorted(
        f.render() for f in findings)


def test_a101_blocking_in_handlers():
    findings = lint_paths([str(FIXTURES / "repro" / "apps")])
    a101 = _by_rule(findings).get("A101", [])
    messages = "\n".join(f.message for f in a101)
    assert "time.sleep" in messages
    assert ".wait()" in messages
    assert ".wait_done()" in messages
    assert "threading.Event" in messages
    # the `# repro: allow[A101]` line must NOT appear
    lines = {f.line for f in a101}
    source = (FIXTURES / "repro" / "apps" / "bad_blocking.py").read_text()
    suppressed_line = next(i + 1 for i, ln in enumerate(source.splitlines())
                           if "repro: allow[A101]" in ln)
    assert suppressed_line not in lines


def test_a102_nondeterminism_in_core():
    findings = lint_paths(
        [str(FIXTURES / "repro" / "core" / "bad_nondeterminism.py")])
    a102 = _by_rule(findings).get("A102", [])
    messages = "\n".join(f.message for f in a102)
    assert "random.random" in messages
    assert "random.randint" in messages
    assert "time.time" in messages
    # seeded instance + monotonic clocks + the suppressed line are clean
    assert len(a102) == 3


def test_a103_direct_and_transitive_jax():
    findings = lint_paths([str(FIXTURES)])
    a103 = _by_rule(findings).get("A103", [])
    chains = "\n".join(f.message for f in a103)
    assert "repro.core.bad_jax_direct -> jax" in chains
    assert ("repro.core.bad_jax_transitive -> repro.kernels_helper -> jax"
            in chains)
    # the helper itself lives outside core/apps: never flagged
    assert not any("kernels_helper.py" in f.path for f in a103)


def test_a104_stats_owner():
    findings = lint_paths(
        [str(FIXTURES / "repro" / "core" / "bad_stats_owner.py")])
    a104 = _by_rule(findings).get("A104", [])
    assert len(a104) == 2                      # unlocked_bump + unlocked_gauge
    messages = "\n".join(f.message for f in a104)
    assert ".spawns" in messages
    assert ".queue_depth_hwm" in messages


def test_clean_fixture_module_has_no_findings():
    findings = lint_paths(
        [str(FIXTURES / "repro" / "core" / "clean_module.py")])
    assert findings == []


def test_real_tree_is_clean():
    """The enforced gate: the shipped src/repro tree lints clean."""
    findings = lint_paths([str(SRC)])
    assert findings == [], "\n".join(f.render() for f in findings)


def test_every_rule_has_a_hint():
    assert set(HINTS) == set(RULES)
    assert all(HINTS[r] for r in RULES)


def test_cli_contract_clean_tree_and_dirty_fixture():
    """`python -m repro.analysis.lint`: exit 0 on the tree; exit 1 with
    rule id + fix hint per violation on each fixture."""
    env_src = str(REPO / "src")
    ok = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(SRC)],
        capture_output=True, text=True, cwd=str(REPO),
        env={"PYTHONPATH": env_src, "PATH": "/usr/bin:/bin"})
    assert ok.returncode == 0, ok.stdout + ok.stderr
    bad = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(FIXTURES)],
        capture_output=True, text=True, cwd=str(REPO),
        env={"PYTHONPATH": env_src, "PATH": "/usr/bin:/bin"})
    assert bad.returncode == 1
    assert "A101" in bad.stdout and "A103" in bad.stdout
    assert "hint:" in bad.stdout
