"""Lint fixture: a non-core repro module that imports jax at top level
(legal here — but anything in core/apps importing *this* violates A103)."""
import jax  # noqa: F401


def fused_step():
    return jax.__name__


def lazy_ok():
    import jax.numpy as jnp  # function-local: never counted by A103
    return jnp
