"""Lint fixture: every A101 violation class in one handler module."""
import threading
import time


def handler_sleeps(svc, payload):
    time.sleep(0.1)                     # A101: blocks the carrier
    yield


def handler_blocking_wait(svc, payload):
    fut = yield object()
    fut.wait(timeout=1.0)               # A101: blocking join in a handler
    return fut.wait_done()              # A101: ditto


def handler_builds_primitive(svc, payload):
    done = threading.Event()            # A101: kernel primitive in handler
    yield
    return done


def handler_suppressed(svc, payload):
    time.sleep(0.0)  # repro: allow[A101]
    yield
