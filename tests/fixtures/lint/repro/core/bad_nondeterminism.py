"""Lint fixture: A102 violations (unseeded RNG, wall clock) in core."""
import random
import time

_OK_RNG = random.Random(42)             # allowed: seeded instance


def jitter():
    return random.random()              # A102: unseeded module-level RNG


def pick(n):
    return random.randint(0, n)         # A102: unseeded module-level RNG


def stamp():
    return time.time()                  # A102: wall clock


def ok_clock():
    return time.monotonic(), time.perf_counter(), _OK_RNG.random()


def suppressed():
    return time.time()  # repro: allow[A102]
