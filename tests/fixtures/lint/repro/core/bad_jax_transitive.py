"""Lint fixture: A103 — jax reached transitively through a repro module."""
from repro.kernels_helper import fused_step  # noqa: F401


def run():
    return fused_step()
