"""Lint fixture: A103 — direct module-level jax import in core."""
import jax  # noqa: F401


def uses_it():
    return jax.__name__
