"""Lint fixture: A104 violations — counters mutated outside their owner."""
import threading


class RogueExecutor:
    """Not in the owner-thread table; must hold a lock to mutate."""

    def __init__(self):
        self._lock = threading.Lock()
        self.spawns = 0                 # ok: __init__ runs before sharing
        self.switches = 0

    def unlocked_bump(self):
        self.spawns += 1                # A104: no owning lock held

    def unlocked_gauge(self, depth):
        self.queue_depth_hwm = depth    # A104: no owning lock held

    def locked_bump(self):
        with self._lock:
            self.switches += 1          # ok: owner lock held

    def suppressed_bump(self):
        self.spawns += 1  # repro: allow[A104]


class FiberScheduler:
    """Shadows an owner-thread-only class name: mutations are sanctioned."""

    def owner_thread_bump(self):
        self.switches += 1              # ok: owner-thread-only class
