"""Lint fixture: a clean core module — zero findings expected."""
import random
import time

_RNG = random.Random(7)


class FiberScheduler:
    def __init__(self):
        self.switches = 0

    def bump(self):
        self.switches += 1


def backoff(attempt):
    return min(0.05, 0.002 * (2 ** attempt)) * _RNG.random()


def now():
    return time.monotonic()
