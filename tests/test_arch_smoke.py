"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes and no NaNs; prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import Model

B, S = 2, 16


def _batch(model: Model, rng):
    cfg = model.cfg
    tok = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, axis=1)}
    if cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(rng, (B, S, cfg.d_model),
                                            cfg.cdtype) * 0.02
        batch["embed_mask"] = jnp.arange(S)[None, :].repeat(B, 0) < 4
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        batch["positions"] = jnp.stack([pos, pos, pos])
    if cfg.is_encdec:
        batch = {"src": jax.random.normal(rng, (B, S, cfg.d_model),
                                          cfg.cdtype) * 0.02,
                 "tokens": tok[:, : max(S // 4, 8)],
                 "labels": jnp.roll(tok[:, : max(S // 4, 8)], -1, axis=1)}
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    rng = jax.random.key(0)
    params = model.init(rng)

    loss, metrics = jax.jit(model.loss)(params, _batch(model, rng))
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(metrics["ce"]) > 0

    # one gradient step: grads finite
    grads = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(
        params, _batch(model, rng))
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, dtype=np.float32)))
               for g in flat), f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    rng = jax.random.key(1)
    params = model.init(rng)
    inputs = _batch(model, rng)
    inputs.pop("labels", None)

    logits, cache = jax.jit(model.prefill)(params, inputs)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    # one decode step continuing after the prompt
    prompt_len = inputs["tokens"].shape[1]
    # pad the cache to a longer max_len for full-cache families
    cache = _pad_cache(model, cache, prompt_len + 4)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    pos = jnp.full((B,), prompt_len, jnp.int32)
    logits2, cache2 = jax.jit(model.decode_step)(params, cache, tok, pos)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


def _pad_cache(model, cache, max_len):
    """Right-pad seq-indexed caches from prefill length to max_len."""
    cfg = model.cfg
    if cfg.family in ("ssm", "hybrid"):
        return cache  # O(1)/ring state needs no padding

    def pad(x, axis):
        pad_n = max_len - x.shape[axis]
        if pad_n <= 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad_n)
        return jnp.pad(x, widths)

    if cfg.is_encdec:
        return {"self": {k: pad(v, 2) for k, v in cache["self"].items()},
                "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
    return {k: pad(v, 2) for k, v in cache.items()}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_validates(arch):
    cfg = get_config(arch)
    cfg.validate()
    assert cfg.name.startswith(arch.split("-")[0][:4]) or True
    # abstract params build without allocation
    model = Model(cfg)
    n = model.count_params()
    assert n > 0


def test_param_counts_plausible():
    """Full-config parameter counts are in the right ballpark."""
    expect = {
        "qwen2-0.5b": (0.3e9, 0.7e9),
        "qwen3-32b": (28e9, 36e9),
        "llama3-405b": (380e9, 430e9),
        "olmoe-1b-7b": (5e9, 8e9),
        "grok-1-314b": (280e9, 350e9),
        "rwkv6-3b": (2.5e9, 4e9),
        "minicpm3-4b": (3e9, 5.5e9),
        "recurrentgemma-9b": (7e9, 11e9),
        "qwen2-vl-2b": (1e9, 2.5e9),
        "seamless-m4t-medium": (0.7e9, 1.8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = Model(get_config(arch)).count_params()
        assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B params not in " \
                              f"[{lo / 1e9:.1f}, {hi / 1e9:.1f}]B"


def test_moe_expert_split_equivalence():
    """Half-expert sharding (moe_expert_split=2) is numerically identical to
    the unsplit MoE given correspondingly re-laid-out weights."""
    import jax
    import jax.numpy as jnp
    from repro.models.layers import moe_ffn

    cfg1 = get_smoke_config("grok-1-314b").with_(
        param_dtype="float32", compute_dtype="float32", remat=False,
        moe_capacity_factor=16.0)
    cfg2 = cfg1.with_(moe_expert_split=2)
    L, E, d, f = 1, cfg1.n_experts, cfg1.d_model, cfg1.d_ff
    k = 2
    rng = jax.random.PRNGKey(3)
    ks = jax.random.split(rng, 4)
    p1 = {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * 0.02,
        "w_gate": jax.random.normal(ks[1], (E, d, f), jnp.float32) * 0.02,
        "w_up": jax.random.normal(ks[2], (E, d, f), jnp.float32) * 0.02,
        "w_down": jax.random.normal(ks[3], (E, f, d), jnp.float32) * 0.02,
    }
    # re-lay-out: f split k ways, sub-experts e-major
    p2 = {
        "router": p1["router"],
        "w_gate": p1["w_gate"].reshape(E, d, k, f // k)
                  .transpose(0, 2, 1, 3).reshape(E * k, d, f // k),
        "w_up": p1["w_up"].reshape(E, d, k, f // k)
                .transpose(0, 2, 1, 3).reshape(E * k, d, f // k),
        "w_down": p1["w_down"].reshape(E, k, f // k, d)
                  .reshape(E * k, f // k, d),
    }
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 16, d), jnp.float32)
    y1, aux1 = moe_ffn(x, p1, cfg1)
    y2, aux2 = moe_ffn(x, p2, cfg2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-6)
