"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode on CPU; the kernels target TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.rwkv6_scan.ops import wkv6
from repro.kernels.rwkv6_scan.ref import wkv6_ref


def _assert_close(a, b, dtype, atol32=3e-5, atolbf=3e-2):
    atol = atolbf if dtype == jnp.bfloat16 else atol32
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               atol=atol, rtol=atol)


# --------------------------------------------------------- flash attention
FA_CASES = [
    # B, S, T, Hq, Hkv, D, causal, window, softcap
    (2, 256, 256, 4, 2, 64, True, 0, 0.0),
    (1, 512, 512, 8, 8, 128, True, 0, 0.0),
    (1, 256, 512, 4, 1, 64, True, 0, 30.0),
    (2, 256, 256, 4, 2, 128, True, 128, 0.0),
    (1, 256, 256, 2, 2, 64, False, 0, 0.0),
    (1, 1024, 1024, 2, 1, 64, True, 256, 0.0),
]


@pytest.mark.parametrize("case", FA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dtype):
    B, S, T, Hq, Hkv, D, causal, window, cap = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal, window, cap)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window,
                              softcap=cap)
    assert out.dtype == dtype
    _assert_close(out, ref, dtype)


def test_flash_attention_grad_matches_ref():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    g1 = jax.grad(lambda q, k, v: flash_attention(q, k, v).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda q, k, v: flash_attention_ref(q, k, v).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        _assert_close(a, b, jnp.float32)


# ----------------------------------------------------------- flash decode
FD_CASES = [
    (2, 1024, 8, 2, 64, 0.0),
    (4, 512, 4, 1, 128, 0.0),
    (2, 2048, 16, 8, 128, 30.0),
    (1, 512, 14, 2, 64, 0.0),     # qwen2-0.5b head geometry
]


@pytest.mark.parametrize("case", FD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(case, dtype):
    B, T, Hq, Hkv, D, cap = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 4)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, T + 1)
    out = decode_attention(q, k, v, lengths, softcap=cap)
    ref = decode_attention_ref(q, k, v, lengths, softcap=cap)
    _assert_close(out, ref, dtype)


def _check_decode_attention_case(B, T, heads, D):
    """Property body: kernel == oracle for arbitrary (B,T,heads,D,lengths)."""
    Hq, Hkv = heads
    ks = jax.random.split(jax.random.PRNGKey(B * T + Hq + D), 4)
    q = jax.random.normal(ks[0], (B, Hq, D))
    k = jax.random.normal(ks[1], (B, T, Hkv, D))
    v = jax.random.normal(ks[2], (B, T, Hkv, D))
    lengths = jax.random.randint(ks[3], (B,), 1, T + 1)
    _assert_close(decode_attention(q, k, v, lengths),
                  decode_attention_ref(q, k, v, lengths), jnp.float32)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(1, 4), st.sampled_from([256, 512]),
           st.sampled_from([(4, 2), (8, 1), (2, 2)]),
           st.sampled_from([64, 128]))
    def test_decode_attention_property(B, T, heads, D):
        _check_decode_attention_case(B, T, heads, D)
else:
    @pytest.mark.parametrize("B,T,heads,D", [
        (1, 256, (4, 2), 64), (4, 512, (8, 1), 128),
        (2, 256, (2, 2), 128), (3, 512, (4, 2), 64),
    ])
    def test_decode_attention_property_fallback(B, T, heads, D):
        _check_decode_attention_case(B, T, heads, D)


# ------------------------------------------------------------------ wkv6
WKV_CASES = [
    (2, 128, 2, 64),
    (1, 256, 4, 64),
    (2, 64, 1, 32),
    (1, 512, 2, 64),
]


@pytest.mark.parametrize("case", WKV_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_sweep(case, dtype):
    B, T, H, D = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 6)
    r = jax.random.normal(ks[0], (B, T, H, D), dtype)
    k = jax.random.normal(ks[1], (B, T, H, D), dtype)
    v = jax.random.normal(ks[2], (B, T, H, D), dtype)
    x = jax.random.uniform(ks[3], (B, T, H, D), minval=-6.0, maxval=1.0)
    w = jnp.exp(-jnp.exp(x)).astype(jnp.float32)
    u = (jax.random.normal(ks[4], (H, D)) * 0.3).astype(dtype)
    s0 = (jax.random.normal(ks[5], (B, H, D, D)) * 0.1).astype(jnp.float32)
    out, sT = wkv6(r, k, v, w, u, s0)
    oref, sref = wkv6_ref(r, k, v, w, u, s0)
    _assert_close(out, oref, dtype, atol32=3e-4, atolbf=5e-2)
    _assert_close(sT, sref, jnp.float32, atol32=3e-4)


def test_wkv6_extreme_decay_stable():
    """Strong decays underflow to 0 harmlessly (no NaN/Inf)."""
    B, T, H, D = 1, 128, 1, 64
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    r = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, H, D))
    v = jax.random.normal(ks[2], (B, T, H, D))
    w = jnp.full((B, T, H, D), 1e-4)        # near-total forgetting
    u = jnp.zeros((H, D))
    s0 = jnp.zeros((B, H, D, D))
    out, sT = wkv6(r, k, v, w, u, s0)
    oref, _ = wkv6_ref(r, k, v, w, u, s0)
    assert np.all(np.isfinite(np.asarray(out)))
    _assert_close(out, oref, jnp.float32, atol32=1e-3)


# ------------------------------------------------------------- rglru scan
@pytest.mark.parametrize("shape", [(2, 256, 512), (1, 128, 1024),
                                   (3, 64, 128), (1, 512, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan_sweep(shape, dtype):
    B, T, W = shape
    ks = jax.random.split(jax.random.PRNGKey(B + T + W), 3)
    a = (jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, W))) ** 0.2).astype(dtype)
    b = (jax.random.normal(ks[1], (B, T, W)) * 0.3).astype(dtype)
    h0 = jax.random.normal(ks[2], (B, W), jnp.float32)
    h, hT = rglru_scan(a, b, h0)
    href, hTref = rglru_scan_ref(a, b, h0)
    _assert_close(h, href, dtype)
    _assert_close(hT, hTref, jnp.float32, atol32=1e-4, atolbf=5e-2)


def test_rglru_scan_grad_matches_ref():
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (1, 64, 128))) ** 0.2
    b = jax.random.normal(ks[1], (1, 64, 128)) * 0.3
    h0 = jax.random.normal(ks[2], (1, 128))
    g1 = jax.grad(lambda a, b: rglru_scan(a, b, h0)[0].sum(),
                  argnums=(0, 1))(a, b)
    g2 = jax.grad(lambda a, b: rglru_scan_ref(a, b, h0)[0].sum(),
                  argnums=(0, 1))(a, b)
    for x, y in zip(g1, g2):
        _assert_close(x, y, jnp.float32)


# --------------------------------------------- model-level kernel parity
def test_rwkv_model_kernel_path_matches_ref_path():
    """The full rwkv6 smoke model gives the same loss with the Pallas
    chunked kernel as with the lax.scan reference."""
    from repro.configs import get_smoke_config
    from repro.models import Model
    cfg = get_smoke_config("rwkv6-3b").with_(remat=False)
    rng = jax.random.PRNGKey(0)
    tok = jax.random.randint(rng, (2, 64), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    m_ref = Model(cfg, use_kernels=False)
    m_ker = Model(cfg, use_kernels=True)
    params = m_ref.init(rng)
    l1, _ = m_ref.loss(params, batch)
    l2, _ = m_ker.loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-2)
