"""ShardedEventLoopExecutor (event-loop-shard) tests.

Sharding must be a pure placement decision: deterministic (the parity suite
and trace replay depend on it), reasonably balanced over a sequential
request stream, and invisible to handler semantics — each shard is a full
single-threaded event loop and a request never migrates off its shard.
"""
import threading

import pytest

from repro.core import Future, Sleep, SpawnLocal, Wait, WaitAll
from repro.core.eventloop import EventLoopExecutor, ShardedEventLoopExecutor


# ------------------------------------------------------------ hash placement
def test_shard_for_is_deterministic_and_in_range():
    for n in (1, 2, 3, 4, 7):
        for rid in range(256):
            s = ShardedEventLoopExecutor.shard_for(rid, n)
            assert 0 <= s < n
            assert s == ShardedEventLoopExecutor.shard_for(rid, n)


def test_shard_for_spreads_a_sequential_stream():
    """Sequential request ids (the ticket stream) must cover every shard
    without herding: no shard may take more than twice its fair share."""
    for n in (2, 3, 4, 5, 8):
        counts = [0] * n
        total = 1024
        for rid in range(total):
            counts[ShardedEventLoopExecutor.shard_for(rid, n)] += 1
        assert all(c > 0 for c in counts), (n, counts)
        assert max(counts) <= 2 * total / n, (n, counts)


def test_delivery_sequence_maps_to_same_shards_every_run():
    """Two executors fed the same delivery sequence place every request on
    the same shard — the determinism the parity cells rely on."""
    def placements(n_deliver):
        ex = ShardedEventLoopExecutor(app=None, name="det", n_workers=4)
        seen = []
        for i, shard in enumerate(ex._shards):
            shard.deliver = lambda gen, reply, i=i: seen.append(i)
        for _ in range(n_deliver):
            ex.deliver(iter(()), Future())
        return seen

    first, second = placements(64), placements(64)
    assert first == second
    assert set(first) == {0, 1, 2, 3}          # every shard participates


# ----------------------------------------------------------- loop semantics
def _leaf(ran_on, lock, i):
    with lock:
        ran_on.append(threading.current_thread().name)
    return i
    yield  # pragma: no cover - marks this as a generator


def test_requests_fan_across_shard_threads_but_never_migrate():
    """Different requests land on different shard loops; a request's own
    continuations (SpawnLocal fan-out) all stay on its shard thread."""
    ex = ShardedEventLoopExecutor(app=None, name="fan", n_workers=4)
    assert ex.n_shards == 4
    lock = threading.Lock()
    per_request_threads = []

    def _handler():
        ran_on = []
        futs = []
        for i in range(4):
            f = yield SpawnLocal(_leaf, (ran_on, lock, i))
            futs.append(f)
        vals = yield WaitAll(futs)
        with lock:
            ran_on.append(threading.current_thread().name)
            per_request_threads.append(set(ran_on))
        return vals

    ex.start()
    try:
        futs = []
        for _ in range(16):
            fut = Future()
            ex.deliver(_handler(), fut)
            futs.append(fut)
        for f in futs:
            assert f.wait(timeout=10) == list(range(4))
    finally:
        ex.stop()
    # each request was pinned: its handler + all its spawns on ONE thread
    for threads in per_request_threads:
        assert len(threads) == 1, threads
    all_threads = set().union(*per_request_threads)
    assert len(all_threads) > 1, "all 16 requests herded onto one shard"
    assert all(t.startswith("fan-shard") for t in all_threads)


def test_single_shard_degenerates_to_plain_event_loop():
    ex = ShardedEventLoopExecutor(app=None, name="solo", n_workers=1)
    assert ex.n_shards == 1
    assert isinstance(ex._shards[0], EventLoopExecutor)
    ex.start()
    try:
        def one():
            yield Sleep(0.001)
            return "ok"
        fut = Future()
        ex.deliver(one(), fut)
        assert fut.wait(timeout=5) == "ok"
    finally:
        ex.stop()


def test_exception_propagates_through_a_shard():
    ex = ShardedEventLoopExecutor(app=None, name="boom", n_workers=3)
    ex.start()

    def _boom():
        yield Sleep(0.001)
        raise ValueError("shard boom")

    try:
        futs = []
        for _ in range(6):                 # hit several shards
            fut = Future()
            ex.deliver(_boom(), fut)
            futs.append(fut)
        for fut in futs:
            with pytest.raises(ValueError, match="shard boom"):
                fut.wait(timeout=5)
    finally:
        ex.stop()


def test_parked_wait_resumes_via_owning_shard():
    ex = ShardedEventLoopExecutor(app=None, name="park", n_workers=2)
    ex.start()
    gate = Future()
    parked = threading.Event()

    def _waiter():
        parked.set()
        val = yield Wait(gate)
        return val + 1

    try:
        fut = Future()
        ex.deliver(_waiter(), fut)
        assert parked.wait(timeout=5)
        gate.set_result(41)
        assert fut.wait(timeout=5) == 42
    finally:
        ex.stop()


# ------------------------------------------------------------------- stats
def test_stats_aggregate_shards_and_report_width():
    ex = ShardedEventLoopExecutor(app=None, name="st", n_workers=4)

    def _fan(n):
        futs = []
        for i in range(n):
            f = yield SpawnLocal(_leaf, ([], threading.Lock(), i))
            futs.append(f)
        vals = yield WaitAll(futs)
        return vals

    ex.start()
    try:
        futs = []
        for _ in range(8):
            fut = Future()
            ex.deliver(_fan(3), fut)
            futs.append(fut)
        for f in futs:
            assert f.wait(timeout=10) == list(range(3))
    finally:
        ex.stop()
    st = ex.stats()
    assert st.shards == 4                       # gauge: configured width
    assert st.spawns == 8 * 3 == ex.spawns      # summed across shards
    assert st.switches >= 8 * 4                 # handlers + leaves resumed
