"""Resilience layer: deadlines, retries, breakers, bounds, overload mode.

Covers the policy objects (unit tests with injected clocks — no sleeping
through state machines), enforcement across the full backend matrix
(timeouts must fire on every backend, armed by timers rather than polling
on the cooperative ones), and the overload harness built on top.
"""
import threading
import time

import pytest

from repro.core import (BACKEND_NAMES, App, AsyncRpc, CircuitBreaker,
                        CircuitOpenError, Compute, DeadlineExceeded,
                        Rejected, ResiliencePolicy, RetryPolicy, ServiceSpec,
                        Sleep, Wait, run_overload, run_trial)
from repro.core.future import Future
from repro.core.resilience import RetryBudget
from repro.core.timers import TimerThread


# --------------------------------------------------------------- app helpers
def _sleepy_app(backend: str, leaf_sleep: float = 0.2,
                resilience=None) -> App:
    """root --rpc--> leaf, leaf sleeps: the canonical deadline victim."""
    def leaf(svc, payload):
        yield Sleep(leaf_sleep)
        return "leaf"

    def root(svc, payload):
        f = yield AsyncRpc("leaf", "get", payload)
        return (yield Wait(f))

    app = App(backend=backend, net_latency=0.0, resilience=resilience)
    app.add_service(ServiceSpec("leaf", {"get": leaf}, n_workers=1))
    app.add_service(ServiceSpec("root", {"get": root}, n_workers=1))
    return app


# ------------------------------------------------------------------ deadlines
@pytest.mark.parametrize("backend", BACKEND_NAMES)
def test_deadline_expires_on_every_backend(backend):
    """A per-call deadline shorter than the leaf's sleep must resolve the
    reply with DeadlineExceeded — on all 8 backends — and tick the app-wide
    timeout counter."""
    app = _sleepy_app(backend, leaf_sleep=0.25)
    with app:
        fut = app.send("root", "get", None,
                       deadline=time.monotonic() + 0.02)
        with pytest.raises(DeadlineExceeded):
            fut.wait(timeout=5.0)
        assert app.backend_stats().timeouts >= 1


@pytest.mark.parametrize("backend", ["fiber", "fiber-batch", "fiber-batch-cq",
                                     "event-loop", "event-loop-shard"])
def test_deadline_fires_by_timer_not_drain(backend):
    """Cooperative backends arm the expiry on their timer wheel: it must
    fire close to the deadline even though the parked request would
    otherwise never resume (the gate stays closed), proving there is a
    timer driving it and not a poll-on-next-completion."""
    gate = Future()

    def hold(svc, payload):
        return (yield Wait(gate))

    app = App(backend=backend, net_latency=0.0)
    app.add_service(ServiceSpec("gate", {"hold": hold}, n_workers=1))
    with app:
        t0 = time.monotonic()
        fut = app.send("gate", "hold", None, deadline=t0 + 0.05)
        with pytest.raises(DeadlineExceeded):
            fut.wait(timeout=5.0)
        elapsed = time.monotonic() - t0
        gate.set_result("open")  # release the parked generator
    assert elapsed >= 0.04, elapsed          # not failed eagerly
    assert elapsed < 1.0, elapsed            # fired by the timer, promptly


@pytest.mark.parametrize("backend", BACKEND_NAMES)
def test_deadline_propagates_to_nested_hops(backend):
    """An expired budget must cut the whole chain: the root's AsyncRpc to a
    second hop happens after the deadline passed, so the downstream call
    fails fast instead of doing dead work."""
    done_leaf = []

    def leaf(svc, payload):
        done_leaf.append(1)
        yield Compute(0.0)
        return "leaf"

    def root(svc, payload):
        yield Sleep(0.08)  # burn the whole budget before the hop
        f = yield AsyncRpc("leaf", "get", payload)
        return (yield Wait(f))

    app = App(backend=backend, net_latency=0.0)
    app.add_service(ServiceSpec("leaf", {"get": leaf}, n_workers=1))
    app.add_service(ServiceSpec("root", {"get": root}, n_workers=1))
    with app:
        fut = app.send("root", "get", None,
                       deadline=time.monotonic() + 0.02)
        with pytest.raises(DeadlineExceeded):
            fut.wait(timeout=5.0)
    assert not done_leaf  # the downstream hop never ran dead work


def test_policy_default_deadline_is_stamped():
    """With a ResiliencePolicy, sends that pass no explicit deadline get
    the policy default."""
    pol = ResiliencePolicy(deadline=0.02, breakers=False)
    app = _sleepy_app("fiber", leaf_sleep=0.3, resilience=pol)
    with app:
        with pytest.raises(DeadlineExceeded):
            app.send("root", "get").wait(timeout=5.0)
        assert app.backend_stats().timeouts >= 1


# -------------------------------------------------------------------- retries
def test_retry_succeeds_after_transient_failures():
    attempts = []

    def flaky(svc, payload):
        attempts.append(1)
        if len(attempts) < 3:
            raise RuntimeError("transient")
        return "ok"
        yield  # make it a generator

    pol = ResiliencePolicy(deadline=1.0, breakers=False,
                           retry=RetryPolicy(max_attempts=3,
                                             base_backoff=0.001))
    app = App(backend="fiber", net_latency=0.0, resilience=pol)
    app.add_service(ServiceSpec("flaky", {"get": flaky}, n_workers=1))
    with app:
        assert app.send("flaky", "get").wait(timeout=5.0) == "ok"
        assert app.backend_stats().retries == 2
    assert len(attempts) == 3


def test_retry_attempts_capped():
    attempts = []

    def dead(svc, payload):
        attempts.append(1)
        raise RuntimeError("permanent")
        yield

    pol = ResiliencePolicy(deadline=2.0, breakers=False,
                           retry=RetryPolicy(max_attempts=3,
                                             base_backoff=0.001))
    app = App(backend="fiber", net_latency=0.0, resilience=pol)
    app.add_service(ServiceSpec("dead", {"get": dead}, n_workers=1))
    with app:
        with pytest.raises(RuntimeError, match="permanent"):
            app.send("dead", "get").wait(timeout=5.0)
    assert len(attempts) == 3  # first try + 2 retries, then give up


def test_retry_budget_extinguishes_storm():
    """Under a hard outage the token bucket drains and retries dry up:
    total attempts stay bounded by sends + budget, not sends x attempts."""
    attempts = []

    def dead(svc, payload):
        attempts.append(1)
        raise RuntimeError("outage")
        yield

    pol = ResiliencePolicy(
        deadline=5.0, breakers=False,
        retry=RetryPolicy(max_attempts=4, base_backoff=0.0005,
                          budget_initial=3.0, budget_ratio=0.0))
    app = App(backend="fiber", net_latency=0.0, resilience=pol)
    app.add_service(ServiceSpec("dead", {"get": dead}, n_workers=1))
    with app:
        futs = [app.send("dead", "get") for _ in range(10)]
        for f in futs:
            with pytest.raises(RuntimeError):
                f.wait(timeout=5.0)
        stats = app.backend_stats()
    # 10 first tries + at most 3 budget tokens of retries
    assert len(attempts) <= 13, len(attempts)
    assert stats.retries <= 3, stats.retries


def test_deadline_exceeded_is_not_retried():
    pol = ResiliencePolicy(deadline=0.02, breakers=False,
                           retry=RetryPolicy(max_attempts=5,
                                             base_backoff=0.001))
    app = _sleepy_app("fiber", leaf_sleep=0.3, resilience=pol)
    with app:
        with pytest.raises(DeadlineExceeded):
            app.send("root", "get").wait(timeout=5.0)
        assert app.backend_stats().retries == 0


def test_retry_budget_unit():
    budget = RetryBudget(RetryPolicy(budget_initial=2.0, budget_ratio=0.5,
                                     budget_cap=3.0))
    assert budget.try_spend() and budget.try_spend()
    assert not budget.try_spend()          # drained
    for _ in range(10):
        budget.credit()                    # successes refill, capped
    assert budget.tokens == 3.0
    assert budget.try_spend()


def test_backoff_bounds():
    pol = RetryPolicy(base_backoff=0.002, max_backoff=0.05, jitter=0.5)
    for attempt in range(1, 12):
        d = pol.backoff_for(attempt)
        assert 0.0 <= d <= 0.05 * 1.5, (attempt, d)


# ------------------------------------------------------------------- breakers
def test_breaker_state_transitions_fake_clock():
    now = [0.0]
    br = CircuitBreaker(threshold=0.5, window=8, min_volume=4,
                        reset_timeout=1.0, clock=lambda: now[0])
    assert br.state == "closed"
    for _ in range(4):
        assert br.allow()
        br.record(False)
    assert br.state == "open"
    assert br.opens == 1
    assert not br.allow()                  # fail-fast while open
    now[0] = 0.5
    assert not br.allow()                  # still inside reset_timeout
    now[0] = 1.5
    assert br.allow()                      # admits the half-open probe
    assert br.state == "half-open"
    assert not br.allow()                  # ...but only one probe at a time
    br.record(False)                       # probe failed -> reopen
    assert br.state == "open"
    assert br.opens == 2
    now[0] = 3.0
    assert br.allow()
    br.record(True)                        # probe succeeded -> close
    assert br.state == "closed"
    for _ in range(4):                     # window was cleared on close
        assert br.allow()
        br.record(True)
    assert br.state == "closed"


def test_breaker_abort_probe_releases_slot():
    """A half-open probe aborted by a downstream open circuit must free
    the probe slot; otherwise the breaker is stuck half-open forever and
    the graph can never heal (regression: whole-app recovery deadlock)."""
    now = [0.0]
    br = CircuitBreaker(threshold=0.5, window=8, min_volume=4,
                        reset_timeout=1.0, clock=lambda: now[0])
    for _ in range(4):
        br.allow()
        br.record(False)
    now[0] = 2.0
    assert br.allow()                      # half-open probe admitted
    assert not br.allow()
    br.abort_probe()                       # probe died on a downstream edge
    assert br.state == "half-open"
    assert br.allow()                      # a fresh probe may go
    br.record(True)
    assert br.state == "closed"
    br.abort_probe()                       # no-op outside half-open
    assert br.state == "closed"


def test_breaker_graph_heals_after_outage():
    """Chain root->leaf: a leaf outage opens both edges (the root edge via
    the propagated real errors).  Once the leaf heals, the whole chain must
    close again within a few reset timeouts — half-open probes aborted by
    the still-open leaf edge must not wedge the root edge (regression:
    stuck half-open, ok-rate pinned at zero forever)."""
    healthy = threading.Event()

    def leaf(svc, payload):
        if not healthy.is_set():
            raise RuntimeError("outage")
        return "ok"
        yield

    def root(svc, payload):
        f = yield AsyncRpc("leaf", "get", payload)
        return (yield Wait(f))

    pol = ResiliencePolicy(deadline=2.0, breakers=True,
                           breaker_min_volume=4, breaker_window=8,
                           breaker_reset=0.05)
    app = App(backend="fiber", net_latency=0.0, resilience=pol)
    app.add_service(ServiceSpec("leaf", {"get": leaf}, n_workers=1))
    app.add_service(ServiceSpec("root", {"get": root}, n_workers=1))
    with app:
        for _ in range(30):  # drive both edges open
            try:
                app.send("root", "get").wait(timeout=5.0)
            except RuntimeError:  # includes CircuitOpenError
                pass
        assert app._breakers[("leaf", "get")].state != "closed"
        healthy.set()
        deadline = time.monotonic() + 5.0
        recovered = False
        while time.monotonic() < deadline:
            try:
                if app.send("root", "get").wait(timeout=5.0) == "ok":
                    recovered = True
                    break
            except RuntimeError:
                time.sleep(0.01)
        assert recovered
        assert app._breakers[("leaf", "get")].state == "closed"


@pytest.mark.parametrize("backend", BACKEND_NAMES)
def test_breaker_fail_fast_on_every_backend(backend):
    """A persistently failing destination must trip its per-edge breaker
    and subsequent sends must fail fast with CircuitOpenError — on all 8
    backends."""
    def bad(svc, payload):
        raise RuntimeError("always fails")
        yield

    pol = ResiliencePolicy(deadline=2.0, breakers=True,
                           breaker_min_volume=4, breaker_window=8,
                           breaker_reset=30.0)
    app = App(backend=backend, net_latency=0.0, resilience=pol)
    app.add_service(ServiceSpec("bad", {"get": bad}, n_workers=1))
    with app:
        opened = False
        for _ in range(30):
            try:
                app.send("bad", "get").wait(timeout=5.0)
            except CircuitOpenError:
                opened = True
                break
            except RuntimeError:
                continue
        assert opened
        assert app.backend_stats().breaker_opens >= 1
        # while open, the edge stays fail-fast
        with pytest.raises(CircuitOpenError):
            app.send("bad", "get").wait(timeout=5.0)


def test_downstream_open_circuit_does_not_trip_upstream():
    """CircuitOpenError raised by a downstream edge propagates to the
    caller but is NOT recorded as a failure of the upstream edge — open
    circuits must not cascade up the call graph."""
    def bad(svc, payload):
        raise RuntimeError("always fails")
        yield

    def mid(svc, payload):
        f = yield AsyncRpc("bad", "get", payload)
        try:
            return (yield Wait(f))
        except CircuitOpenError:
            raise  # downstream failing fast: surface it to the caller
        except RuntimeError:
            return "degraded"  # real downstream errors are handled here

    pol = ResiliencePolicy(deadline=2.0, breakers=True,
                           breaker_min_volume=4, breaker_window=8,
                           breaker_reset=30.0)
    app = App(backend="fiber", net_latency=0.0, resilience=pol)
    app.add_service(ServiceSpec("bad", {"get": bad}, n_workers=1))
    app.add_service(ServiceSpec("mid", {"get": mid}, n_workers=1))
    with app:
        saw_open = 0
        for _ in range(40):
            try:
                app.send("mid", "get").wait(timeout=5.0)
            except CircuitOpenError:
                saw_open += 1
        breakers = app._breakers
        assert breakers[("bad", "get")].state == "open"
        assert saw_open > 0  # the open downstream circuit did reach callers
        # ...but those CircuitOpenError replies must not count against the
        # mid edge: only 'bad' trips
        assert breakers[("mid", "get")].state == "closed"
        assert (app.backend_stats().breaker_opens
                == breakers[("bad", "get")].opens)


# ---------------------------------------------------------------- load level
def test_bounded_mailbox_rejects_excess():
    def slow(svc, payload):
        yield Sleep(0.2)
        return "ok"

    pol = ResiliencePolicy(deadline=5.0, breakers=False, mailbox_bound=2)
    app = App(backend="thread", net_latency=0.0, resilience=pol)
    app.add_service(ServiceSpec("slow", {"get": slow}, n_workers=4))
    with app:
        futs = [app.send("slow", "get") for _ in range(8)]
        outcomes = []
        for f in futs:
            try:
                outcomes.append(f.wait(timeout=5.0))
            except Rejected:
                outcomes.append("rejected")
        stats = app.backend_stats()
    assert outcomes.count("ok") == 2
    assert outcomes.count("rejected") == 6
    assert stats.rejections == 6


# -------------------------------------------------------------- timer thread
def test_timer_thread_orders_and_restarts():
    fired = []
    cond = threading.Condition()
    t = TimerThread(name="test-timer")

    def mark(tag):
        with cond:
            fired.append(tag)
            cond.notify()

    now = time.monotonic()
    t.push(now + 0.05, lambda: mark("late"))
    t.push(now + 0.01, lambda: mark("early"))
    with cond:
        assert cond.wait_for(lambda: len(fired) == 2, timeout=5.0)
    assert fired == ["early", "late"]
    t.stop()
    t.stop()  # idempotent
    # restartable: a push after stop lazily brings the thread back
    t.push(time.monotonic() + 0.01, lambda: mark("again"))
    with cond:
        assert cond.wait_for(lambda: len(fired) == 3, timeout=5.0)
    t.stop()


def test_timer_thread_callback_exception_does_not_kill_loop():
    fired = []
    cond = threading.Condition()
    t = TimerThread(name="test-timer-exc")

    def boom():
        raise RuntimeError("callback bug")

    def mark():
        with cond:
            fired.append(1)
            cond.notify()

    now = time.monotonic()
    t.push(now + 0.005, boom)
    t.push(now + 0.02, mark)
    with cond:
        assert cond.wait_for(lambda: fired, timeout=5.0)
    t.stop()


# ------------------------------------------------------------- goodput/overload
def test_goodput_classification():
    """Completions slower than the trial deadline are completed but not
    good; goodput excludes them without enforcement."""
    def slow(svc, payload):
        yield Sleep(0.05)
        return "ok"

    app = App(backend="fiber", net_latency=0.0)
    app.add_service(ServiceSpec("slow", {"get": slow}, n_workers=1))
    with app:
        tr = run_trial(app, lambda rng: ("slow", "get", None), rate=50,
                       duration=0.3, seed=11, deadline=0.01)
    assert tr.completed > 0, tr.row()
    assert tr.good == 0, tr.row()
    assert tr.goodput_rps == 0.0, tr.row()
    assert tr.offered >= tr.completed, tr.row()


def test_run_overload_smoke():
    """End-to-end overload harness on a tiny app: drives past the peak,
    reports goodput and recovers."""
    def fast(svc, payload):
        yield Compute(0.0)
        return "ok"

    pol = ResiliencePolicy(deadline=0.05, breakers=True,
                           retry=RetryPolicy(base_backoff=0.001))
    app = App(backend="fiber", net_latency=0.0, resilience=pol)
    app.add_service(ServiceSpec("fast", {"get": fast}, n_workers=1))
    with app:
        res = run_overload(app, lambda rng: ("fast", "get", None),
                           peak_rps=300.0, deadline=0.05, multiple=3.0,
                           duration=0.3, recovery_duration=0.15,
                           recovery_timeout=3.0, seed=12)
    assert res.overload_rps == pytest.approx(900.0)
    assert res.overload.offered > 0
    assert res.overload.goodput_rps >= 0.0
    assert res.recovered, res
    assert res.recovery_time < 3.0
    assert res.probes


def test_trial_row_mentions_resilience_counters():
    """The human row surfaces the new counters when they fire."""
    pol = ResiliencePolicy(deadline=0.01, breakers=False)
    app = _sleepy_app("fiber", leaf_sleep=0.2, resilience=pol)
    with app:
        tr = run_trial(app, lambda rng: ("root", "get", None), rate=30,
                       duration=0.2, seed=13, deadline=0.01,
                       enforce_deadline=True, drain=1.0)
    assert tr.errors > 0, tr.row()
    assert "to=" in tr.row(), tr.row()
