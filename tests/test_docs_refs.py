"""Docs cannot rot: resolve every code pointer in docs/ and README.

The documentation layer (PR 7) uses greppable pointers of the form
``path/to/file.py:Symbol`` or ``path/to/file.py:Class.method`` inside
inline code spans.  This test extracts every such span from ``docs/*.md``
and ``README.md``, checks the file exists, and — for ``.py`` targets with
a symbol — resolves the symbol against the module's AST (module-level
functions/classes, plus one level of class attributes/methods).  A doc
pointer to a renamed or deleted symbol fails here, in the fast lane,
instead of silently going stale.

Stdlib-only by design: ``ast`` parsing, no imports of the target modules
(so a doc pointer into an optional-dependency module still resolves).
"""
from __future__ import annotations

import ast
import glob
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# an inline span counts as a code pointer when it is exactly a repo path
# with a checked extension, optionally followed by :Symbol[.member]
_REF = re.compile(r"^([\w][\w./-]*\.(?:py|md|json|toml|yml|yaml))"
                  r"(?::([A-Za-z_][\w]*(?:\.[A-Za-z_][\w]*)*))?$")
_FENCE = re.compile(r"^(```|~~~)")
_SPAN = re.compile(r"`([^`\n]+)`")


def _doc_files():
    docs = sorted(glob.glob(os.path.join(REPO, "docs", "*.md")))
    readme = os.path.join(REPO, "README.md")
    assert docs, "docs/ directory has no markdown files"
    return docs + [readme]


def _spans(md_path):
    """Inline code spans outside fenced blocks, with line numbers."""
    out = []
    in_fence = False
    with open(md_path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            if _FENCE.match(line.strip()):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for m in _SPAN.finditer(line):
                out.append((lineno, m.group(1)))
    return out


def _refs(md_path):
    refs = []
    for lineno, span in _spans(md_path):
        m = _REF.match(span.strip())
        if m:
            refs.append((lineno, m.group(1), m.group(2)))
    return refs


def _module_symbols(py_path):
    """{name} for module-level defs/classes, {Class.member} one level."""
    with open(py_path, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=py_path)
    syms = set()
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            syms.add(node.name)
        elif isinstance(node, ast.ClassDef):
            syms.add(node.name)
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef,
                                    ast.ClassDef)):
                    syms.add(f"{node.name}.{sub.name}")
                elif isinstance(sub, ast.Assign):
                    for tgt in sub.targets:
                        if isinstance(tgt, ast.Name):
                            syms.add(f"{node.name}.{tgt.id}")
                elif isinstance(sub, ast.AnnAssign) and \
                        isinstance(sub.target, ast.Name):
                    syms.add(f"{node.name}.{sub.target.id}")
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    syms.add(tgt.id)
        elif isinstance(node, ast.AnnAssign) and \
                isinstance(node.target, ast.Name):
            syms.add(node.target.id)
    return syms


@pytest.mark.parametrize("md_path", _doc_files(),
                         ids=lambda p: os.path.relpath(p, REPO))
def test_all_code_pointers_resolve(md_path):
    refs = _refs(md_path)
    problems = []
    sym_cache = {}
    for lineno, rel, symbol in refs:
        target = os.path.join(REPO, rel)
        where = f"{os.path.relpath(md_path, REPO)}:{lineno}"
        if not os.path.isfile(target):
            problems.append(f"{where}: `{rel}` does not exist")
            continue
        if symbol is None:
            continue
        if not rel.endswith(".py"):
            problems.append(f"{where}: `{rel}:{symbol}` — symbol pointers "
                            f"only make sense for .py files")
            continue
        if rel not in sym_cache:
            sym_cache[rel] = _module_symbols(target)
        if symbol not in sym_cache[rel]:
            problems.append(f"{where}: `{rel}:{symbol}` — no such symbol "
                            f"(module-level or Class.member)")
    assert not problems, "stale doc pointers:\n" + "\n".join(problems)


def test_docs_actually_contain_symbol_pointers():
    """The doc layer's contract is greppable pointers — make sure the
    extraction regex keeps matching them (an extraction bug that matched
    nothing would make the resolution test pass vacuously)."""
    arch = os.path.join(REPO, "docs", "ARCHITECTURE.md")
    res = os.path.join(REPO, "docs", "RESILIENCE.md")
    n_arch = sum(1 for _, _, sym in _refs(arch) if sym)
    n_res = sum(1 for _, _, sym in _refs(res) if sym)
    assert n_arch >= 30, f"ARCHITECTURE.md has only {n_arch} symbol pointers"
    assert n_res >= 15, f"RESILIENCE.md has only {n_res} symbol pointers"
