"""App / OffloadPool / executor lifecycle tests.

Pins the three lifecycle bugfixes: a restartable OffloadPool (stop() used
to leave _started True with dead workers, and a stop() before start()
poisoned the queue with sentinels), an idempotent App.stop() with fail-fast
send() on a stopped app, and a full App stop -> start -> stop round trip —
offload futures included — on every registered backend (the benchmark
harnesses re-enter one App as a context manager between sweeps).
"""
import threading
import time

import pytest

from repro.core import (App, BACKEND_NAMES, Offload, ServiceSpec, Wait)
from repro.core.fiber import FiberScheduler
from repro.core.service import OffloadPool


# ------------------------------------------------------------- OffloadPool
def test_offload_pool_restarts_with_fresh_workers():
    """Regression: after stop() the workers had exited but _started stayed
    True, so a second start() was a no-op and every subsequent submit()
    future hung forever."""
    pool = OffloadPool(n_threads=2)
    pool.start()
    assert pool.submit(lambda: 1).wait(timeout=5) == 1
    first_threads = list(pool._threads)
    pool.stop()
    assert all(not t.is_alive() for t in first_threads)
    pool.start()                                   # must spawn fresh workers
    assert pool.submit(lambda: 2).wait(timeout=5) == 2
    assert all(t.is_alive() for t in pool._threads)
    assert not (set(pool._threads) & set(first_threads))
    pool.stop()


def test_offload_pool_stop_before_start_does_not_poison():
    """Regression: stop() on a never-started pool enqueued None sentinels
    that killed the workers the moment the pool later started."""
    pool = OffloadPool(n_threads=2)
    pool.stop()                                    # idempotent no-op
    pool.stop()
    pool.start()
    # both workers must be serving, not sentinel-killed: run more jobs than
    # one worker could if its sibling had eaten a stale sentinel and exited
    futs = [pool.submit(lambda i=i: i * i) for i in range(8)]
    assert [f.wait(timeout=5) for f in futs] == [i * i for i in range(8)]
    assert sum(t.is_alive() for t in pool._threads) == 2
    pool.stop()


def test_offload_pool_drains_stale_sentinels_but_keeps_queued_work():
    """A sentinel left over from a missed shutdown must be swallowed on
    start(); real work queued while stopped must survive, in order."""
    pool = OffloadPool(n_threads=1)
    fut_before = pool.submit(lambda: "queued-while-stopped")
    pool._q.put(None)                              # simulate stale poison
    fut_after = pool.submit(lambda: "also-queued")
    pool.start()
    assert fut_before.wait(timeout=5) == "queued-while-stopped"
    assert fut_after.wait(timeout=5) == "also-queued"
    assert pool._threads[0].is_alive()             # sentinel did not kill it
    pool.stop()


def test_offload_pool_start_and_stop_are_idempotent():
    pool = OffloadPool(n_threads=2)
    pool.start()
    threads = list(pool._threads)
    pool.start()                                   # second start: no-op
    assert pool._threads == threads
    pool.stop()
    pool.stop()                                    # second stop: no-op
    # and no sentinel pile-up from the double stop: restart still works
    pool.start()
    assert pool.submit(lambda: "ok").wait(timeout=5) == "ok"
    pool.stop()


# ---------------------------------------------------------- FiberScheduler
def test_fiber_scheduler_restarts_after_stop():
    """Regression: start() did not reset the stop latch, so a restarted
    scheduler's thread exited at its first idle check."""
    s = FiberScheduler(app=None, name="restart")
    s.start()
    s.stop()
    assert not s._thread.is_alive()
    s.start()
    try:
        def body():
            return "alive"
            yield  # pragma: no cover - marks this as a generator
        assert s.spawn_external(body()).wait(timeout=5) == "alive"
    finally:
        s.stop()


# ------------------------------------------------------------- App lifecycle
def _offload_square(svc, payload):
    f = yield Offload(lambda x: x * x, (payload,))
    v = yield Wait(f)
    return v


def _tiny_app(backend):
    app = App(backend=backend)
    app.add_service(ServiceSpec("svc", {"sq": _offload_square}, n_workers=2))
    return app


def test_app_stop_is_idempotent():
    app = _tiny_app("fiber")
    app.start()
    app.stop()
    app.stop()                                     # must not re-join/poison
    app.start()                                    # and must not break restart
    assert app.send("svc", "sq", 4).wait(timeout=10) == 16
    app.stop()


def test_app_start_is_idempotent():
    app = _tiny_app("thread")
    app.start()
    n_offload = len(app.offload_pool._threads)
    app.start()                                    # no duplicate workers
    assert len(app.offload_pool._threads) == n_offload
    assert app.send("svc", "sq", 3).wait(timeout=10) == 9
    app.stop()


def test_send_on_stopped_app_fails_fast():
    """A send into a stopped app must resolve exceptionally at once — not
    park a delivery in a dead executor's mailbox and hang blocking waiters."""
    app = _tiny_app("fiber")
    reply = app.send("svc", "sq", 2)               # never started
    assert reply.done                              # fail-fast, no hang window
    with pytest.raises(RuntimeError, match="not started"):
        reply.wait(timeout=1)
    with app:
        assert app.send("svc", "sq", 2).wait(timeout=10) == 4
    t0 = time.perf_counter()
    reply = app.send("svc", "sq", 2)               # stopped again
    with pytest.raises(RuntimeError, match="not started"):
        reply.wait(timeout=5)
    assert time.perf_counter() - t0 < 1.0          # failed fast, no timeout


@pytest.mark.parametrize("backend", BACKEND_NAMES)
def test_stop_start_stop_round_trip_resolves_offloads(backend):
    """Context-manager re-entry (what the benchmark harnesses do when they
    reuse an App) must serve identical results — offload futures resolved —
    in both lives, on every backend."""
    app = _tiny_app(backend)
    with app:
        first = [app.send("svc", "sq", i).wait(timeout=10) for i in range(6)]
    with app:
        second = [app.send("svc", "sq", i).wait(timeout=10) for i in range(6)]
    assert first == second == [i * i for i in range(6)]


def test_concurrent_offloads_survive_restart_cycles():
    """Offload futures submitted in each life of the pool all resolve, even
    across several stop/start cycles with work in flight."""
    app = _tiny_app("fiber-batch-cq")
    for cycle in range(3):
        with app:
            futs = [app.send("svc", "sq", i) for i in range(10)]
            done = threading.Event()

            def waiter():
                for i, f in enumerate(futs):
                    assert f.wait(timeout=10) == i * i
                done.set()

            t = threading.Thread(target=waiter)
            t.start()
            t.join(timeout=15)
            assert done.is_set(), f"cycle {cycle}: offload futures unresolved"
