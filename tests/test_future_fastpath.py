"""Future fast-path unit tests + zero-handoff inline execution tests.

The PR 4 Future resolves locklessly (value-then-done-flag publication) and
materializes its ``threading.Condition`` only on the first *blocking*
waiter, so the cooperative backends never touch a kernel sync object on the
happy path.  These tests hammer the racy seams of that design — resolve vs
blocking-wait, callback registration vs resolve — and pin down the
semantics of :class:`CompletedFuture` and of same-carrier call inlining
(parity, budget, counters) across the whole backend matrix.
"""
import threading

import numpy as np
import pytest

from repro.apps import APP_NAMES, BENCH_BACKENDS, get_app_def
from repro.core import CompletedFuture, Future
from repro.core.future import FutureError


# ------------------------------------------------------------ lazy Condition
def test_resolve_before_wait_never_materializes_condition():
    f = Future()
    f.set_result(1)
    assert f.wait() == 1
    assert f.result() == 1
    assert not f.blocking_waited()       # the fast-future classification


def test_blocking_wait_materializes_condition_exactly_for_blockers():
    f = Future()
    threading.Timer(0.05, f.set_result, args=("x",)).start()
    assert f.wait(timeout=2.0) == "x"
    assert f.blocking_waited()


def test_wait_done_is_a_blocking_wait():
    f = Future()
    threading.Timer(0.05, f.set_result, args=(None,)).start()
    assert f.wait_done(timeout=2.0)
    assert f.blocking_waited()
    # but wait_done on an already-done future takes the lock-free path
    g = Future()
    g.set_result(3)
    assert g.wait_done()
    assert not g.blocking_waited()


def test_wait_timeout_raises_and_future_still_resolvable():
    f = Future()
    with pytest.raises(TimeoutError):
        f.wait(timeout=0.02)
    f.set_result("late")
    assert f.wait() == "late"


def test_double_resolve_raises():
    f = Future()
    f.set_result(1)
    with pytest.raises(FutureError):
        f.set_result(2)
    with pytest.raises(FutureError):
        f.set_exception(ValueError("no"))


# --------------------------------------------------- cross-thread races
def test_cross_thread_resolve_wait_race():
    """Many futures resolved by one thread while another blocks on each
    with no sleep anywhere: every wait must return, none may hang on a
    lost notify (the lazy-Condition publication order is what prevents
    that)."""
    futures = [Future() for _ in range(500)]

    def resolver():
        for i, f in enumerate(futures):
            f.set_result(i)

    t = threading.Thread(target=resolver)
    t.start()
    got = [f.wait(timeout=10) for f in futures]
    t.join()
    assert got == list(range(500))


def test_callback_vs_resolve_race_fires_exactly_once():
    """Register a callback from one thread while another resolves: the
    callback must fire exactly once whichever side wins the race."""
    for trial in range(300):
        f = Future()
        fired = []
        barrier = threading.Barrier(2)

        def register():
            barrier.wait()
            f.add_done_callback(lambda fut: fired.append(fut.result()))

        def resolve():
            barrier.wait()
            f.set_result(trial)

        ts = [threading.Thread(target=register),
              threading.Thread(target=resolve)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert fired == [trial]


def test_callbacks_fire_in_registration_order():
    f = Future()
    seen = []
    for i in range(5):
        f.add_done_callback(lambda fut, i=i: seen.append(i))
    f.set_result(None)
    assert seen == list(range(5))
    # after resolution: immediate, still ordered after the drained ones
    f.add_done_callback(lambda fut: seen.append(5))
    assert seen == list(range(6))


def test_callback_registered_inside_callback_fires():
    f = Future()
    seen = []
    f.add_done_callback(
        lambda fut: f.add_done_callback(lambda g: seen.append("inner")))
    f.set_result(None)
    assert seen == ["inner"]


# ------------------------------------------------------- CompletedFuture
def test_completed_future_value():
    f = CompletedFuture(42)
    assert f.done
    assert f.result() == 42
    assert f.wait() == 42
    assert not f.blocking_waited()
    seen = []
    f.add_done_callback(lambda fut: seen.append(fut.result()))
    assert seen == [42]


def test_completed_future_exception_propagates():
    f = CompletedFuture(exc=ValueError("inline boom"))
    assert f.done
    with pytest.raises(ValueError, match="inline boom"):
        f.result()
    with pytest.raises(ValueError, match="inline boom"):
        f.wait()
    # callback path: fires immediately; the callback sees the exception
    caught = []
    def cb(fut):
        try:
            fut.result()
        except ValueError as e:
            caught.append(str(e))
    f.add_done_callback(cb)
    assert caught == ["inline boom"]


def test_completed_future_rejects_second_resolve():
    f = CompletedFuture(1)
    with pytest.raises(FutureError):
        f.set_result(2)


# -------------------------------------------- multi-waiter exception re-raise
def _tb_depth(exc):
    n, tb = 0, exc.__traceback__
    while tb is not None:
        n, tb = n + 1, tb.tb_next
    return n


def _failed_future():
    f = Future()
    try:
        raise ValueError("stored boom")
    except ValueError as exc:
        f.set_exception(exc)
    return f


def test_repeated_reraise_does_not_grow_traceback():
    """Regression: wait()/result() re-raised the *same* stored exception
    object, so every catch appended the raising frames to the shared
    __traceback__ and a wait->catch->wait loop grew it without bound.  Each
    re-raise must restore the traceback snapshot taken at set_exception
    time."""
    f = _failed_future()
    depths = []
    for _ in range(6):
        for getter in (f.result, f.wait):
            try:
                getter()
            except ValueError as exc:
                depths.append(_tb_depth(exc))
    assert len(set(depths)) == 1, f"traceback grew across re-raises: {depths}"


def test_concurrent_waiters_see_bounded_tracebacks():
    """Many threads blocking-wait on one failed future: no cross-waiter
    traceback growth (each re-raise starts from the stored snapshot, so the
    observed depth is bounded regardless of how raises interleave)."""
    f = Future()
    n = 8
    barrier = threading.Barrier(n + 1)
    depths = []
    lock = threading.Lock()

    def waiter():
        barrier.wait()
        for _ in range(50):
            try:
                f.wait(timeout=5)
            except ValueError as exc:
                with lock:
                    depths.append(_tb_depth(exc))

    threads = [threading.Thread(target=waiter) for _ in range(n)]
    for t in threads:
        t.start()
    barrier.wait()
    try:
        raise ValueError("concurrent boom")
    except ValueError as exc:
        baseline_depth = _tb_depth(exc)
        f.set_exception(exc)
    for t in threads:
        t.join()
    assert len(depths) == n * 50
    # each re-raise restores the snapshot before propagating, so a traceback
    # can carry at most the frames of the raises in flight *right now* (≤ 2
    # per concurrent waiter) on top of it — never a chain compounded across
    # the 50 iterations, which under the old `raise self._exc` discipline
    # grew past n * iterations frames
    assert max(depths) <= baseline_depth + 2 * n, (min(depths), max(depths))


# ----------------------------------------- inline execution: app-level
def _fixed_requests(app_name, n=3):
    factory = get_app_def(app_name).make_request_factory("mixed")
    rng = np.random.default_rng(12)
    return [factory(rng) for _ in range(n)]


def _run(app_name, backend, requests, inline_budget=None):
    d = get_app_def(app_name)
    app = d.build(backend)
    if inline_budget is not None:
        app.inline_budget = inline_budget
    with app:
        out = [app.send(dest, m, p).wait(timeout=15)
               for dest, m, p in requests]
        stats = app.backend_stats()
    return out, stats


@pytest.mark.parametrize("app_name", APP_NAMES)
def test_inline_and_noninline_execution_are_identical(app_name):
    """The zero-handoff fast path changes scheduling, never semantics:
    inlined (default) and non-inlined (budget 0, the PR 3 carrier path)
    execution must return identical results on every backend, and both
    must match the thread baseline."""
    requests = _fixed_requests(app_name)
    baseline, _ = _run(app_name, "thread", requests)
    for backend in BENCH_BACKENDS:
        inlined, st_on = _run(app_name, backend, requests)
        carried, st_off = _run(app_name, backend, requests, inline_budget=0)
        assert inlined == baseline, f"{backend} inlined diverged"
        assert carried == baseline, f"{backend} carrier-path diverged"
        assert st_off.inline_calls == 0  # budget 0 really disables it
        if backend in ("fiber", "fiber-steal", "event-loop",
                       "event-loop-shard"):
            assert st_on.inline_calls > 0, f"{backend} never inlined"


def test_inline_budget_bounds_depth():
    """A chain deeper than the budget must fall back to the carrier path
    beyond the budget (and still return the right answer)."""
    from repro.core import App, AsyncRpc, ServiceSpec, Wait

    DEPTH = 8

    def _hop(svc, payload):
        if payload == 0:
            return 0
            yield  # pragma: no cover - marks this as a generator
        f = yield AsyncRpc(f"hop{payload - 1}", "go", payload - 1)
        v = yield Wait(f)
        return v + 1

    app = App(backend="fiber", inline_budget=3)
    for i in range(DEPTH):
        app.add_service(ServiceSpec(f"hop{i}", {"go": _hop}, n_workers=1))
    with app:
        assert app.send(f"hop{DEPTH - 1}", "go",
                        DEPTH - 1).wait(timeout=10) == DEPTH - 1
        st = app.backend_stats()
    assert st.inline_depth_hwm == 3          # gauge capped by the budget
    assert 0 < st.inline_calls < DEPTH - 1   # some hops had to fall back


def test_thread_callee_is_not_inlined():
    """Thread-family services decline inline execution — their kernel
    dispatch cost is the paper's baseline and must stay measured."""
    from repro.core import App, ServiceSpec, sync_rpc

    def _leaf(svc, payload):
        return payload
        yield  # pragma: no cover - marks this as a generator

    def _front(svc, payload):
        v = yield from sync_rpc("leaf", "go", payload)
        return v

    app = App(backend="fiber")
    app.add_service(ServiceSpec("front", {"go": _front}, n_workers=1))
    app.add_service(ServiceSpec("leaf", {"go": _leaf}, n_workers=1,
                                backend="thread"))
    with app:
        assert app.send("front", "go", 7).wait(timeout=10) == 7
        st = app.backend_stats()
        # never inlined: the call went through the thread service's mailbox
        # (carrier *elision* still applies on the caller side — the reply
        # future is handed over directly, so no carrier fiber either)
        assert st.inline_calls == 0
        assert app.services["leaf"].requests == 1


def test_net_latency_disables_the_fast_path():
    """A simulated network hop means the call is not co-located: the full
    carrier path (which pays the hop) must run."""
    from repro.apps import build_socialnetwork

    app = build_socialnetwork("fiber", net_latency=0.0005)
    with app:
        out = app.send("frontend", "compose", {"text": "t"}).wait(timeout=10)
        st = app.backend_stats()
    assert out == {"post_id": 42}
    assert st.inline_calls == 0
    assert st.spawns == 9  # one carrier fiber per async call, as in PR 3
