"""Render EXPERIMENTS.md tables from dryrun.json.

    PYTHONPATH=src python launch_results/render_tables.py [--mesh pod1]
"""
import argparse
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))


def fmt_t(s):
    if s >= 1:
        return f"{s:.1f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.1f}ms"
    return f"{s * 1e6:.0f}us"


def fmt_b(b):
    if b >= 2**30:
        return f"{b / 2**30:.1f}G"
    return f"{b / 2**20:.0f}M"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None, choices=(None, "pod1", "pod2"))
    ap.add_argument("--variants", action="store_true")
    args = ap.parse_args()
    with open(os.path.join(HERE, "dryrun.json")) as f:
        results = json.load(f)

    print("| arch | shape | mesh | mem/dev (tpu-corr) | t_compute | t_memory "
          "| t_coll | dominant | useful-FLOP ratio | roofline frac |")
    print("|---|---|---|---:|---:|---:|---:|---|---:|---:|")
    for key in sorted(results):
        is_variant = "#" in key
        if is_variant != args.variants:
            continue
        rec = results[key]
        parts = key.split("#")[0].split("|")
        arch, shape, mesh = parts
        if args.mesh and mesh != args.mesh:
            continue
        suffix = ("#" + key.split("#")[1]) if is_variant else ""
        if rec.get("status") == "skip":
            print(f"| {arch} | {shape} | {mesh} | — | — | — | — | "
                  f"SKIP (quadratic) | — | — |")
            continue
        if rec.get("status") != "ok":
            print(f"| {arch} | {shape} | {mesh} | ERROR | | | | | | |")
            continue
        r = rec["roofline"]
        mem = rec.get("memory_tpu_corrected", rec.get("memory", {})) \
            .get("per_device_total_bytes", 0)
        flag = " (!)" if mem > 16 * 2**30 else ""
        print(f"| {arch}{suffix} | {shape} | {mesh} | {fmt_b(mem)}{flag} "
              f"| {fmt_t(r['t_compute_s'])} | {fmt_t(r['t_memory_s'])} "
              f"| {fmt_t(r['t_collective_s'])} | {r['dominant']} "
              f"| {r['useful_flops_ratio']:.2f} "
              f"| {r['roofline_fraction']:.3f} |")


if __name__ == "__main__":
    main()
