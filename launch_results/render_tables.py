"""Render EXPERIMENTS.md tables from dryrun.json or benchmark CSV.

    PYTHONPATH=src python launch_results/render_tables.py [--mesh pod1]
    PYTHONPATH=src python launch_results/render_tables.py \
        --bench bench.csv [--app hotelreservation]

``--bench`` consumes the ``name,us_per_call,derived`` CSV emitted by
``benchmarks/run.py`` and renders one backend-matrix markdown table per app
(peak throughput per workload for every backend + gains vs the thread
baseline, then the p99 sweep).
"""
import argparse
import json
import os
import re
from collections import defaultdict

HERE = os.path.dirname(os.path.abspath(__file__))

# canonical column order for the backend matrix; backends the CSV mentions
# that are not listed here (future registry entries) are appended sorted.
BACKEND_ORDER = ["thread", "thread-pool", "fiber", "fiber-steal",
                 "fiber-batch", "fiber-batch-cq", "event-loop",
                 "event-loop-shard"]


def _order_backends(found):
    known = [b for b in BACKEND_ORDER if b in found]
    return known + sorted(set(found) - set(known))


def fmt_t(s):
    if s >= 1:
        return f"{s:.1f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.1f}ms"
    return f"{s * 1e6:.0f}us"


def fmt_b(b):
    if b >= 2**30:
        return f"{b / 2**30:.1f}G"
    return f"{b / 2**20:.0f}M"


def _parse_derived(derived):
    """'rps=1234;p50_us=5.1' -> {'rps': 1234.0, 'p50_us': 5.1}"""
    out = {}
    for part in derived.split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


def render_bench(path, app_filter=None):
    """Render per-app backend-matrix tables from benchmarks/run.py CSV."""
    peaks = defaultdict(dict)   # (app, workload) -> backend -> rps
    gains = defaultdict(dict)   # (app, workload) -> backend -> gain vs thread
    p99s = defaultdict(list)    # app -> (workload, backend, rate, p99, p50)
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith(("#", "name,")):
                continue
            name, value, derived = line.split(",", 2)
            d = _parse_derived(derived)
            m = re.match(r"peak_throughput/([^/]+)/([^/]+)/([^/,@]+)$", name)
            if m:
                app, wl, backend = m.groups()
                if backend.endswith("_gain"):
                    # "fiber_gain", "fiber-steal_gain", ... vs thread baseline
                    gains[(app, wl)][backend[:-len("_gain")]] = float(value)
                else:
                    peaks[(app, wl)][backend] = d.get("rps", 0.0)
                continue
            m = re.match(r"p99_latency/([^/]+)/([^/]+)/([^@]+)@(\d+)rps$",
                         name)
            if m:
                app, wl, backend, rate = m.groups()
                p99s[app].append((wl, backend, float(rate), float(value),
                                  d.get("p50_us", float("nan"))))

    available = sorted({a for a, _ in peaks} | set(p99s))
    apps = available
    if app_filter:
        wanted = [a for v in app_filter for a in v.split(",") if a]
        apps = [a for a in available if a in wanted]
        missing = sorted(set(wanted) - set(available))
        if missing:
            raise SystemExit(
                f"no benchmark rows for app(s) {missing} "
                f"(CSV has: {available})")
    for app in apps:
        print(f"### {app}\n")
        wls = [wl for (a, wl) in peaks if a == app]
        if wls:
            backends = _order_backends(
                {b for wl in wls for b in peaks[(app, wl)]})
            gain_cols = [b for b in backends if b != "thread"]
            header = ("| workload | "
                      + " | ".join(f"{b} rps" for b in backends)
                      + " | "
                      + " | ".join(f"{b} gain" for b in gain_cols) + " |")
            print(header)
            print("|---" + "|---:" * (len(backends) + len(gain_cols)) + "|")
            for wl in wls:
                row = peaks[(app, wl)]
                g = gains.get((app, wl), {})
                cells = [f"{row.get(b, 0):.0f}" for b in backends]
                cells += [f"{g.get(b, float('nan')):.2f}x"
                          for b in gain_cols]
                print(f"| {wl} | " + " | ".join(cells) + " |")
            print()
        if p99s.get(app):
            print("| workload | backend | offered rps | p99 | p50 |")
            print("|---|---|---:|---:|---:|")
            for wl, backend, rate, p99, p50 in p99s[app]:
                print(f"| {wl} | {backend} | {rate:.0f} "
                      f"| {fmt_t(p99 * 1e-6)} | {fmt_t(p50 * 1e-6)} |")
            print()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None, choices=(None, "pod1", "pod2"))
    ap.add_argument("--variants", action="store_true")
    ap.add_argument("--bench", default=None, metavar="CSV",
                    help="render app benchmark tables from run.py output")
    ap.add_argument("--app", action="append", default=None,
                    help="with --bench: restrict to these apps")
    args = ap.parse_args()
    if args.bench:
        render_bench(args.bench, app_filter=args.app)
        return
    with open(os.path.join(HERE, "dryrun.json")) as f:
        results = json.load(f)

    print("| arch | shape | mesh | mem/dev (tpu-corr) | t_compute | t_memory "
          "| t_coll | dominant | useful-FLOP ratio | roofline frac |")
    print("|---|---|---|---:|---:|---:|---:|---|---:|---:|")
    for key in sorted(results):
        is_variant = "#" in key
        if is_variant != args.variants:
            continue
        rec = results[key]
        parts = key.split("#")[0].split("|")
        arch, shape, mesh = parts
        if args.mesh and mesh != args.mesh:
            continue
        suffix = ("#" + key.split("#")[1]) if is_variant else ""
        if rec.get("status") == "skip":
            print(f"| {arch} | {shape} | {mesh} | — | — | — | — | "
                  f"SKIP (quadratic) | — | — |")
            continue
        if rec.get("status") != "ok":
            print(f"| {arch} | {shape} | {mesh} | ERROR | | | | | | |")
            continue
        r = rec["roofline"]
        mem = rec.get("memory_tpu_corrected", rec.get("memory", {})) \
            .get("per_device_total_bytes", 0)
        flag = " (!)" if mem > 16 * 2**30 else ""
        print(f"| {arch}{suffix} | {shape} | {mesh} | {fmt_b(mem)}{flag} "
              f"| {fmt_t(r['t_compute_s'])} | {fmt_t(r['t_memory_s'])} "
              f"| {fmt_t(r['t_collective_s'])} | {r['dominant']} "
              f"| {r['useful_flops_ratio']:.2f} "
              f"| {r['roofline_fraction']:.3f} |")


if __name__ == "__main__":
    main()
