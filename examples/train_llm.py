"""Train a small LM end-to-end with checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_llm.py [--arch olmoe-1b-7b] [--steps 200]

Uses the reduced (smoke) config of the chosen architecture so it runs on
CPU; the same driver scales to the production mesh via launch/train.py.
Kill it mid-run and re-run: it resumes from the last async checkpoint.
"""
import argparse
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()
    # delegate to the launcher (same code path as production)
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", args.arch, "--smoke",
           "--steps", str(args.steps), "--batch", str(args.batch),
           "--seq", str(args.seq), "--save-every", "25",
           "--ckpt-dir", f"/tmp/repro_ckpt_{args.arch}"]
    sys.exit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
