"""DeathStarBench reproduction driver — any registered app, every backend.

Measures peak throughput (paper Fig. 1) and p99-vs-rate (paper Fig. 2)
for each of the app's request generators under every registered async
backend (thread, thread-pool, fiber, fiber-steal, fiber-batch,
fiber-batch-cq, event-loop, event-loop-shard).

    PYTHONPATH=src python examples/deathstarbench.py \
        --app {socialnetwork,hotelreservation,mediaservice} [--quick] \
        [--backend fiber --backend fiber-batch]
"""
import argparse

from repro.apps import APP_NAMES, BENCH_BACKENDS, build_bench_app, get_app_def
from repro.core import find_peak_throughput, latency_sweep, warmup


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="socialnetwork", choices=APP_NAMES)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--workloads", nargs="*", default=None)
    ap.add_argument("--backend", action="append", default=None,
                    choices=BENCH_BACKENDS,
                    help="backends to sweep (default: all registered)")
    args = ap.parse_args(argv)
    duration = 0.6 if args.quick else 1.2
    backends = tuple(args.backend) if args.backend else BENCH_BACKENDS

    d = get_app_def(args.app)
    workloads = args.workloads or list(d.workloads)
    print(f"=== app: {d.name} ({d.description}) ===")

    print("=== peak throughput (paper Fig. 1) ===")
    peaks = {}
    for wl in workloads:
        factory = d.make_request_factory(wl)
        for backend in backends:
            with build_bench_app(d.name, backend) as app:
                warmup(app, factory)
                pk = find_peak_throughput(app, factory, start_rate=200,
                                          duration=duration)
            peaks[(wl, backend)] = pk.peak_rps
            print(f"  {wl:10s} {backend:11s}: {pk.peak_rps:8.0f} rps")
        base = peaks.get((wl, "thread"))
        if base:
            for backend in backends:
                if backend == "thread":
                    continue
                gain = peaks[(wl, backend)] / max(base, 1e-9)
                print(f"  {wl:10s} {backend} gain: {gain:.2f}x")

    print("\n=== p99 latency vs offered rate (paper Fig. 2) ===")
    for wl in workloads:
        factory = d.make_request_factory(wl)
        ref_peak = peaks[(wl, backends[0])]
        rates = [ref_peak * f for f in (0.2, 0.5, 0.8)]
        for backend in backends:
            with build_bench_app(d.name, backend) as app:
                warmup(app, factory)
                rows = latency_sweep(app, factory, rates, duration=duration)
            for tr in rows:
                print(f"  {wl:10s} {backend:11s} @{tr.offered_rps:7.0f} rps: "
                      f"p99={tr.p99 * 1e3:9.2f} ms")


if __name__ == "__main__":
    main()
