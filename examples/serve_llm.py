"""End-to-end driver: serve a small LLM with batched requests.

Boots the full microservice model server (api -> tokenizer -> continuous-
batching engine -> detokenizer) on the fiber runtime and pushes a batch of
concurrent requests through it.

    PYTHONPATH=src python examples/serve_llm.py [--backend thread] [--arch rwkv6-3b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import Model
from repro.serving import ServeConfig, build_llm_app


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCH_IDS)
    ap.add_argument("--backend", default="fiber",
                    choices=("fiber", "thread"))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).with_(remat=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name} ({model.count_params() / 1e6:.1f}M params) "
          f"on the {args.backend} backend")

    scfg = ServeConfig(max_batch=4, max_len=96, prefill_bucket=16,
                       max_new_tokens=args.max_new)
    app = build_llm_app(model, params, scfg, backend=args.backend)
    with app:
        app.send("engine", "run", None)
        app.send("api", "generate", {"text": "warmup"}).wait(timeout=300)

        t0 = time.perf_counter()
        futs = [app.send("api", "generate",
                         {"text": f"tell me a story about pod {i}"})
                for i in range(args.requests)]
        outs = [f.wait(timeout=600) for f in futs]
        dt = time.perf_counter() - t0

        for i, out in enumerate(outs[:3]):
            print(f"  req{i}: tokens={out['tokens']}")
        eng = app.services["engine"].state["engine"]
        print(f"{args.requests} requests in {dt:.2f}s "
              f"({eng.generated / dt:.1f} tok/s, "
              f"{eng.steps} continuous-batch steps)")
        app.services["engine"].state["stop"] = True


if __name__ == "__main__":
    main()
