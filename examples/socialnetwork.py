"""DeathStarBench SocialNetwork reproduction — the paper's evaluation.

Measures peak throughput (paper Fig. 1) and p99-vs-rate (paper Fig. 2)
for the four request generators under both async backends.

    PYTHONPATH=src python examples/socialnetwork.py [--quick]
"""
import argparse

from repro.apps import WORKLOADS, build_socialnetwork, make_request_factory
from repro.core import find_peak_throughput, latency_sweep, run_trial


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--workloads", nargs="*", default=list(WORKLOADS))
    args = ap.parse_args()
    duration = 0.6 if args.quick else 1.2

    print("=== peak throughput (paper Fig. 1) ===")
    peaks = {}
    for wl in args.workloads:
        for backend in ("thread", "fiber"):
            app = build_socialnetwork(
                backend,
                n_workers=8 if backend == "thread" else 2,
                frontend_workers=16 if backend == "thread" else 2)
            with app:
                run_trial(app, make_request_factory(wl), 100, 0.3)  # warmup
                pk = find_peak_throughput(app, make_request_factory(wl),
                                          start_rate=200, duration=duration)
            peaks[(wl, backend)] = pk.peak_rps
            print(f"  {wl:10s} {backend:7s}: {pk.peak_rps:8.0f} rps")
        gain = peaks[(wl, 'fiber')] / max(peaks[(wl, 'thread')], 1e-9)
        print(f"  {wl:10s} fiber gain: {gain:.2f}x")

    print("\n=== p99 latency vs offered rate (paper Fig. 2) ===")
    for wl in args.workloads:
        thread_peak = peaks[(wl, "thread")]
        rates = [thread_peak * f for f in (0.2, 0.5, 0.8)]
        for backend in ("thread", "fiber"):
            app = build_socialnetwork(
                backend,
                n_workers=8 if backend == "thread" else 2,
                frontend_workers=16 if backend == "thread" else 2)
            with app:
                run_trial(app, make_request_factory(wl), 100, 0.3)
                rows = latency_sweep(app, make_request_factory(wl), rates,
                                     duration=duration)
            for tr in rows:
                print(f"  {wl:10s} {backend:7s} @{tr.offered_rps:7.0f} rps: "
                      f"p99={tr.p99 * 1e3:9.2f} ms")


if __name__ == "__main__":
    main()
