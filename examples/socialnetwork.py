"""DeathStarBench SocialNetwork reproduction — the paper's evaluation.

Thin wrapper over the app-generic driver; kept for backwards compatibility.

    PYTHONPATH=src python examples/socialnetwork.py [--quick]

Equivalent to ``examples/deathstarbench.py --app socialnetwork``; see that
driver for HotelReservation and MediaService.
"""
import sys

from deathstarbench import main as dsb_main


def main():
    dsb_main(["--app", "socialnetwork"] + sys.argv[1:])


if __name__ == "__main__":
    main()
