"""Quickstart: the paper's technique in 60 lines.

Build a tiny microservice app, run it on the thread backend (DeathStarBench
std::async baseline) and the fiber backend (the paper's boost::fiber fix),
and watch the async-call spawn cost difference.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.core import (App, AsyncRpc, Compute, ServiceSpec, Sleep, Wait,
                        WaitAll)


# 1. Write service handlers ONCE as effect generators.
def fetch(svc, payload):
    yield Compute(20e-6)           # a little CPU work (serialization)
    yield Sleep(300e-6)            # wait-dominated I/O (cache round trip)
    return {"item": payload}


def frontpage(svc, payload):
    # the ComposePost pattern: fan out async RPCs, join them all
    futs = []
    for i in range(6):
        f = yield AsyncRpc("store", "fetch", i)
        futs.append(f)
    items = yield WaitAll(futs)
    return {"items": [x["item"] for x in items]}


def build(backend):
    app = App(backend=backend)
    app.add_service(ServiceSpec("store", {"fetch": fetch}, n_workers=2))
    app.add_service(ServiceSpec("front", {"page": frontpage}, n_workers=4))
    return app


# 2. Same app, two execution backends.
for backend in ("thread", "fiber"):
    with build(backend) as app:
        app.send("front", "page", None).wait(timeout=10)   # warmup
        t0 = time.perf_counter()
        n = 300
        futs = [app.send("front", "page", None) for _ in range(n)]
        for f in futs:
            f.wait(timeout=30)
        dt = time.perf_counter() - t0
        print(f"{backend:7s}: {n / dt:8.0f} req/s  "
              f"({app.total_spawns()} async-call carriers spawned)")

print("\nfibers win because each async call is a deque push, not a clone().")
