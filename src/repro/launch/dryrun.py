import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# (the two lines above MUST run before any jax import — jax locks the device
# count at first initialization)
import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax               # noqa: E402

from ..configs import ARCH_IDS, get_config                      # noqa: E402
from ..distributed import use_sharding                          # noqa: E402
from ..distributed.sharding import (cache_shardings,            # noqa: E402
                                    param_shardings,
                                    step_in_shardings)
from ..models import Model, shape_by_name                       # noqa: E402
from ..models.config import ALL_SHAPES                          # noqa: E402
from ..training import AdamWConfig, adamw_init, make_train_step  # noqa: E402
from ..training.train_step import settings_for                  # noqa: E402
from .mesh import make_production_mesh                          # noqa: E402
from .roofline import extract_terms, model_flops_estimate       # noqa: E402

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "launch_results", "dryrun.json")


def _rules_for(arch: str, kind: str) -> Optional[Dict[str, Any]]:
    """Per-arch logical-rule overrides: big archs shard the remat-saved
    scan carry over "model" during training (activation memory / 16)."""
    st = settings_for(arch)
    if st.seq_shard_activations and kind == "train":
        return {"carry_seq": "model"}
    return None


def _mem_report(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    total = (out.get("argument_size_in_bytes", 0)
             + out.get("temp_size_in_bytes", 0)
             + out.get("output_size_in_bytes", 0)
             - out.get("alias_size_in_bytes", 0))
    out["per_device_total_bytes"] = total
    return out


def _f16_shadow(cfg, settings):
    """Identical-buffer-size model in f16 for TPU-corrected memory readings.

    XLA CPU's float-normalization pass promotes bf16 while-loop buffers to
    f32 (verified: the same scan compiled in f16 has no duplicates), so
    bf16 memory_analysis over-reports vs a real TPU.  f16 has the same
    byte-width as bf16 and is CPU-native, giving the true footprint.
    """
    import dataclasses
    remap = lambda d: "float16" if d == "bfloat16" else d
    cfg2 = cfg.with_(param_dtype=remap(cfg.param_dtype),
                     compute_dtype=remap(cfg.compute_dtype))
    st2 = dataclasses.replace(
        settings, grad_dtype=remap(settings.grad_dtype),
        opt_state_dtype=remap(settings.opt_state_dtype))
    return cfg2, st2


# §Perf hillclimb variants: config/settings overrides lowered side by side
# with the baseline (results keyed "<arch>|<shape>|<mesh>#<variant>")
VARIANTS: Dict[str, Dict[str, Any]] = {
    "carry_cache": {"cfg": {"decode_carry_cache": True}},
    "attn_chunk512": {"cfg": {"attn_chunk_threshold": 512}},
    "attn_chunk1024": {"cfg": {"attn_chunk_threshold": 1024}},
    "compress_pod": {"settings": {"compress_grads": True}},
    "adafactor": {"settings": {"optimizer": "adafactor",
                               "opt_state_dtype": "bfloat16"}},
    "carry_seq_off": {"rules": {"carry_seq": None}},
    "xla_flash": {"cfg": {"attn_online": True}},
    "expert_split2": {"cfg": {"moe_expert_split": 2}},
    "accum4": {"settings": {"accum_steps": 4}},
    "accum2": {"settings": {"accum_steps": 2}},
    # small models: replicate weights, give BOTH mesh axes to the batch
    # (0.5B x 256-way TP+FSDP is pure overhead)
    "pure_dp": {"settings": {"accum_steps": 1},
                "rules": {"batch": ("data", "model"), "wtp": None,
                          "fsdp": None, "tp": None, "experts": None,
                          "kv_seq": None, "carry_seq": None, "seq": None}},
}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, f16_shadow: bool = False,
             variant: Optional[str] = None) -> Dict[str, Any]:
    """Lower + compile one (arch x shape x mesh) cell; return its record."""
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    mesh_name = "pod2" if multi_pod else "pod1"
    vspec = VARIANTS.get(variant or "", {})
    if vspec.get("cfg"):
        cfg = cfg.with_(**vspec["cfg"])

    # ---- skip rules (documented in DESIGN.md §Arch-applicability)
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return {"status": "skip",
                "reason": "quadratic full-attention arch; 500k dense KV "
                          "attention is not servable without a "
                          "sub-quadratic mechanism"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    settings = settings_for(arch)
    if vspec.get("settings"):
        import dataclasses
        settings = dataclasses.replace(settings, **vspec["settings"])
    if shape.kind == "train":
        # microbatch must stay shardable over the DP axes of this mesh
        import dataclasses
        dp = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
        max_accum = max(shape.global_batch // dp, 1)
        if settings.accum_steps > max_accum:
            settings = dataclasses.replace(settings, accum_steps=max_accum)
    if f16_shadow:
        cfg, settings = _f16_shadow(cfg, settings)
    model = Model(cfg)
    rules = _rules_for(arch, shape.kind)
    if vspec.get("rules"):
        rules = {**(rules or {}), **vspec["rules"]}

    t0 = time.time()
    with use_sharding(mesh, rules) as ctx:
        params_abs = model.abstract_params()
        p_sh = param_shardings(ctx, params_abs)
        specs = model.input_specs(shape)
        in_sh = step_in_shardings(ctx, model, shape, specs)

        if shape.kind == "train":
            opt_cfg = AdamWConfig(state_dtype=settings.opt_state_dtype)
            from ..training.optimizer import make_optimizer
            opt_init, _ = make_optimizer(settings.optimizer, opt_cfg)
            opt_abs = jax.eval_shape(opt_init, params_abs)
            if settings.optimizer == "adafactor":
                from ..distributed.sharding import param_shardings as _ps
                o_sh = jax.tree.map(
                    lambda l: ctx.sharding((None,) * len(l.shape), l.shape),
                    opt_abs)
                o_sh["m"] = p_sh
            else:
                o_sh = {"m": p_sh, "v": p_sh,
                        "step": ctx.sharding((), ())}
            step = make_train_step(model, opt_cfg, settings,
                                   mesh=mesh)
            jitted = jax.jit(step,
                             in_shardings=(p_sh, o_sh, in_sh["batch"]),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, specs["batch"])
            tokens = (specs["batch"]["labels"].shape[0]
                      * specs["batch"]["labels"].shape[1])
            mf = model_flops_estimate(model.active_params(), tokens, "train")
        elif shape.kind == "prefill":
            logits_sh = ctx.sharding(("batch", "tp"),
                                     (shape.global_batch, cfg.vocab_size))
            cache_abs = jax.eval_shape(
                lambda p, i: model.prefill(p, i)[1], params_abs,
                specs["inputs"])
            c_sh = cache_shardings(ctx, cfg, cache_abs)
            jitted = jax.jit(model.prefill,
                             in_shardings=(p_sh, in_sh["inputs"]),
                             out_shardings=(logits_sh, c_sh))
            lowered = jitted.lower(params_abs, specs["inputs"])
            tokens = shape.global_batch * shape.seq_len
            mf = model_flops_estimate(model.active_params(), tokens,
                                      "prefill")
        else:  # decode
            logits_sh = ctx.sharding(("batch", "tp"),
                                     (shape.global_batch, cfg.vocab_size))
            c_sh = in_sh["cache"]
            jitted = jax.jit(model.decode_step,
                             in_shardings=(p_sh, c_sh,
                                           in_sh["tokens"], in_sh["pos"]),
                             out_shardings=(logits_sh, c_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, specs["cache"],
                                   specs["tokens"], specs["pos"])
            tokens = shape.global_batch
            mf = model_flops_estimate(model.active_params(), tokens,
                                      "decode")
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = _mem_report(compiled)
    terms = extract_terms(compiled, n_chips, mf)
    if not f16_shadow:
        _save_hlo(arch, shape_name, mesh_name, variant, compiled.as_text(),
                  n_chips, mf)
    record = {
        "status": "ok",
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem,
        "roofline": terms.to_dict(),
    }
    if not f16_shadow:
        # TPU-corrected memory via the f16 shadow compile (same byte widths,
        # no CPU float-normalization f32 promotion of bf16 loop buffers)
        try:
            shadow = run_cell(arch, shape_name, multi_pod, verbose=False,
                              f16_shadow=True, variant=variant)
            record["memory_tpu_corrected"] = shadow.get("memory", {})
        except Exception as e:  # shadow failure is non-fatal
            record["memory_tpu_corrected"] = {"error": str(e)}
    if verbose:
        corr = record.get("memory_tpu_corrected", {}) \
            .get("per_device_total_bytes", 0)
        print(f"[{arch} x {shape_name} x {mesh_name}] "
              f"compile={t_compile:.0f}s "
              f"mem/dev={mem.get('per_device_total_bytes', 0) / 2**30:.2f}GiB"
              f" (tpu~{corr / 2**30:.2f}GiB) "
              f"flops/dev={terms.flops:.3e} "
              f"coll/dev={terms.collective_bytes / 2**20:.1f}MiB "
              f"dominant={terms.dominant}", flush=True)
        print(f"  memory_analysis: {mem}", flush=True)
        ca = {k: v for k, v in (compiled.cost_analysis() or {}).items()
              if k in ("flops", "bytes accessed")}
        print(f"  cost_analysis: {ca}", flush=True)
    return record


HLO_DIR = os.path.join(os.path.dirname(RESULTS_PATH), "hlo")


def _hlo_path(key: str) -> str:
    return os.path.join(os.path.abspath(HLO_DIR),
                        key.replace("|", "__").replace("#", "--") + ".hlo.gz")


def _save_hlo(arch, shape_name, mesh_name, variant, text, n_chips, mf):
    import gzip
    key = f"{arch}|{shape_name}|{mesh_name}" + (f"#{variant}" if variant
                                                else "")
    path = _hlo_path(key)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with gzip.open(path, "wt") as f:
        f.write(f"# n_chips={n_chips} model_flops={mf}\n")
        f.write(text)


def reterm(results: Dict[str, Any]) -> int:
    """Recompute roofline terms from cached HLO (no recompilation)."""
    import gzip
    from .roofline import RooflineTerms
    from .hlo_cost import analyze_hlo
    n = 0
    for key, rec in results.items():
        if rec.get("status") != "ok":
            continue
        path = _hlo_path(key)
        if not os.path.exists(path):
            continue
        with gzip.open(path, "rt") as f:
            hdr = f.readline()
            text = f.read()
        meta = dict(kv.split("=") for kv in hdr[1:].split())
        cost = analyze_hlo(text)
        from .roofline import CollectiveStats
        stats = CollectiveStats(
            bytes_by_kind=dict(cost.coll_bytes),
            count_by_kind={k: int(v) for k, v in cost.coll_count.items()})
        terms = RooflineTerms(
            flops=cost.flops, hbm_bytes=cost.bytes,
            collective_bytes=cost.total_coll_bytes,
            n_chips=int(meta["n_chips"]),
            model_flops=float(meta["model_flops"]), collectives=stats)
        rec["roofline"] = terms.to_dict()
        n += 1
    return n


def load_results(path: str) -> Dict[str, Any]:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_results(path: str, results: Dict[str, Any]) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1)
    os.replace(tmp, path)


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in ALL_SHAPES] + [None])
    ap.add_argument("--mesh", default="both",
                    choices=("pod1", "pod2", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default=None, choices=list(VARIANTS))
    ap.add_argument("--reterm", action="store_true",
                    help="recompute roofline terms from cached HLO only")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_PATH))
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = [s.name for s in ALL_SHAPES] if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"pod1": [False], "pod2": [True],
              "both": [False, True]}[args.mesh]

    results = load_results(args.out)
    if args.reterm:
        n = reterm(results)
        save_results(args.out, results)
        print(f"re-derived terms for {n} cells from cached HLO")
        return
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                key = f"{arch}|{shape_name}|{'pod2' if multi_pod else 'pod1'}"
                if args.variant:
                    key += f"#{args.variant}"
                if key in results and not args.force \
                        and results[key].get("status") in ("ok", "skip"):
                    continue
                try:
                    results[key] = run_cell(arch, shape_name, multi_pod,
                                            variant=args.variant)
                except Exception as e:
                    failures += 1
                    results[key] = {"status": "error",
                                    "error": f"{type(e).__name__}: {e}"}
                    print(f"[{key}] FAILED: {e}", flush=True)
                    traceback.print_exc()
                save_results(args.out, results)
    ok = sum(1 for r in results.values() if r.get("status") == "ok")
    sk = sum(1 for r in results.values() if r.get("status") == "skip")
    er = sum(1 for r in results.values() if r.get("status") == "error")
    print(f"dry-run: {ok} ok, {sk} skip, {er} error", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
