"""HLO cost analyzer with correct while-loop trip-count accounting.

``compiled.cost_analysis()`` on the CPU backend counts each while-loop body
ONCE (verified experimentally: a 4-layer scan reports 1/4 of the true dot
flops).  Since scan-over-layers puts ~all of a model's work inside while
bodies, the dry-run must walk the call graph itself:

  * per-computation local costs from op definition lines
      - dot:  flops = 2 x result_elems x contraction_size
      - fusion: HBM bytes = operands + result (a fusion is XLA's unit of
        HBM traffic); flops recurse into the fused computation
      - collectives: operand bytes, by kind (-start variants counted once,
        -done skipped)
      - plain arithmetic at top level: bytes = operands + result
  * call-graph resolution with memoization
      - while: (body + condition) x trip_count, trip count recovered from
        the largest integer constant in the condition computation
      - call / conditional / fusion: recurse

Parsing targets the post-optimization HLO text from ``compiled.as_text()``.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s8": 1, "u8": 1, "pred": 1,
    "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9a-z]+)?)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
# the opcode is the first lowercase word immediately followed by '(' in the
# RHS — result types (tuples with /*index=N*/ comments, layouts {1,0:T(...)})
# never contain a lowercase-word-then-paren sequence
_OPCODE_RE = re.compile(r"(?<![\w.%])([a-z][a-z0-9\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(.*\))?\s*->.*{")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)="
    r"(?:{([^}]*)}|%?([\w\.\-]+))")
_OPERAND_NAME_RE = re.compile(r"%([\w\.\-]+)")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")

_COLLECTIVES = {"all-gather": "all-gather", "all-gather-start": "all-gather",
                "all-reduce": "all-reduce", "all-reduce-start": "all-reduce",
                "reduce-scatter": "reduce-scatter",
                "all-to-all": "all-to-all",
                "collective-permute": "collective-permute",
                "collective-permute-start": "collective-permute"}

_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota",
             "all-gather-done", "all-reduce-done", "collective-permute-done",
             "copy-start", "copy-done", "opt-barrier"}

_ARITH_1FLOP = {"add", "subtract", "multiply", "divide", "maximum", "minimum",
                "exponential", "tanh", "rsqrt", "sqrt", "log", "negate",
                "compare", "select", "and", "or", "xor", "power", "reduce",
                "reduce-window", "convert", "clamp", "abs", "floor", "cosine",
                "sine", "logistic"}


def _type_bytes(type_str: str) -> float:
    return sum((int(math.prod([int(d) for d in dims.split(",")]))
                if dims.strip() else 1) * _DTYPE_BYTES.get(dt, 4)
               for dt, dims in _SHAPE_RE.findall(type_str))


def _type_elems(type_str: str) -> float:
    return sum((int(math.prod([int(d) for d in dims.split(",")]))
                if dims.strip() else 1)
               for dt, dims in _SHAPE_RE.findall(type_str))


@dataclass
class _Op:
    name: str
    result_type: str
    opcode: str
    rest: str          # operands + attributes (remainder of the line)


@dataclass
class _Computation:
    name: str
    ops: List[_Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # name -> type str
    root: str = ""

    def op_by_name(self, name: str):
        for o in self.ops:
            if o.name == name:
                return o
        return None


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    coll_count: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0.0) + v * mult

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


def _split_computations(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    entry_name = None
    for line in text.splitlines():
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and line.rstrip().endswith("{"):
            cur = _Computation(hdr.group(1))
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry_name = cur.name
            # parameter shapes from the header signature
            sig = line[line.find("("):line.rfind("->")]
            for pm in re.finditer(r"%?([\w\.\-]+):\s*([^,()]*\[[0-9,]*\][^,()]*)",
                                  sig):
                cur.shapes[pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _DEF_RE.match(line)
        if m:
            name, rhs = m.groups()
            m2 = _OPCODE_RE.search(rhs)
            if not m2:
                continue
            rtype = rhs[:m2.start()].strip()
            opcode = m2.group(1)
            rest = rhs[m2.end():]
            cur.ops.append(_Op(name, rtype, opcode, rest))
            cur.shapes[name] = rtype
            if line.lstrip().startswith("ROOT"):
                cur.root = name
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _operand_bytes(op: _Op, comp: _Computation) -> float:
    """Bytes of the op's operands (inline types or name lookup)."""
    # operand section: up to the matching close paren
    depth, end = 1, len(op.rest)
    for i, ch in enumerate(op.rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operands = op.rest[:end]
    inline = _type_bytes(operands)
    if inline:
        return inline
    total = 0.0
    for nm in _OPERAND_NAME_RE.findall(operands):
        t = comp.shapes.get(nm)
        if t:
            total += _type_bytes(t)
    return total


def _dot_flops(op: _Op, comp: _Computation) -> float:
    """2 x result_elems x contraction_size."""
    result_elems = _type_elems(op.result_type)
    m = re.search(r"lhs_contracting_dims={([0-9,]*)}", op.rest)
    if not m:
        return 2.0 * result_elems  # degenerate
    dims = [int(d) for d in m.group(1).split(",") if d.strip()]
    # lhs operand: first %name (or first inline shape)
    operands = op.rest
    lhs_shape = None
    inline = _SHAPE_RE.findall(operands.split(",")[0])
    if inline:
        lhs_shape = inline[0]
    else:
        names = _OPERAND_NAME_RE.findall(operands)
        if names:
            t = comp.shapes.get(names[0])
            if t:
                sh = _SHAPE_RE.findall(t)
                if sh:
                    lhs_shape = sh[0]
    if lhs_shape is None:
        return 2.0 * result_elems
    lhs_dims = [int(d) for d in lhs_shape[1].split(",") if d.strip()]
    contract = 1
    for d in dims:
        if d < len(lhs_dims):
            contract *= lhs_dims[d]
    return 2.0 * result_elems * contract


def _dus_update_bytes(op: _Op, comp: _Computation) -> float:
    """Bytes of a dynamic-update-slice's *update* operand (operand #1)."""
    names = _OPERAND_NAME_RE.findall(op.rest)
    if len(names) >= 2:
        t = comp.shapes.get(names[1])
        if t:
            return _type_bytes(t)
    shapes = _SHAPE_RE.findall(op.rest)
    if len(shapes) >= 2:
        dt, dims = shapes[1]
        return _shape_to_bytes(dt, dims)
    return _type_bytes(op.result_type)


def _shape_to_bytes(dt: str, dims: str) -> float:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _called(op: _Op) -> List[str]:
    out = []
    for m in _CALL_ATTR_RE.finditer(op.rest):
        if m.group(1) is not None:
            out.extend(x.strip().lstrip("%") for x in m.group(1).split(",")
                       if x.strip())
        else:
            out.append(m.group(2))
    return out


def _trip_count(cond: _Computation) -> int:
    """Largest integer constant in the loop-condition computation (scan
    conditions are `lt(counter, constant(L))`)."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.match(r"(\d+)\)", op.rest.strip())
            if m:
                best = max(best, int(m.group(1)))
    return best


class HloCostModel:
    def __init__(self, hlo_text: str) -> None:
        self.comps = _split_computations(hlo_text)
        self._memo: Dict[Tuple[str, bool], Cost] = {}

    def entry_cost(self) -> Cost:
        if "__entry__" not in self.comps:
            return Cost()
        return self._cost(self.comps["__entry__"].name, top_level=True)

    def _cost(self, name: str, top_level: bool) -> Cost:
        key = (name, top_level)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = Cost()  # cycle guard
        comp = self.comps.get(name)
        if comp is None:
            return Cost()
        total = Cost()
        for op in comp.ops:
            oc = op.opcode
            if oc in _SKIP_OPS:
                continue
            if oc in _COLLECTIVES:
                kind = _COLLECTIVES[oc]
                b = _operand_bytes(op, comp)
                total.coll_bytes[kind] = total.coll_bytes.get(kind, 0.0) + b
                total.coll_count[kind] = total.coll_count.get(kind, 0.0) + 1
                total.bytes += b + _type_bytes(op.result_type)
                continue
            if oc == "dot":
                total.flops += _dot_flops(op, comp)
                if top_level:
                    total.bytes += _operand_bytes(op, comp) \
                        + _type_bytes(op.result_type)
                continue
            if oc == "fusion":
                # fusion = HBM traffic unit; internal dots still count flops
                total.bytes += self._fusion_traffic(op, comp)
                for sub in _called(op):
                    total.add(self._fusion_flops(sub))
                continue
            if oc == "while":
                body, cond = None, None
                m = re.search(r"body=%?([\w\.\-]+)", op.rest)
                if m:
                    body = m.group(1)
                m = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                if m:
                    cond = m.group(1)
                trip = _trip_count(self.comps[cond]) if cond in self.comps \
                    else 1
                if body:
                    total.add(self._cost(body, top_level=True), mult=trip)
                continue
            if oc in ("call", "conditional", "async-start"):
                for sub in _called(op):
                    total.add(self._cost(sub, top_level=True))
                continue
            if oc in ("custom-call", "convolution"):
                total.bytes += _operand_bytes(op, comp) \
                    + _type_bytes(op.result_type)
                continue
            if oc == "dynamic-slice":
                # reads + writes only the slice, not the source buffer
                total.bytes += 2 * _type_bytes(op.result_type)
                continue
            if oc == "dynamic-update-slice":
                # in-place slice write: traffic = 2 x update size
                total.bytes += 2 * _dus_update_bytes(op, comp)
                continue
            if oc == "copy":
                # loop-carry copies are elided on TPU when buffers are
                # donated/aliased; count the write side only
                total.bytes += _type_bytes(op.result_type)
                continue
            if oc in ("sort", "scatter", "gather", "transpose",
                      "reshape", "broadcast", "concatenate", "slice", "pad",
                      "reverse", "reduce", "reduce-window",
                      "select-and-scatter"):
                total.bytes += _operand_bytes(op, comp) \
                    + _type_bytes(op.result_type)
                continue
            if oc in _ARITH_1FLOP:
                total.flops += _type_elems(op.result_type)
                total.bytes += _operand_bytes(op, comp) \
                    + _type_bytes(op.result_type)
                continue
            # unknown op: count memory conservatively
            total.bytes += _type_bytes(op.result_type)
        self._memo[key] = total
        return total

    def _fusion_traffic(self, op: _Op, comp: _Computation) -> float:
        """HBM traffic of one fusion: operands + result, EXCEPT in-place
        slice-update fusions (the scan/while pattern), where the aliased
        big buffer contributes only the touched slice."""
        result_b = _type_bytes(op.result_type)
        operand_b = _operand_bytes(op, comp)
        called = _called(op)
        sub = self.comps.get(called[0]) if called else None
        if sub is None or not sub.root:
            return operand_b + result_b
        root = sub.op_by_name(sub.root)
        if root is None:
            return operand_b + result_b

        def dus_bytes(dus_op):
            return 2 * _dus_update_bytes(dus_op, sub)

        if root.opcode == "dynamic-update-slice":
            # exclude the aliased big operand (same type as the result)
            alias = _type_bytes(op.result_type)
            return max(operand_b - alias, 0.0) + dus_bytes(root)
        if root.opcode == "tuple":
            # multi-output loop fusion: per-element dus -> slice traffic
            total = 0.0
            elem_names = _OPERAND_NAME_RE.findall(root.rest)
            alias_excluded = 0.0
            for en in elem_names:
                eop = sub.op_by_name(en)
                if eop is not None and eop.opcode == "dynamic-update-slice":
                    total += dus_bytes(eop)
                    alias_excluded += _type_bytes(eop.result_type)
                elif eop is not None:
                    total += _type_bytes(eop.result_type)
            return max(operand_b - alias_excluded, 0.0) + total
        if root.opcode == "dynamic-slice":
            return 2 * result_b + min(operand_b, 2 * result_b)
        return operand_b + result_b

    def _fusion_flops(self, name: str) -> Cost:
        """Inside a fusion: only flops (dots + arithmetic); bytes counted at
        the fusion boundary."""
        key = (name, False)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = Cost()
        comp = self.comps.get(name)
        if comp is None:
            return Cost()
        total = Cost()
        for op in comp.ops:
            if op.opcode == "dot":
                total.flops += _dot_flops(op, comp)
            elif op.opcode in _ARITH_1FLOP:
                total.flops += _type_elems(op.result_type)
            elif op.opcode == "fusion" or op.opcode == "call":
                for sub in _called(op):
                    total.add(self._fusion_flops(sub))
        self._memo[key] = total
        return total


def analyze_hlo(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).entry_cost()
