"""Serving driver: ``python -m repro.launch.serve --arch qwen2-0.5b --smoke``

Boots the microservice LLM server (api -> tokenizer -> engine ->
detokenizer) on the chosen async backend and runs a batch of requests
through it, reporting throughput and latency percentiles.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import ARCH_IDS, get_config, get_smoke_config
from ..models import Model
from ..serving import ServeConfig, build_llm_app


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--backend", default="fiber",
                    choices=("fiber", "thread"),
                    help="async-RPC backend (the paper's comparison)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg.with_(remat=False))
    params = model.init(jax.random.PRNGKey(0))
    scfg = ServeConfig(max_batch=args.max_batch, max_len=128,
                       prefill_bucket=32, max_new_tokens=args.max_new)
    app = build_llm_app(model, params, scfg, backend=args.backend)
    with app:
        app.send("engine", "run", None)
        # warmup / compile
        app.send("api", "generate", {"text": "warmup"}).wait(timeout=300)
        lats = []
        t0 = time.perf_counter()
        futs = []
        for i in range(args.requests):
            ts = time.perf_counter()
            fut = app.send("api", "generate", {"text": f"request {i}"})
            fut.add_done_callback(
                lambda f, ts=ts: lats.append(time.perf_counter() - ts))
            futs.append(fut)
        for f in futs:
            f.wait(timeout=600)
        dt = time.perf_counter() - t0
        eng = app.services["engine"].state["engine"]
        print(f"backend={args.backend} requests={args.requests} "
              f"wall={dt:.2f}s rps={args.requests / dt:.1f} "
              f"tokens={eng.generated} tok/s={eng.generated / dt:.1f}")
        print(f"latency p50={np.percentile(lats, 50) * 1e3:.1f}ms "
              f"p99={np.percentile(lats, 99) * 1e3:.1f}ms")
        app.services["engine"].state["stop"] = True


if __name__ == "__main__":
    main()
