"""Training driver: ``python -m repro.launch.train --arch qwen2-0.5b ...``

Wires config -> model -> synthetic data (prefetched) -> jitted train step ->
async checkpointing + supervisor.  ``--smoke`` uses the reduced config so the
loop runs on CPU; the full configs target the production mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import ARCH_IDS, get_config, get_smoke_config
from ..models import Model
from ..training import (AdamWConfig, CheckpointManager, Prefetcher,
                        SyntheticDataset, adamw_init, make_train_step)
from ..training.train_step import settings_for
from ..distributed.fault_tolerance import TrainSupervisor


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    settings = settings_for(args.arch)
    if args.batch % settings.accum_steps != 0:
        import dataclasses
        import math
        settings = dataclasses.replace(
            settings, accum_steps=math.gcd(args.batch,
                                           settings.accum_steps))
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20,
                          decay_steps=max(args.steps, 100),
                          state_dtype=settings.opt_state_dtype)
    step_fn = jax.jit(make_train_step(model, opt_cfg, settings),
                      donate_argnums=(0, 1))

    data = Prefetcher(SyntheticDataset(cfg, args.batch, args.seq), depth=2)
    mgr = CheckpointManager(args.ckpt_dir)
    sup = TrainSupervisor(mgr, save_every=args.save_every)

    def init_state():
        params = model.init(jax.random.PRNGKey(0))
        return {"params": params, "opt": adamw_init(params, opt_cfg)}

    abstract = jax.eval_shape(init_state)
    start_step, state = sup.startup(init_state, abstract)
    print(f"arch={cfg.name} params={model.count_params() / 1e6:.1f}M "
          f"start_step={start_step}", flush=True)

    params, opt = state["params"], state["opt"]
    tokens_per_step = args.batch * args.seq
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = next(data)
        params, opt, metrics = step_fn(params, opt, batch)
        if (step + 1) % args.log_every == 0:
            dt = time.time() - t0
            tput = tokens_per_step * args.log_every / dt
            print(f"step {step + 1:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics.get('grad_norm', 0)):.3f} "
                  f"tok/s={tput:,.0f}", flush=True)
            t0 = time.time()
        sup.maybe_save(step + 1, {"params": params, "opt": opt})
    sup.finalize(args.steps, {"params": params, "opt": opt})
    data.close()
    mgr.close()
    print("done", flush=True)


if __name__ == "__main__":
    main()
