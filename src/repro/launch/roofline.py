"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), seconds per step on TPU v5e:

    compute    = HLO_FLOPs / (chips x 197e12 bf16 FLOP/s)
    memory     = HLO_bytes / (chips x 819e9 B/s HBM)
    collective = collective_bytes / (chips x 50e9 B/s ICI per link)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  Collective
bytes are parsed from the *optimized* (post-SPMD) HLO text: the sum of
operand sizes of every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute (async ``-start`` variants counted once).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

# ---- TPU v5e hardware constants (per chip) --------------------------------
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "s4": 0.5, "u4": 0.5,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches dtype[1,2,3] occurrences (shape may be empty for scalars)
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9a-z]+)?)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of collective ops in (post-SPMD) HLO text."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        # operand section: everything after the op name's '('
        operands = line[m.end():]
        # strip any trailing attributes after the closing paren of operands
        depth, end = 1, len(operands)
        for i, ch in enumerate(operands):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = operands[:end]
        nbytes = sum(_shape_bytes(dt, dims)
                     for dt, dims in _SHAPE_RE.findall(operands))
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0.0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class RooflineTerms:
    flops: float                 # per-device HLO FLOPs
    hbm_bytes: float             # per-device HLO bytes accessed
    collective_bytes: float      # per-device collective operand bytes
    n_chips: int
    model_flops: float = 0.0     # 6*N*D (global), for the usefulness ratio
    collectives: Optional[CollectiveStats] = None

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (chips x per-device HLO FLOPs)."""
        total_hlo = self.flops * self.n_chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable fraction of compute roofline, assuming perfect overlap:
        useful compute time / bound time."""
        useful_t = (self.model_flops / self.n_chips) / PEAK_FLOPS
        return useful_t / self.t_bound if self.t_bound else 0.0

    def to_dict(self) -> Dict:
        d = {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.collective_bytes,
            "n_chips": self.n_chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }
        if self.collectives:
            d["collective_bytes_by_kind"] = self.collectives.bytes_by_kind
            d["collective_count_by_kind"] = self.collectives.count_by_kind
        return d


def model_flops_estimate(arch_params_active: int, tokens: int,
                         kind: str) -> float:
    """6*N*D for training, 2*N*D for inference forward passes."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * arch_params_active * tokens


def extract_terms(compiled, n_chips: int, model_flops: float
                  ) -> RooflineTerms:
    """Derive the three terms from the compiled per-device HLO module.

    NOTE: the XLA CPU backend's ``cost_analysis()`` counts while-loop bodies
    exactly once (verified: a 4-layer scan reports 1/4 of the true dot
    flops), so the dry-run walks the HLO call graph itself with trip-count
    multiplication (launch/hlo_cost.py), validated against analytic counts.
    """
    from .hlo_cost import analyze_hlo
    cost = analyze_hlo(compiled.as_text())
    stats = CollectiveStats(bytes_by_kind=dict(cost.coll_bytes),
                            count_by_kind={k: int(v) for k, v
                                           in cost.coll_count.items()})
    return RooflineTerms(flops=cost.flops, hbm_bytes=cost.bytes,
                         collective_bytes=cost.total_coll_bytes,
                         n_chips=n_chips, model_flops=model_flops,
                         collectives=stats)
