"""Production mesh construction.

Defined as a function (not a module-level constant) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization, and everything else must see the real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: 256 chips (16,16) ("data","model").
    Multi-pod: 512 chips (2,16,16) ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever devices this host actually has (tests / examples)."""
    n = jax.device_count()
    return jax.make_mesh((n,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
