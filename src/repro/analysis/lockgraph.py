"""Lock-order graph for the dynamic sanitizer: acquisition-order cycles.

Every ``lock_acquire(key)`` event observed while the same thread already
holds other locks adds directed edges ``held -> key`` to a global graph.
A cycle in that graph is a potential deadlock: two threads can each hold
one lock of the cycle and block on the next (the classic AB/BA
inversion), even if the run at hand happened to get away with it.

Keys are stable strings (e.g. ``"svc:frontend.state"``), not object ids,
so edges aggregate across lock instances playing the same role and the
report names something a human can find.  Edges remember one sample stack
label per endpoint order so findings can say *where* each order was
established.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple


class LockOrderGraph:
    """Directed acquisition-order graph with per-thread held stacks."""

    def __init__(self) -> None:
        self._held: Dict[int, List[str]] = {}        # tid -> held keys, order
        self._edges: Dict[str, Set[str]] = {}        # key -> keys acquired after
        self._reentrant: Set[Tuple[int, str]] = set()

    # -------------------------------------------------------------- events
    def acquire(self, tid: int, key: str) -> None:
        """Thread ``tid`` acquired ``key`` (called with the lock held)."""
        held = self._held.setdefault(tid, [])
        if key in held:
            # re-entrant acquire (RLock): no new ordering information
            self._reentrant.add((tid, key))
            held.append(key)
            return
        for outer in held:
            self._edges.setdefault(outer, set()).add(key)
        held.append(key)

    def release(self, tid: int, key: str) -> None:
        """Thread ``tid`` released ``key`` (out-of-order release is fine)."""
        held = self._held.get(tid)
        if held is None:
            return
        # remove the innermost matching hold (re-entrant releases unwind)
        for i in range(len(held) - 1, -1, -1):
            if held[i] == key:
                del held[i]
                return

    # -------------------------------------------------------------- report
    def cycles(self) -> List[List[str]]:
        """Every elementary cycle reachable in the edge set, as key lists
        (``[a, b, a]`` for an AB/BA inversion).  The graph is tiny (tens of
        keys), so a DFS per node is plenty."""
        out: List[List[str]] = []
        seen_cycles: Set[Tuple[str, ...]] = set()
        for start in sorted(self._edges):
            path: List[str] = []
            on_path: Set[str] = set()

            def dfs(node: str) -> None:
                path.append(node)
                on_path.add(node)
                for nxt in sorted(self._edges.get(node, ())):
                    if nxt == start and len(path) > 1:
                        cyc = path + [start]
                        canon = tuple(sorted(set(cyc)))
                        if canon not in seen_cycles:
                            seen_cycles.add(canon)
                            out.append(list(cyc))
                    elif nxt not in on_path and nxt > start:
                        # only explore nodes ordered after `start`: each
                        # cycle is then found exactly once, rooted at its
                        # smallest key
                        dfs(nxt)
                path.pop()
                on_path.discard(node)

            dfs(start)
        return out

    def edges(self) -> Dict[str, Set[str]]:
        """The raw acquisition-order edge set (for reports and tests)."""
        return {k: set(v) for k, v in self._edges.items()}

    def currently_held(self, tid: int) -> List[str]:
        """Keys ``tid`` holds right now, outermost first."""
        return list(self._held.get(tid, ()))
