"""Static and dynamic analysis for the repro runtime (PR 10).

Two layers, one package:

* :mod:`repro.analysis.sanitizer` — a **dynamic concurrency sanitizer**:
  a :class:`~repro.core.instrument.Hooks` implementation that consumes the
  runtime's instrumentation events (future set/wait, fiber spawn/park/
  steal, queue put/take, ring submit/drain, timer arm/fire, trial sever)
  and runs a happens-before race checker (:mod:`repro.analysis.hb`), a
  lock-order-inversion graph (:mod:`repro.analysis.lockgraph`), a leaked-
  future detector and the trial-summary freshness protocol over them.
  Attach it around any test or workload with
  :func:`~repro.analysis.sanitizer.attached`.

* :mod:`repro.analysis.lint` — a **static AST lint pass** (stdlib ``ast``
  only): no blocking primitives in ``repro.apps`` handler bodies, no
  unseeded randomness or wall-clock reads in ``repro.core``, no ``jax``
  in the core/apps import closure, and ``BackendStats`` counters mutated
  only under their documented owner.  Run it as
  ``python -m repro.analysis.lint src/repro``.

The runtime never imports this package — the dependency points one way
(analysis -> core), and with no sanitizer installed the instrumentation
seam costs a single predictable-untaken branch per event site (verified
by the hooks-off row of ``benchmarks/bench_rpc_path.py``).

Rule catalog, suppression syntax and extension guide: ``docs/ANALYSIS.md``.
"""
# Lazy exports (PEP 562): `python -m repro.analysis.lint` must not find the
# submodule pre-imported by its own package (runpy's double-import warning),
# and importing the package stays free of submodule side effects.
_EXPORTS = {
    "Finding": "sanitizer", "Sanitizer": "sanitizer", "attached": "sanitizer",
    "LintFinding": "lint", "lint_paths": "lint",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    """Resolve the public surface from its submodule on first touch."""
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f".{mod}", __name__), name)
