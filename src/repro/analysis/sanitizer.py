"""Dynamic concurrency sanitizer: the consumer of ``repro.core.instrument``.

:class:`Sanitizer` is a :class:`~repro.core.instrument.Hooks`
implementation.  Installed via :func:`attached` (or raw
``instrument.install``), it folds the runtime's event stream into four
detectors:

``SAN-RACE``
    Unsynchronized shared-variable access: ``access(key, write)`` events
    checked against the vector-clock happens-before state
    (:mod:`repro.analysis.hb`).  Mailbox put/take, ring submit/drain,
    future set/resume, fiber steal and timer arm/fire events are the
    synchronization edges; anything else concurrent is a race.

``SAN-LOCK-ORDER``
    Lock-acquisition-order cycles (:mod:`repro.analysis.lockgraph`), fed
    by ``lock_acquire``/``lock_release`` events — usually emitted by the
    :class:`TrackedLock` / :class:`TrackedCondition` proxies that
    :func:`track_app_locks` swaps onto a live app's locks.

``SAN-FUT-LEAK``
    Futures somebody *awaited* — a cooperative ``Wait`` park
    (``future_join``) or an untimed blocking ``Future.wait``
    (``future_block(timeout=None)``) — that are still unresolved when
    :meth:`Sanitizer.check` runs: a lost wakeup or a leaked blackhole.
    Timed blocking waits are excluded (the waiter owned a recovery path).

``SAN-TRIAL-SUMMARY``
    The loadgen trial-isolation protocol (PR 6): a
    ``LatencyRecorder`` write arriving *after* the recorder's summary was
    read while the trial had not yet been severed means the summary raced
    a late completion; a write after ``trial_sever`` means the sever
    failed to freeze the recorder.  Either ordering is the PR 6 bug.

``SAN-SELF-DEADLOCK`` (warn tier this PR)
    A thread blocking on a :class:`~repro.core.future.Future` whose only
    producer is a scheduler *owned by that same thread* — the producer
    can never run while its carrier is blocked.  Reported as a warning
    until a full PR of soak coverage upgrades it (see docs/ANALYSIS.md).

Scope and cost
--------------
The sanitizer is a **test-time** tool: all event processing serializes
under one internal lock, and object identity is tracked by ``id()`` (safe
for test-scoped attachment windows; a detached sanitizer drops its
references).  Production runs never install hooks and pay one untaken
branch per event site.
"""
from __future__ import annotations

import contextlib
import threading
from collections import Counter
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

from repro.core import instrument

from .hb import HBState
from .lockgraph import LockOrderGraph

_WARN_RULES = frozenset({"SAN-SELF-DEADLOCK"})


@dataclass
class Finding:
    """One sanitizer finding: rule id, severity tier, human message."""

    rule: str
    message: str
    severity: str = "error"  # "error" | "warn"

    def __str__(self) -> str:
        return f"[{self.severity}] {self.rule}: {self.message}"


class Sanitizer(instrument.Hooks):
    """Happens-before + lock-order + leak detectors over the event seam."""

    def __init__(self) -> None:
        self._mu = threading.RLock()
        self.hb = HBState()
        self.lockgraph = LockOrderGraph()
        self.counts: Counter = Counter()
        self.findings: List[Finding] = []
        # futures awaited without a recovery path: id -> (fut, how)
        self._awaited: Dict[int, Tuple[Any, str]] = {}
        # producer scheduler per future (id -> scheduler id), and the
        # kernel thread that owns each scheduler loop (scheduler id -> tid)
        self._producer: Dict[int, int] = {}
        self._owner: Dict[int, int] = {}
        # LatencyRecorder protocol state: id -> {"summary", "severed"}
        self._recorders: Dict[int, Dict[str, bool]] = {}
        # App.stop phase order per app id, for shutdown-ordering audits
        self._stop_phases: Dict[int, List[str]] = {}
        self._dedup: set = set()

    # ------------------------------------------------------------ plumbing
    def _flag(self, rule: str, message: str, *, dedup: Optional[str] = None
              ) -> None:
        if dedup is not None:
            if dedup in self._dedup:
                return
            self._dedup.add(dedup)
        sev = "warn" if rule in _WARN_RULES else "error"
        self.findings.append(Finding(rule, message, sev))

    @staticmethod
    def _tid() -> int:
        return threading.get_ident()

    # ------------------------------------------------------------- futures
    def future_set(self, fut: Any) -> None:
        with self._mu:
            self.counts["future_set"] += 1
            self.hb.release(self._tid(), id(fut))

    def future_block(self, fut: Any, timeout: Optional[float]) -> None:
        with self._mu:
            self.counts["future_block"] += 1
            if timeout is None:
                self._awaited[id(fut)] = (fut, "blocking wait")
            sched = self._producer.get(id(fut))
            if (sched is not None and not fut.done
                    and self._owner.get(sched) == self._tid()):
                self._flag(
                    "SAN-SELF-DEADLOCK",
                    "blocking Future.wait on the scheduler thread that owns "
                    "the future's only producer: the producer fiber can "
                    "never run while its carrier thread is blocked "
                    "(yield Wait(...) instead of calling wait())",
                    dedup=f"selfdl:{id(fut)}")

    def future_unblock(self, fut: Any, done: bool) -> None:
        with self._mu:
            self.counts["future_unblock"] += 1
            if done:
                self.hb.acquire(self._tid(), id(fut))

    def future_join(self, fut: Any) -> None:
        with self._mu:
            self.counts["future_join"] += 1
            if not fut.done:
                self._awaited[id(fut)] = (fut, "cooperative Wait park")

    # -------------------------------------------------------------- fibers
    def fiber_spawn(self, sched: Any, fib: Any) -> None:
        with self._mu:
            self.counts["fiber_spawn"] += 1
            fut = getattr(fib, "future", None)
            if fut is not None:
                self._producer[id(fut)] = id(sched)

    def fiber_park(self, sched: Any, fib: Any) -> None:
        with self._mu:
            self.counts["fiber_park"] += 1

    def fiber_resume(self, sched: Any, fib: Any) -> None:
        with self._mu:
            self.counts["fiber_resume"] += 1

    def fiber_steal(self, victim: Any, thief: Any, n: int) -> None:
        with self._mu:
            self.counts["fiber_steal"] += n

    def sched_loop(self, sched: Any) -> None:
        with self._mu:
            self.counts["sched_loop"] += 1
            self._owner[id(sched)] = self._tid()

    # ----------------------------------------------------- queues and rings
    def queue_put(self, obj: Any) -> None:
        with self._mu:
            self.counts["queue_put"] += 1
            self.hb.release(self._tid(), id(obj))

    def queue_take(self, obj: Any) -> None:
        with self._mu:
            self.counts["queue_take"] += 1
            self.hb.acquire(self._tid(), id(obj))

    def ring_submit(self, ring: Any) -> None:
        with self._mu:
            self.counts["ring_submit"] += 1
            self.hb.release(self._tid(), id(ring))

    def ring_drain(self, ring: Any, n: int, reason: str) -> None:
        with self._mu:
            self.counts["ring_drain"] += 1
            self.hb.acquire(self._tid(), id(ring))

    # --------------------------------------------------------- event loops
    def loop_spawn(self, loop: Any, fut: Any) -> None:
        with self._mu:
            self.counts["loop_spawn"] += 1
            self._producer[id(fut)] = id(loop)

    def shard_handoff(self, loop: Any, shard: Any) -> None:
        with self._mu:
            self.counts["shard_handoff"] += 1

    # --------------------------------------------------------------- timers
    def timer_arm(self, owner: Any, deadline: float) -> None:
        with self._mu:
            self.counts["timer_arm"] += 1
            self.hb.release(self._tid(), ("timer", id(owner)))

    def timer_fire(self, owner: Any, n: int) -> None:
        with self._mu:
            self.counts["timer_fire"] += n
            self.hb.acquire(self._tid(), ("timer", id(owner)))

    def timer_cancel(self, owner: Any, n: int) -> None:
        with self._mu:
            self.counts["timer_cancel"] += n

    # ------------------------------------------------------------- carriers
    def carrier_start(self, owner: Any, name: str) -> None:
        with self._mu:
            self.counts["carrier_start"] += 1

    def carrier_stop(self, owner: Any) -> None:
        with self._mu:
            self.counts["carrier_stop"] += 1

    # ---------------------------------------------------- lifecycle / trials
    def stop_phase(self, app: Any, phase: str) -> None:
        with self._mu:
            self.counts["stop_phase"] += 1
            self._stop_phases.setdefault(id(app), []).append(phase)

    def trial_sever(self, recorder: Any) -> None:
        with self._mu:
            self.counts["trial_sever"] += 1
            self._rec(recorder)["severed"] = True

    def recorder_write(self, recorder: Any) -> None:
        with self._mu:
            self.counts["recorder_write"] += 1
            st = self._rec(recorder)
            if st["severed"]:
                self._flag(
                    "SAN-TRIAL-SUMMARY",
                    "LatencyRecorder write after its trial was severed: the "
                    "sever failed to freeze the recorder (a late completion "
                    "escaped the liveness check)",
                    dedup=f"sever-write:{id(recorder)}")
            elif st["summary"]:
                self._flag(
                    "SAN-TRIAL-SUMMARY",
                    "LatencyRecorder write after its summary was read on a "
                    "live (unsevered) trial: the summary raced a late "
                    "completion — sever the trial before reading it "
                    "(loadgen.run_trial's sever-then-summarize order)",
                    dedup=f"summary-write:{id(recorder)}")

    def recorder_summary(self, recorder: Any) -> None:
        with self._mu:
            self.counts["recorder_summary"] += 1
            self._rec(recorder)["summary"] = True

    def _rec(self, recorder: Any) -> Dict[str, bool]:
        st = self._recorders.get(id(recorder))
        if st is None:
            st = self._recorders[id(recorder)] = {
                "summary": False, "severed": False}
        return st

    # ----------------------------------------------------- locks + accesses
    def lock_acquire(self, key: str) -> None:
        with self._mu:
            self.counts["lock_acquire"] += 1
            tid = self._tid()
            self.hb.acquire(tid, ("lock", key))
            self.lockgraph.acquire(tid, key)

    def lock_release(self, key: str) -> None:
        with self._mu:
            self.counts["lock_release"] += 1
            tid = self._tid()
            self.hb.release(tid, ("lock", key))
            self.lockgraph.release(tid, key)

    def access(self, key: str, write: bool) -> None:
        with self._mu:
            self.counts["access"] += 1
            race = self.hb.access(self._tid(), key, write)
            if race is not None:
                self._flag(
                    "SAN-RACE",
                    f"unsynchronized {race.kind} on {race.key!r} between "
                    f"threads {race.prev_tid} and {race.curr_tid}: no "
                    "happens-before edge orders the accesses (guard the "
                    "counter with its owner lock, or make it an "
                    "itertools.count ticket)",
                    dedup=f"race:{race.key}:{race.kind}")

    # --------------------------------------------------------------- report
    def stop_phases(self, app: Any) -> List[str]:
        """Shutdown phases observed for ``app``, in execution order."""
        with self._mu:
            return list(self._stop_phases.get(id(app), ()))

    def check(self) -> List[Finding]:
        """Finalize the run: fold in end-of-run detectors (leaked futures,
        lock-order cycles) and return every finding."""
        with self._mu:
            for fid, (fut, how) in list(self._awaited.items()):
                if not fut.done:
                    self._flag(
                        "SAN-FUT-LEAK",
                        f"future awaited ({how}) but never resolved: a lost "
                        "wakeup or a leaked blackhole (settle abandoned "
                        "replies at teardown — see FaultPlan."
                        "settle_blackholed and App.stop)",
                        dedup=f"leak:{fid}")
            for cyc in self.lockgraph.cycles():
                self._flag(
                    "SAN-LOCK-ORDER",
                    "lock-acquisition-order cycle "
                    + " -> ".join(cyc)
                    + ": two threads taking these locks in opposite orders "
                    "can deadlock (pick one global order and stick to it)",
                    dedup=f"cycle:{tuple(sorted(set(cyc)))}")
            return list(self.findings)

    def errors(self) -> List[Finding]:
        """Findings in the hard-fail tier (after :meth:`check`)."""
        return [f for f in self.findings if f.severity == "error"]

    def warnings(self) -> List[Finding]:
        """Findings in the warn tier (after :meth:`check`)."""
        return [f for f in self.findings if f.severity == "warn"]


# --------------------------------------------------------------------------
# lock proxies: feed SAN-LOCK-ORDER without touching production lock code
# --------------------------------------------------------------------------
class TrackedLock:
    """A named proxy around a real ``threading.Lock``/``RLock`` that emits
    ``lock_acquire``/``lock_release`` events.  Swap one onto a live object's
    lock attribute (see :func:`track_app_locks`) — with no hooks installed
    it degrades to one attribute load per operation."""

    __slots__ = ("_lock", "name")

    def __init__(self, lock: Any, name: str) -> None:
        self._lock = lock
        self.name = name

    def acquire(self, *args: Any, **kwargs: Any) -> bool:
        got = self._lock.acquire(*args, **kwargs)
        if got:
            h = instrument.hooks
            if h is not None:
                h.lock_acquire(self.name)
        return got

    def release(self) -> None:
        h = instrument.hooks
        if h is not None:
            h.lock_release(self.name)
        self._lock.release()

    def __enter__(self) -> "TrackedLock":
        self.acquire()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.release()

    def locked(self) -> bool:
        """Delegate liveness probe (tests use it)."""
        return self._lock.locked()


class TrackedCondition:
    """Same proxy for ``threading.Condition``: ``wait`` releases the lock
    (a ``lock_release`` event) and re-acquires it on wakeup, so the
    lock-order graph sees exactly what the kernel does."""

    __slots__ = ("_cond", "name")

    def __init__(self, cond: Any, name: str) -> None:
        self._cond = cond
        self.name = name

    def acquire(self, *args: Any, **kwargs: Any) -> bool:
        got = self._cond.acquire(*args, **kwargs)
        if got:
            h = instrument.hooks
            if h is not None:
                h.lock_acquire(self.name)
        return got

    def release(self) -> None:
        h = instrument.hooks
        if h is not None:
            h.lock_release(self.name)
        self._cond.release()

    def __enter__(self) -> "TrackedCondition":
        self.acquire()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.release()

    def wait(self, timeout: Optional[float] = None) -> bool:
        h = instrument.hooks
        if h is not None:
            h.lock_release(self.name)
        try:
            return self._cond.wait(timeout=timeout)
        finally:
            if h is not None:
                h.lock_acquire(self.name)

    def wait_for(self, predicate: Callable[[], bool],
                 timeout: Optional[float] = None) -> bool:
        h = instrument.hooks
        if h is not None:
            h.lock_release(self.name)
        try:
            return self._cond.wait_for(predicate, timeout=timeout)
        finally:
            if h is not None:
                h.lock_acquire(self.name)

    def notify(self, n: int = 1) -> None:
        self._cond.notify(n)

    def notify_all(self) -> None:
        self._cond.notify_all()


def track_app_locks(app: Any) -> Callable[[], None]:
    """Swap :class:`TrackedLock` proxies onto a live app's principal locks
    (service state locks, admission locks, the breaker-table lock) so the
    lock-order graph sees their acquisition order.  Returns a restore
    callable that puts the original locks back."""
    restores: List[Callable[[], None]] = []

    def swap(obj: Any, attr: str, name: str) -> None:
        orig = getattr(obj, attr)
        setattr(obj, attr, TrackedLock(orig, name))
        restores.append(lambda o=obj, a=attr, g=orig: setattr(o, a, g))

    for svc_name, svc in getattr(app, "services", {}).items():
        swap(svc, "lock", f"svc:{svc_name}.state")
        swap(svc, "_adm_lock", f"svc:{svc_name}.admission")
    if hasattr(app, "_breaker_lock"):
        swap(app, "_breaker_lock", "app.breaker_table")

    def restore() -> None:
        for r in reversed(restores):
            r()

    return restore


@contextlib.contextmanager
def attached(*, app: Any = None) -> Iterator[Sanitizer]:
    """Install a fresh :class:`Sanitizer` for the duration of the block.

    With ``app`` given, its principal locks are proxy-tracked too (and
    restored on exit).  The sanitizer is *not* checked automatically —
    call ``san.check()`` (and assert on ``san.errors()``) inside or after
    the block, while the objects under test are still alive."""
    san = Sanitizer()
    restore: Optional[Callable[[], None]] = None
    instrument.install(san)
    try:
        if app is not None:
            restore = track_app_locks(app)
        yield san
    finally:
        if restore is not None:
            restore()
        instrument.uninstall()
