"""Static AST lint for the repro tree: ``python -m repro.analysis.lint``.

Four rules, each encoding an invariant the runtime's correctness (or the
paper reproduction's determinism) depends on.  This is deliberately *not*
general-purpose style linting — ruff owns style; these rules know the
repository's architecture:

``A101`` no-blocking-in-handlers
    ``repro.apps`` handler bodies must stay cooperative: no
    ``time.sleep``, no blocking ``Future.wait``/``wait_done``, no kernel
    synchronization primitives constructed inline.  A blocking call
    inside a handler stalls the whole scheduler carrier (every fiber or
    continuation sharing it), which is exactly the failure mode the
    effect vocabulary (``Sleep``/``Wait``) exists to prevent.

``A102`` deterministic-core
    ``repro.core`` must be reproducible run-to-run: no unseeded
    module-level ``random`` calls (seeded ``random.Random(seed)``
    instances are fine) and no wall-clock reads (``time.time``,
    ``datetime.now``); ``time.monotonic``/``perf_counter`` are the
    sanctioned clocks.

``A103`` no-jax-in-core
    Neither ``repro.core`` nor ``repro.apps`` may import ``jax`` at
    module level, directly or transitively through other repro modules.
    The benchmark matrix runs on a numpy-only environment; a stray jax
    import would silently skew the CPU-scheduling measurements (and
    break the numpy-only CI lane).  Function-local imports stay legal —
    that is the sanctioned lazy-loading pattern.

``A104`` stats-owner
    ``BackendStats``-surfaced counters may be mutated only under their
    documented owner: inside a ``with <lock>:`` block, in a class whose
    counters are owner-thread-only by design (the cooperative
    schedulers), or in ``__init__`` (before the object is shared).  An
    unowned ``+= 1`` is a lost-update bug waiting for load.

Suppression: append ``# repro: allow[A101]`` (with the violated rule's
id) to the flagged line.  Rule catalog and extension guide:
``docs/ANALYSIS.md``.  Stdlib-only by design (``ast`` + ``pathlib``): the
lint must run in the numpy-only CI lane before anything is installed.
"""
from __future__ import annotations

import ast
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

# -------------------------------------------------------------------- rules
RULES: Dict[str, str] = {
    "A101": "blocking call in a repro.apps handler body",
    "A102": "nondeterminism in repro.core (unseeded RNG / wall clock)",
    "A103": "jax reachable from repro.core / repro.apps module imports",
    "A104": "BackendStats counter mutated outside its documented owner",
}

HINTS: Dict[str, str] = {
    "A101": "yield Sleep(dt) / yield Wait(fut) instead; handlers must stay "
            "cooperative",
    "A102": "use a seeded random.Random(seed) instance and "
            "time.monotonic()/perf_counter()",
    "A103": "move the import into the function that needs it (lazy import)",
    "A104": "mutate under the owner lock (with self._lock:) or keep it "
            "owner-thread-only",
}

# A101: blocking threading-primitive constructors and blocking method names
_BLOCKING_CONSTRUCTORS = {"Lock", "RLock", "Condition", "Event", "Semaphore",
                          "BoundedSemaphore", "Barrier"}
_BLOCKING_METHODS = {"wait", "wait_done"}

# A102: module-level clocks/RNG verdicts
_WALL_CLOCK = {("time", "time"), ("time", "ctime"), ("time", "localtime"),
               ("time", "gmtime"), ("datetime", "now"), ("date", "today"),
               ("datetime", "utcnow")}
_RANDOM_ALLOWED = {"Random", "SystemRandom"}

# A104: the BackendStats counter names a mutation is judged against
# (mirrors repro.core.metrics.BackendStats; `hwm` variants included where
# they are per-executor attributes surfaced through stats()).
_STATS_FIELDS = {
    "spawns", "spawn_seconds", "switches", "steals", "pool_stalls",
    "stall_seconds", "queue_depth_hwm", "batched_calls", "flushes_size",
    "flushes_join", "flushes_timeout", "ring_hwm", "completions_batched",
    "cq_flushes_size", "cq_flushes_timeout", "cq_flushes_idle", "cq_hwm",
    "inline_calls", "inline_depth_hwm", "fast_futures", "slow_futures",
}

# A104: classes whose counters are owner-thread-only by documented design
# (one kernel thread runs the mutating loop; cross-thread work arrives via
# the injection queue, never by touching counters).  BackendStats mutates
# itself in add/delta; CompletionRing guards with its own ring lock but is
# listed for its lock-held helper methods.
_OWNER_THREAD_CLASSES = {
    "FiberScheduler", "BatchFiberScheduler", "CQBatchFiberScheduler",
    "EventLoopExecutor", "ShardedEventLoopExecutor", "CompletionRing",
    "BackendStats",
}


@dataclass
class LintFinding:
    """One lint violation: location, rule id, message, fix hint."""

    path: str
    line: int
    rule: str
    message: str

    def render(self) -> str:
        """``path:line: RULE message (hint: ...)`` — the CLI output row."""
        return (f"{self.path}:{self.line}: {self.rule} {self.message} "
                f"(hint: {HINTS[self.rule]})")


def _suppressed(source_lines: Sequence[str], line: int, rule: str) -> bool:
    """True when the 1-indexed ``line`` carries ``# repro: allow[RULE]``."""
    if 1 <= line <= len(source_lines):
        return f"repro: allow[{rule}]" in source_lines[line - 1]
    return False


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for an Attribute/Name chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _module_of(path: Path) -> Optional[str]:
    """Dotted repro module name for ``path`` (``.../repro/core/x.py`` ->
    ``repro.core.x``), or None when the file is outside a repro package."""
    parts = list(path.with_suffix("").parts)
    if "repro" not in parts:
        return None
    parts = parts[parts.index("repro"):]
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


# ------------------------------------------------------------ per-file pass
class _FileLinter(ast.NodeVisitor):
    """Single-file visitor for A101/A102/A104 (A103 is cross-file)."""

    def __init__(self, rel_path: str, module: str,
                 source_lines: Sequence[str]) -> None:
        self.rel_path = rel_path
        self.module = module
        self.lines = source_lines
        self.findings: List[LintFinding] = []
        self.in_apps = module.startswith("repro.apps")
        self.in_core = module.startswith("repro.core")
        self._func_depth = 0
        self._class_stack: List[str] = []
        self._with_lock_depth = 0
        self._in_init = False

    # ------------------------------------------------------------- helpers
    def _flag(self, node: ast.AST, rule: str, message: str) -> None:
        line = getattr(node, "lineno", 1)
        if not _suppressed(self.lines, line, rule):
            self.findings.append(
                LintFinding(self.rel_path, line, rule, message))

    @staticmethod
    def _mentions_lock(expr: ast.AST) -> bool:
        name = _dotted(expr)
        return name is not None and "lock" in name.lower()

    # ------------------------------------------------------------ traversal
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._enter_func(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._enter_func(node)

    def _enter_func(self, node: ast.AST) -> None:
        was_init = self._in_init
        if self._func_depth == 0 and self._class_stack:
            self._in_init = getattr(node, "name", "") == "__init__"
        self._func_depth += 1
        self.generic_visit(node)
        self._func_depth -= 1
        self._in_init = was_init

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._class_stack.append(node.name)
        self.generic_visit(node)
        self._class_stack.pop()

    def visit_With(self, node: ast.With) -> None:
        locked = any(self._mentions_lock(item.context_expr)
                     for item in node.items)
        if locked:
            self._with_lock_depth += 1
        self.generic_visit(node)
        if locked:
            self._with_lock_depth -= 1

    # ----------------------------------------------------------------- A101
    def visit_Call(self, node: ast.Call) -> None:
        name = _dotted(node.func)
        if self.in_apps and self._func_depth > 0:
            if name == "time.sleep":
                self._flag(node, "A101",
                           "time.sleep blocks the whole scheduler carrier")
            elif name is not None and any(
                    name == f"threading.{c}" for c in _BLOCKING_CONSTRUCTORS):
                self._flag(node, "A101",
                           f"kernel primitive {name} constructed in a "
                           "handler body")
            elif (isinstance(node.func, ast.Attribute)
                    and node.func.attr in _BLOCKING_METHODS):
                self._flag(node, "A101",
                           f"blocking .{node.func.attr}() in a handler "
                           "body")
        if self.in_core:
            self._check_a102_call(node, name)
        self.generic_visit(node)

    # ----------------------------------------------------------------- A102
    def _check_a102_call(self, node: ast.Call, name: Optional[str]) -> None:
        if name is None:
            return
        parts = name.split(".")
        if parts[0] == "random" and len(parts) == 2 \
                and parts[1] not in _RANDOM_ALLOWED:
            self._flag(node, "A102",
                       f"unseeded module-level RNG call {name}()")
        elif len(parts) >= 2 and (parts[-2], parts[-1]) in _WALL_CLOCK:
            self._flag(node, "A102", f"wall-clock read {name}()")

    # ----------------------------------------------------------------- A104
    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if self.in_core:
            self._check_a104(node, node.target)
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        if self.in_core:
            for target in node.targets:
                self._check_a104(node, target)
        self.generic_visit(node)

    def _check_a104(self, node: ast.AST, target: ast.AST) -> None:
        if not (isinstance(target, ast.Attribute)
                and target.attr in _STATS_FIELDS):
            return
        if self._in_init or self._with_lock_depth > 0:
            return
        if any(c in _OWNER_THREAD_CLASSES for c in self._class_stack):
            return
        self._flag(node, "A104",
                   f"counter .{target.attr} mutated with no owning lock "
                   "held and outside an owner-thread-only class")


# ------------------------------------------------------- cross-file: A103
def _top_level_imports(tree: ast.Module, module: str) -> Set[str]:
    """Absolute dotted names imported at module level (relative imports
    resolved against ``module``).  Imports nested in functions/classes are
    lazy by construction and excluded; top-level ``if``/``try`` bodies are
    included — they execute at import time when the branch is live."""
    out: Set[str] = set()
    pkg_parts = module.split(".")[:-1]

    def walk(body: Iterable[ast.stmt]) -> None:
        for stmt in body:
            if isinstance(stmt, ast.Import):
                for alias in stmt.names:
                    out.add(alias.name)
            elif isinstance(stmt, ast.ImportFrom):
                if stmt.level == 0:
                    base = stmt.module or ""
                else:
                    anchor = pkg_parts[:len(pkg_parts) - (stmt.level - 1)]
                    base = ".".join(anchor + ([stmt.module]
                                              if stmt.module else []))
                if base:
                    out.add(base)
                    for alias in stmt.names:
                        out.add(f"{base}.{alias.name}")
            elif isinstance(stmt, (ast.If, ast.Try)):
                walk(stmt.body)
                for handler in getattr(stmt, "handlers", ()):
                    walk(handler.body)
                walk(getattr(stmt, "orelse", ()))
                walk(getattr(stmt, "finalbody", ()))

    walk(tree.body)
    return out


def _check_jax_closure(trees: Dict[str, Tuple[Path, ast.Module, List[str]]]
                       ) -> List[LintFinding]:
    """A103 over the whole file set: flag repro.core/.apps modules whose
    module-level import closure (within the repro tree) reaches jax."""
    imports: Dict[str, Set[str]] = {
        mod: _top_level_imports(tree, mod)
        for mod, (_, tree, _) in trees.items()}

    def reaches_jax(mod: str, seen: Set[str]) -> Optional[List[str]]:
        if mod in seen:
            return None
        seen.add(mod)
        for imp in sorted(imports.get(mod, ())):
            if imp == "jax" or imp.startswith("jax."):
                return [mod, "jax"]
            # resolve the import to a repro module in the lint set (the
            # name itself, or the package it lives in)
            for cand in (imp, imp.rsplit(".", 1)[0]):
                if cand in imports and cand != mod:
                    chain = reaches_jax(cand, seen)
                    if chain is not None:
                        return [mod] + chain
                    break
        return None

    findings: List[LintFinding] = []
    for mod in sorted(trees):
        if not (mod.startswith("repro.core") or mod.startswith("repro.apps")):
            continue
        chain = reaches_jax(mod, set())
        if chain is not None:
            path, _, lines = trees[mod]
            if not _suppressed(lines, 1, "A103"):
                findings.append(LintFinding(
                    str(path), 1, "A103",
                    "module-level import chain reaches jax: "
                    + " -> ".join(chain)))
    return findings


# ------------------------------------------------------------------ driver
def lint_paths(paths: Sequence[str]) -> List[LintFinding]:
    """Lint every ``.py`` file under ``paths``; returns all findings,
    sorted by (path, line, rule)."""
    files: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            files.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            files.append(path)
    findings: List[LintFinding] = []
    trees: Dict[str, Tuple[Path, ast.Module, List[str]]] = {}
    for f in files:
        source = f.read_text(encoding="utf-8")
        try:
            tree = ast.parse(source, filename=str(f))
        except SyntaxError as exc:
            findings.append(LintFinding(
                str(f), exc.lineno or 1, "A103",
                f"unparseable file: {exc.msg}"))
            continue
        module = _module_of(f)
        lines = source.splitlines()
        if module is not None:
            trees[module] = (f, tree, lines)
            linter = _FileLinter(str(f), module, lines)
            linter.visit(tree)
            findings.extend(linter.findings)
    findings.extend(_check_jax_closure(trees))
    findings.sort(key=lambda x: (x.path, x.line, x.rule))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    """CLI: lint the given paths (default ``src/repro``); exit 1 on any
    finding, printing one ``path:line: RULE message (hint: ...)`` row per
    violation."""
    args = list(sys.argv[1:] if argv is None else argv)
    paths = args or ["src/repro"]
    findings = lint_paths(paths)
    for f in findings:
        print(f.render())
    if findings:
        print(f"repro.analysis.lint: {len(findings)} finding(s)",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
