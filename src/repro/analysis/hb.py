"""Happens-before state for the dynamic sanitizer: vector clocks + races.

The model is the classic vector-clock data-race detector, adapted to the
runtime's event vocabulary instead of raw memory operations:

* every **thread** (kernel carrier, scheduler loop, timer thread, test
  thread) owns a vector clock, keyed by ``threading.get_ident()``;
* a **release edge** on a channel (mailbox put, ring submit, future set,
  fiber injection) joins the releasing thread's clock into the channel's
  clock, then advances the releaser;
* an **acquire edge** (mailbox take, ring drain, post-wait resume) joins
  the channel's clock into the acquiring thread's clock;
* a **shared-variable access** (``access(key, write)`` events) is checked
  against the variable's last-writer epoch and read map: any pair of
  accesses, at least one a write, on different threads, with neither
  ordered before the other, is a race.

This is FastTrack-lite: writes keep a single last-writer epoch (the
runtime's counters follow a single-writer-or-locked discipline, so a
write-write race already reports on the second write), reads keep a full
per-thread map (many readers are legal and must all be ordered before the
next write).

The state is *not* itself thread-safe — the sanitizer serializes all event
processing under one lock.  That lock creates real-time ordering but no
model-level edges, which is exactly what a dynamic race detector wants:
the analysis sees the interleaving that actually happened, and only the
edges the runtime explicitly emitted count as synchronization.
"""
from __future__ import annotations

from typing import Dict, Hashable, List, Optional, Tuple

# A vector clock: thread ident -> logical time.  Sparse (absent = 0).
Clock = Dict[int, int]


def _join(into: Clock, other: Clock) -> None:
    for tid, c in other.items():
        if into.get(tid, 0) < c:
            into[tid] = c


class RaceReport:
    """One detected race: the variable, both access epochs, the kind."""

    __slots__ = ("key", "kind", "prev_tid", "curr_tid")

    def __init__(self, key: str, kind: str, prev_tid: int,
                 curr_tid: int) -> None:
        self.key = key
        self.kind = kind            # "write-write" | "read-write" | "write-read"
        self.prev_tid = prev_tid
        self.curr_tid = curr_tid

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"RaceReport({self.key!r}, {self.kind}, "
                f"prev_tid={self.prev_tid}, curr_tid={self.curr_tid})")


class HBState:
    """Vector clocks per thread + per-channel clocks + per-variable epochs."""

    def __init__(self) -> None:
        self._clocks: Dict[int, Clock] = {}
        self._channels: Dict[Hashable, Clock] = {}
        # per shared variable: last write epoch and the clock snapshot the
        # writer held, plus every read epoch since that write
        self._last_write: Dict[str, Tuple[int, int]] = {}   # key -> (tid, c)
        self._write_clock: Dict[str, Clock] = {}
        self._reads: Dict[str, Dict[int, int]] = {}          # key -> tid -> c

    # ------------------------------------------------------------- clocks
    def _clock(self, tid: int) -> Clock:
        clk = self._clocks.get(tid)
        if clk is None:
            clk = self._clocks[tid] = {tid: 1}
        return clk

    def _tick(self, tid: int) -> None:
        clk = self._clock(tid)
        clk[tid] = clk.get(tid, 0) + 1

    def release(self, tid: int, channel: Hashable) -> None:
        """``tid`` publishes its history into ``channel`` (e.g. queue put,
        future set) and advances its own component."""
        clk = self._clock(tid)
        chan = self._channels.setdefault(channel, {})
        _join(chan, clk)
        self._tick(tid)

    def acquire(self, tid: int, channel: Hashable) -> None:
        """``tid`` adopts ``channel``'s history (e.g. queue take, post-wait
        resume): everything released into the channel now happens-before
        every subsequent action of ``tid``."""
        chan = self._channels.get(channel)
        if chan:
            _join(self._clock(tid), chan)

    def fork(self, parent_tid: int, channel: Hashable) -> None:
        """Synonym for :meth:`release` at a spawn point — the child's first
        acquire on the same channel inherits the parent's history."""
        self.release(parent_tid, channel)

    def drop_channel(self, channel: Hashable) -> None:
        """Forget a channel's clock (its object was garbage-collected)."""
        self._channels.pop(channel, None)

    # ------------------------------------------------------------ accesses
    def access(self, tid: int, key: str, write: bool) -> Optional[RaceReport]:
        """Record one access to shared variable ``key``; return the race it
        completes, if any (first race per access reported)."""
        clk = self._clock(tid)
        lw = self._last_write.get(key)
        if lw is not None:
            w_tid, w_c = lw
            if w_tid != tid and clk.get(w_tid, 0) < w_c:
                kind = "write-write" if write else "write-read"
                return RaceReport(key, kind, w_tid, tid)
        if write:
            report = None
            for r_tid, r_c in self._reads.get(key, {}).items():
                if r_tid != tid and clk.get(r_tid, 0) < r_c:
                    report = RaceReport(key, "read-write", r_tid, tid)
                    break
            self._last_write[key] = (tid, clk.get(tid, 0))
            self._write_clock[key] = dict(clk)
            self._reads[key] = {}
            self._tick(tid)
            return report
        self._reads.setdefault(key, {})[tid] = clk.get(tid, 0)
        return None

    # ----------------------------------------------------------- introspect
    def ordered_before(self, a_tid: int, a_c: int, b_tid: int) -> bool:
        """True iff epoch ``(a_tid, a_c)`` happened-before ``b_tid``'s now."""
        return self._clock(b_tid).get(a_tid, 0) >= a_c

    def threads(self) -> List[int]:
        """Idents of every thread the state has seen."""
        return list(self._clocks)
