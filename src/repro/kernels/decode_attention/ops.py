"""Public flash-decode op (no VJP needed — decode is inference-only)."""
from __future__ import annotations

from typing import Optional

import jax

from .kernel import decode_attention_fwd


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, softcap: float = 0.0,
                     scale: Optional[float] = None) -> jax.Array:
    """Single-token GQA attention over a KV cache.

    q: (B,Hq,D); k/v: (B,T,Hkv,D); lengths: (B,) valid slots per sequence.
    """
    return decode_attention_fwd(q, k, v, lengths, softcap=softcap,
                                scale=scale, interpret=_on_cpu())
