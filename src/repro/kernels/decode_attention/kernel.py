"""Flash-decode — single-token KV-cache attention, Pallas TPU kernel.

Decode attention is HBM-bandwidth bound: the whole KV cache is streamed once
per step while the query is tiny.  Tiling: grid (B, Hkv, nK) with the K/V
sequence innermost; the online-softmax state for the *whole GQA group* of
q heads (g = Hq/Hkv rows) lives in VMEM scratch, so each K/V tile is read
exactly once (single HBM pass — the roofline-optimal schedule).

Per-step VMEM at (g, Bk, D) = (8, 512, 128): k/v tiles 2x256 KiB, group
q/acc 2x4 KiB — far under budget, leaving headroom for the next tile's DMA
(double buffering).  Cache-slot validity comes from per-sequence ``lengths``
held in SMEM; K blocks past a sequence's length are skipped entirely.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BK = 512


def _fd_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
               m_ref, l_ref, acc_ref, *,
               scale: float, softcap: float, bk: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    length = len_ref[b]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(ki * bk < length)                     # skip fully-invalid blocks
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                    # (g, D)
        k = k_ref[0].astype(jnp.float32)                       # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale        # (g, bk)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("softcap", "scale", "bk", "interpret"))
def decode_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array, *,
                         softcap: float = 0.0,
                         scale: Optional[float] = None,
                         bk: int = DEFAULT_BK,
                         interpret: bool = False) -> jax.Array:
    """q: (B,Hq,D); k/v: (B,T,Hkv,D); lengths: (B,). Returns (B,Hq,D)."""
    B, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = D ** -0.5 if scale is None else scale
    bk = min(bk, T)
    assert T % bk == 0, (T, bk)

    qf = q.reshape(B, Hkv, g, D)
    kf = k.transpose(0, 2, 1, 3)                     # (B, Hkv, T, D)
    vf = v.transpose(0, 2, 1, 3)

    grid = (B, Hkv, T // bk)
    kernel = functools.partial(_fd_kernel, scale=scale, softcap=softcap,
                               bk=bk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # lengths (B,)
            pl.BlockSpec((1, 1, g, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, D),
                         lambda b, h, ki, Hkv=Hkv: (b * Hkv + h, ki, 0)),
            pl.BlockSpec((1, bk, D),
                         lambda b, h, ki, Hkv=Hkv: (b * Hkv + h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, D), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, D), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qf,
      kf.reshape(B * Hkv, T, D), vf.reshape(B * Hkv, T, D))
    return out.reshape(B, Hq, D)
