"""Pure-jnp oracle for single-token KV-cache attention (flash-decode)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         lengths: jnp.ndarray, *,
                         softcap: float = 0.0,
                         scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B,Hq,D); k/v: (B,T,Hkv,D); lengths: (B,) valid cache length
    (slots [0, length) attended). Returns (B,Hq,D)."""
    B, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = D ** -0.5 if scale is None else scale
    qg = q.reshape(B, Hkv, g, D)
    s = jnp.einsum("bhgd,bthd->bhgt", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    valid = jnp.arange(T)[None] < lengths[:, None]          # (B,T)
    s = jnp.where(valid[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", p.astype(v.dtype), v)
    return out.reshape(B, Hq, D)
