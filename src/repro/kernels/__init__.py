"""Pallas TPU kernels for the compute hot-spots.

The paper itself is host-side (no device kernels); these kernels implement
the serving/training hot-spots of the surrounding framework, TPU-natively:
flash attention (prefill), flash-decode (KV-cache attention), chunked WKV6
(rwkv6) and a single-pass blocked RG-LRU scan (recurrentgemma).

Each kernel ships three artifacts:
  kernel.py — pl.pallas_call body + BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (interpret-mode fallback on CPU)
  ref.py    — pure-jnp oracle used by the allclose test sweeps
"""
