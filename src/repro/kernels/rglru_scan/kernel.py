"""Blocked RG-LRU linear scan — Pallas TPU kernel.

XLA's ``associative_scan`` lowers to O(log T) full passes over HBM
(~2 log2(T) reads/writes of the (B,T,W) tensor).  This kernel makes exactly
ONE pass: grid (B, W/BW, T/C) with time innermost, the running state held in
VMEM scratch across chunks, and the C-step recurrence unrolled on the VPU
over (1, BW) lanes.  For prefill_32k at W=4096 that is a ~2x log2(32768)/2
= ~7.5x cut in scan HBM traffic (the memory-roofline term).

Tile choice: BW=512 lanes x C=128 steps = 256 KiB fp32 per operand tile —
two operands + output + state well under VMEM, leaving double-buffer room.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BW = 512
DEFAULT_CHUNK = 128


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, hT_ref, h_ref, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    def step(t, h):
        a_t = a_ref[0, t].astype(jnp.float32)         # (BW,)
        b_t = b_ref[0, t].astype(jnp.float32)
        h = a_t * h + b_t
        o_ref[0, t] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[0])
    h_ref[0] = h

    @pl.when(ci == nc - 1)
    def _emit():
        hT_ref[...] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("bw", "chunk", "interpret"))
def rglru_scan_fwd(a: jax.Array, b: jax.Array, h0: jax.Array, *,
                   bw: int = DEFAULT_BW, chunk: int = DEFAULT_CHUNK,
                   interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """a/b: (B,T,W); h0: (B,W). Returns (h (B,T,W), hT (B,W) fp32)."""
    B, T, W = a.shape
    BW = min(bw, W)
    C = min(chunk, T)
    assert W % BW == 0 and T % C == 0, (W, BW, T, C)

    grid = (B, W // BW, T // C)
    kernel = functools.partial(_rglru_kernel, chunk=C)
    out, hT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C, BW), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, C, BW), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, BW), lambda bi, wi, ci: (bi, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, BW), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, BW), lambda bi, wi, ci: (bi, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, W), a.dtype),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, BW), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return out, hT
