from . import ops, ref
from .ops import rglru_scan

__all__ = ["rglru_scan", "ops", "ref"]
