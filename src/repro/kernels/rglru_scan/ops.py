"""Public RG-LRU scan op: single-pass Pallas forward + recompute VJP."""
from __future__ import annotations

from typing import Tuple

import jax

from .kernel import rglru_scan_fwd
from .ref import rglru_scan_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@jax.custom_vjp
def rglru_scan(a: jax.Array, b: jax.Array, h0: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """First-order linear recurrence h_t = a_t h_{t-1} + b_t (single HBM pass)."""
    return rglru_scan_fwd(a, b, h0, interpret=_on_cpu())


def _fwd(a, b, h0):
    return rglru_scan(a, b, h0), (a, b, h0)


def _bwd(res, g):
    a, b, h0 = res
    _, vjp = jax.vjp(rglru_scan_ref, a, b, h0)
    return vjp(g)


rglru_scan.defvjp(_fwd, _bwd)
