"""Pure-jnp oracle for the first-order linear recurrence (step-by-step)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rglru_scan_ref(a: jax.Array, b: jax.Array, h0: jax.Array
                   ) -> Tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + b_t.  a/b: (B,T,W); h0: (B,W), fp32.
    Returns (h (B,T,W), final h (B,W))."""
    a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)

    def step(h, inp):
        a_t, b_t = inp
        h = a_t * h + b_t
        return h, h

    xs = (jnp.moveaxis(a32, 1, 0), jnp.moveaxis(b32, 1, 0))
    hT, hs = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype), hT
