"""Public flash-attention op: Pallas forward + recompute-based VJP.

The backward pass recomputes attention through the jnp reference under
``jax.vjp`` (remat-style).  On TPU the forward kernel is the serving/prefill
hot-spot; training backward goes through XLA's fused attention gradient.
CPU (this container) runs the kernel in interpret mode for validation.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention_fwd
from .ref import flash_attention_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0,
                    scale: Optional[float] = None) -> jax.Array:
    """GQA flash attention. q: (B,S,Hq,D); k/v: (B,T,Hkv,D)."""
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale,
                               interpret=_on_cpu())


def _fwd(q, k, v, causal, window, softcap, scale):
    out = flash_attention(q, k, v, causal, window, softcap, scale)
    return out, (q, k, v)


def _bwd(causal, window, softcap, scale, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: flash_attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
