"""Pure-jnp oracle for flash attention (GQA, causal, window, softcap)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import jax


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B,S,Hq,D); k/v: (B,T,Hkv,D). fp32 softmax. Returns (B,S,Hq,D)."""
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = D ** -0.5 if scale is None else scale
    qg = q.reshape(B, S, Hkv, g, D)
    s = jnp.einsum("bshgd,bthd->bhgst", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    valid = jnp.ones((S, T), bool)
    if causal:
        # decode-style alignment: q position i corresponds to absolute
        # position i + (T - S)
        valid &= kpos <= qpos + (T - S)
    if window:
        valid &= kpos > qpos + (T - S) - window
    s = jnp.where(valid[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", p.astype(v.dtype), v)
    return out.reshape(B, S, Hq, D)
