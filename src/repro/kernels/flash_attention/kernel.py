"""Flash attention forward — Pallas TPU kernel.

Tiling: grid (B*Hq, nQ, nK), K innermost (sequential on TPU), with the
online-softmax running state (m, l, acc) in VMEM scratch carried across K
blocks.  Per-step VMEM working set at (Bq, Bk, D) = (256, 256, 128):

    q tile 256x128 f32 (128 KiB) + k/v tiles (2x128 KiB)
    + acc 256x128 f32 (128 KiB) + scores 256x256 f32 (256 KiB)  <  1 MiB

well inside the 16 MiB/core budget, leaving room for double buffering of the
HBM->VMEM pipeline (the paper's overlap-the-waits insight applied at the
memory hierarchy level).  MXU dims are multiples of 128.  Causal/window
masking skips fully-masked K blocks via pl.when (no MXU work issued).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

DEFAULT_BQ = 256
DEFAULT_BK = 256


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, window: int, softcap: float,
               bq: int, bk: int, seq_q: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions (q offset by T-S for decode-style alignment)
    q_off = seq_k - seq_q

    def _compute():
        q = q_ref[0].astype(jnp.float32)                    # (bq, D)
        k = k_ref[0].astype(jnp.float32)                    # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale     # (bq, bk)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap

        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
            + q_off
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = kpos < seq_k
        if causal:
            valid &= kpos <= qpos
        if window:
            valid &= kpos > qpos - window
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]                                  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                               # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                      # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal or window:
        # block-level skip: entirely-masked K blocks issue no MXU work
        first_q = qi * bq + q_off
        last_q = first_q + bq - 1
        first_k = ki * bk
        last_k = first_k + bk - 1
        live = jnp.bool_(True)
        if causal:
            live &= first_k <= last_q
        if window:
            live &= last_k > first_q - window
        pl.when(live)(_compute)
    else:
        _compute()

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "bq", "bk",
                     "interpret"))
def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        softcap: float = 0.0,
                        scale: Optional[float] = None,
                        bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                        interpret: bool = False) -> jax.Array:
    """q: (B,S,Hq,D); k/v: (B,T,Hkv,D) -> (B,S,Hq,D)."""
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = D ** -0.5 if scale is None else scale
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)

    # layout: fold heads into batch; kv head index = q head // g
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, T, D)

    grid = (B * Hq, S // bq, T // bk)
    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, seq_q=S, seq_k=T)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, ki, g=g: (bh // g, ki, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, ki, g=g: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max
            pltpu.VMEM((bq, 1), jnp.float32),     # running sum
            pltpu.VMEM((bq, D), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, S, D).transpose(0, 2, 1, 3)
