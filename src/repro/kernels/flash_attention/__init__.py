from . import ops, ref
from .ops import flash_attention

__all__ = ["flash_attention", "ops", "ref"]
