"""Pure-jnp oracle for the WKV6 recurrence (step-by-step lax.scan)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def wkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, state: jax.Array
             ) -> Tuple[jax.Array, jax.Array]:
    """r/k/v/w: (B,T,H,D); u: (H,D); state: (B,H,D,D) fp32 [k-dim x v-dim].

        a_t   = k_t^T v_t
        out_t = r_t (S_t + diag(u) a_t)
        S_t+1 = diag(w_t) S_t + a_t

    Returns (out (B,T,H,D) in r.dtype, final state fp32).
    """
    r32, k32, v32, w32 = (t.astype(jnp.float32) for t in (r, k, v, w))
    u32 = u.astype(jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        a = k_t[..., :, None] * v_t[..., None, :]
        out = jnp.einsum("bhk,bhkv->bhv", r_t, S + u32[None, :, :, None] * a)
        S = w_t[..., :, None] * S + a
        return S, out

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r32, k32, v32, w32))
    state, outs = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype), state
