from . import ops, ref
from .ops import wkv6

__all__ = ["wkv6", "ops", "ref"]
