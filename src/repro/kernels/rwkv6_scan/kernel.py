"""Chunked WKV6 — Pallas TPU kernel.

The WKV6 recurrence is a gated linear attention: a naive step-by-step scan
does T sequential (D x D) state updates with no MXU utilization.  The chunked
form processes C tokens at once with dense matmuls (TPU-native adaptation of
the paper family's CUDA kernels):

  within a chunk, with cumulative per-channel log-decay L_t = sum_{j<=t} log w_j:
    out_t = (r_t * exp(L_{t-1})) @ S0                          (state term, MXU)
          + sum_{s<t} [sum_d r_td k_sd exp(L_{t-1}-L_s)] v_s   (intra, pairwise)
          + (r_t * u * k_t) @ v_t                              (diagonal bonus)
    S_next = diag(exp(L_C)) S0 + sum_s (exp(L_C - L_s) * k_s)^T v_s

  Every exponent is <= 0 (decays are < 1), so exp() never overflows and
  underflow saturates harmlessly at 0 — numerically stable without the
  1/decay rescaling trick GPU kernels use.

Tiling: grid (B, H, T/C), chunk dim innermost/sequential, the (D x D) fp32
state carried in VMEM scratch.  At C=64, D=64: pairwise tensor (C,C,D) fp32
= 1 MiB, state 16 KiB, tiles 4x16 KiB — comfortably inside VMEM.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _wkv6_kernel(r_ref, k_ref, v_ref, logw_ref, u_ref, s0_ref,
                 o_ref, sT_ref, state_ref, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)
    C = chunk

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0].astype(jnp.float32)            # (C, D)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)
    logw = logw_ref[0, :, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)                  # (1, D) -> (D,)

    L = jnp.cumsum(logw, axis=0)                      # (C, D), all <= 0
    Lprev = L - logw                                  # L_{t-1} (zero at t=0)

    S0 = state_ref[...]                               # (D, Dv)
    # ---- state term: (r_t * exp(L_{t-1})) @ S0
    r_dec = r * jnp.exp(Lprev)
    out = jax.lax.dot_general(r_dec, S0, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)

    # ---- intra-chunk pairwise term (strictly causal s < t)
    # P[t,s] = sum_d r_td k_sd exp(Lprev_t - L_s)_d  (exponent <= 0 for s < t)
    diff = Lprev[:, None, :] - L[None, :, :]          # (C, C, D)
    tri = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0) \
        > jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    pair = jnp.where(tri[:, :, None], jnp.exp(diff), 0.0)
    P = jnp.einsum("td,sd,tsd->ts", r, k, pair,
                   preferred_element_type=jnp.float32)
    out = out + jax.lax.dot_general(P, v, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)

    # ---- diagonal bonus: (r_t * u * k_t) . v_t
    out = out + jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * v
    o_ref[0, :, 0] = out.astype(o_ref.dtype)

    # ---- state update: S_next = diag(exp(L_C)) S0 + (exp(L_C - L) * k)^T v
    dC = jnp.exp(L[-1])                               # (D,)
    k_dec = k * jnp.exp(L[-1][None, :] - L)           # (C, D)
    state_ref[...] = dC[:, None] * S0 + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == nc - 1)
    def _emit_state():
        sT_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_fwd(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, state: jax.Array, *, chunk: int = DEFAULT_CHUNK,
             interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """r/k/v/w: (B,T,H,D); u: (H,D); state: (B,H,D,D) fp32.
    Returns (out (B,T,H,D), final state (B,H,D,D))."""
    B, T, H, D = r.shape
    C = min(chunk, T)
    assert T % C == 0, (T, C)
    logw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-38))

    grid = (B, H, T // C)
    kernel = functools.partial(_wkv6_kernel, chunk=C)
    tile = lambda b, h, c: (b, c, h, 0)
    out, sT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C, 1, D), tile),
            pl.BlockSpec((1, C, 1, D), tile),
            pl.BlockSpec((1, C, 1, D), tile),
            pl.BlockSpec((1, C, 1, D), tile),
            pl.BlockSpec((1, D), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, 1, D), tile),
            pl.BlockSpec((1, 1, D, D), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, D), r.dtype),
            jax.ShapeDtypeStruct((B, H, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, state.astype(jnp.float32))
    return out, sT
