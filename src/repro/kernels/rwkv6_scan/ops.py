"""Public WKV6 op: chunked Pallas forward + recompute VJP via the reference."""
from __future__ import annotations

from typing import Tuple

import jax

from .kernel import wkv6_fwd
from .ref import wkv6_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@jax.custom_vjp
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """WKV6 recurrence. r/k/v/w: (B,T,H,D); u: (H,D); state: (B,H,D,D)."""
    return wkv6_fwd(r, k, v, w, u, state, interpret=_on_cpu())


def _fwd(r, k, v, w, u, state):
    out = wkv6(r, k, v, w, u, state)
    return out, (r, k, v, w, u, state)


def _bwd(res, g):
    r, k, v, w, u, state = res
    _, vjp = jax.vjp(wkv6_ref, r, k, v, w, u, state)
    return vjp(g)


wkv6.defvjp(_fwd, _bwd)
