"""Model server as a microservice graph on the async-RPC runtime.

    api ──async──> tokenizer          (CPU-side text work)
     │
     └──async──> engine.submit       (parks until generation completes)
    engine driver fiber: admit -> prefill -> continuous decode steps
                        (device work via Offload; never blocks the scheduler)

Under the paper's baseline ("thread") every submit is a blocked kernel
thread and every async call spawns one more; under "fiber" they are parked
fibers on one scheduler — the DeathStarBench contrast, applied to an LLM
server.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import numpy as np

from ..core import (App, AsyncRpc, Compute, Offload, ServiceSpec, Sleep,
                    Wait, WaitAll)
from .engine import InferenceEngine, ServeConfig

IDLE_SLEEP = 0.002


def _tokenize(svc: Any, payload: Any):
    """Toy tokenizer service: bytes -> token ids (real CPU work)."""
    yield Compute(5e-6)
    text = payload["text"]
    ids = np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(np.int32)
    vocab = svc.state["vocab_size"]
    return {"ids": ids % vocab}


def _detokenize(svc: Any, payload: Any):
    yield Compute(5e-6)
    return {"text": " ".join(str(t) for t in payload["ids"])}


def _generate(svc: Any, payload: Any):
    """API front: tokenize + submit (async), then detokenize the result."""
    f_tok = yield AsyncRpc("tokenizer", "tokenize", payload)
    tok = yield Wait(f_tok)
    f_gen = yield AsyncRpc("engine", "submit",
                           {"ids": tok["ids"],
                            "max_new": payload.get("max_new")})
    gen = yield Wait(f_gen)
    f_det = yield AsyncRpc("detokenizer", "detokenize", gen)
    det = yield Wait(f_det)
    return {"text": det["text"], "tokens": gen["ids"]}


def _submit(svc: Any, payload: Any):
    """Parks (fiber) / blocks (thread) until the engine finishes the request
    — the paper's wait-dominated async pattern."""
    engine: InferenceEngine = svc.state["engine"]
    done = engine.submit(payload["ids"], payload.get("max_new"))
    tokens = yield Wait(done)
    return {"ids": tokens}


def _run(svc: Any, payload: Any):
    """The engine driver: a single long-lived fiber."""
    engine: InferenceEngine = svc.state["engine"]
    while not svc.state.get("stop"):
        progressed = False
        admitted = engine.admit_one()
        if admitted is not None:
            req = admitted[0]
            yield Wait((yield Offload(engine.do_prefill, (req,))))
            progressed = True
        finished = yield Wait((yield Offload(engine.do_decode_step)))
        if finished:
            progressed = True
        if not progressed and not engine.has_work():
            yield Sleep(IDLE_SLEEP)
    return "stopped"


def build_llm_app(model, params, scfg: Optional[ServeConfig] = None,
                  backend: str = "fiber") -> App:
    """Wire the LLM server; call ``app.send('engine', 'run', None)`` once
    after ``app.start()`` to launch the driver."""
    scfg = scfg or ServeConfig()
    engine = InferenceEngine(model, params, scfg)
    app = App(backend=backend, offload_threads=2)
    app.add_service(ServiceSpec(
        "api", {"generate": _generate}, n_workers=2))
    app.add_service(ServiceSpec(
        "tokenizer", {"tokenize": _tokenize}, n_workers=1,
        state={"vocab_size": model.cfg.vocab_size}))
    app.add_service(ServiceSpec(
        "detokenizer", {"detokenize": _detokenize}, n_workers=1))
    app.add_service(ServiceSpec(
        "engine", {"submit": _submit, "run": _run}, n_workers=2,
        state={"engine": engine}))
    app.state = {"engine": engine}  # type: ignore[attr-defined]
    return app
