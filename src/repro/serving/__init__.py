"""Serving substrate: continuous-batching engine on the async-RPC runtime."""
from .engine import InferenceEngine, ServeConfig
from .service import build_llm_app

__all__ = ["InferenceEngine", "ServeConfig", "build_llm_app"]
