"""Continuous-batching inference engine.

vLLM-style slot-based continuous batching, with the *orchestration* —
admission, step loop, per-request completion — running on the repro.core
async runtime.  Every pending request is a parked **fiber** (or a blocked
kernel thread under the paper's baseline backend); device work goes through
``Offload`` so the scheduler never blocks on XLA.

The engine supports the decoder-LM families (dense / moe / vlm-text); the
recurrent families serve through the same Model API but keep O(1) state, so
slot caches are trivially small.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.future import Future
from ..models import Model


@dataclass
class ServeConfig:
    max_batch: int = 4            # concurrent decode slots
    max_len: int = 256            # slot KV capacity
    prefill_bucket: int = 64      # prompts padded to this length
    max_new_tokens: int = 32
    eos_token: int = -1           # -1: never stops early
    greedy: bool = True


@dataclass
class _Request:
    prompt: np.ndarray
    done: Future
    max_new: int
    tokens: List[int] = field(default_factory=list)
    slot: int = -1
    pos: int = 0                  # next absolute position to write


class InferenceEngine:
    """Slot-based continuous batching over a shared padded KV cache."""

    def __init__(self, model: Model, params: Any, scfg: ServeConfig) -> None:
        assert not model.cfg.is_encdec, \
            "the engine serves decoder-only families (dense/moe/ssm/hybrid)"
        self.model = model
        self.params = params
        self.scfg = scfg
        cfg = model.cfg

        self._lock = threading.Lock()
        self._pending: Deque[_Request] = deque()
        self._active: Dict[int, _Request] = {}
        self._free = list(range(scfg.max_batch))
        # engine-wide decode state (padded to max_batch)
        self.cache = model.init_cache(scfg.max_batch, scfg.max_len)
        self.steps = 0
        self.generated = 0

        # --- jitted device functions -------------------------------------
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)
        self._insert = jax.jit(self._insert_impl)

    # ------------------------------------------------------------ plumbing
    def _insert_impl(self, cache: Any, pcache: Any, slot: jax.Array) -> Any:
        """Copy a prefill cache (batch=1) into one slot of the engine cache.

        Leaves are (L, B, ...) with the prefill leaf (L, 1, ...); when the
        prefill leaf is shorter along the sequence dim (full caches), it is
        placed at positions [0, P).  Recurrent-state leaves match exactly.
        """
        def ins(big, small):
            row = small[:, 0].astype(big.dtype)        # (L, ...)
            if row.shape != big.shape[:1] + big.shape[2:]:
                row = jax.lax.dynamic_update_slice_in_dim(
                    big[:, slot], row, 0, axis=1)
            return jax.lax.dynamic_update_index_in_dim(big, row, slot, axis=1)
        return jax.tree.map(ins, cache, pcache)

    def submit(self, prompt: np.ndarray,
               max_new: Optional[int] = None) -> Future:
        req = _Request(prompt=np.asarray(prompt, np.int32), done=Future(),
                       max_new=max_new or self.scfg.max_new_tokens)
        with self._lock:
            self._pending.append(req)
        return req.done

    # ------------------------------------------------------- engine phases
    def admit_one(self) -> Optional[Tuple[Any, ...]]:
        """Pop one pending request + a free slot (engine fiber calls this)."""
        with self._lock:
            if not self._pending or not self._free:
                return None
            req = self._pending.popleft()
            req.slot = self._free.pop()
        return (req,)

    def do_prefill(self, req: _Request) -> None:
        """Blocking device work — runs on the offload pool."""
        P = self.scfg.prefill_bucket
        n = min(len(req.prompt), P)
        padded = np.zeros((1, P), np.int32)
        padded[0, :n] = req.prompt[:n]
        logits, pcache = self._prefill(self.params, {"tokens": padded})
        self.cache = self._insert(self.cache, pcache,
                                  jnp.asarray(req.slot, jnp.int32))
        tok = int(np.argmax(np.asarray(logits)[0]))
        req.tokens.append(tok)
        req.pos = P                      # next insert position
        with self._lock:
            self._active[req.slot] = req

    def do_decode_step(self) -> List[_Request]:
        """One continuous-batching decode step (offload-pool work).
        Returns requests that finished this step."""
        with self._lock:
            active = dict(self._active)
        if not active:
            return []
        B = self.scfg.max_batch
        toks = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        for slot, req in active.items():
            toks[slot, 0] = req.tokens[-1]
            pos[slot] = req.pos
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks),
                                          jnp.asarray(pos))
        logits = np.asarray(logits)
        self.steps += 1
        finished = []
        with self._lock:
            for slot, req in active.items():
                tok = int(np.argmax(logits[slot]))
                req.tokens.append(tok)
                req.pos += 1
                self.generated += 1
                done = (len(req.tokens) >= req.max_new
                        or tok == self.scfg.eos_token
                        or req.pos >= self.scfg.max_len - 1)
                if done:
                    finished.append(req)
                    del self._active[req.slot]
                    self._free.append(req.slot)
        for req in finished:          # resolve outside the lock
            req.done.set_result(req.tokens)
        return finished

    def has_work(self) -> bool:
        with self._lock:
            return bool(self._pending or self._active)
