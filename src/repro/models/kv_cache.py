"""KV-cache / recurrent-state containers for decode.

All caches are plain pytrees (dicts of arrays) with a leading layer dim that
aligns with scan-over-layers.  Shapes:

  full    : k/v (L, B, Smax, Hkv, D), pos-indexed scatter insert
  mla     : latent (L, B, Smax, kv_lora), k_rope (L, B, Smax, 1, dr)
  window  : k/v (L, B, W, Hkv, D) ring buffer + slot positions (L, B, W)
  rwkv    : wkv state (L, B, H, Dk, Dv) fp32 + token-shift prevs (L, B, d)
  lru     : h (L, B, lru_width) fp32 + conv tail (L, B, cw-1, lru_width)
  encdec  : decoder self full-cache + precomputed cross k/v

``long_500k`` stays feasible for ssm/hybrid because their state is O(1) in
sequence length (rwkv/lru) or bounded by the attention window (ring buffer).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig


def _maybe(shape, dtype, abstract: bool):
    if abstract:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jnp.zeros(shape, dtype)


def _maybe_full(shape, value, dtype, abstract: bool):
    if abstract:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jnp.full(shape, value, dtype)


# ------------------------------------------------------------------- full
def init_full_cache(cfg: ModelConfig, n_layers: int, batch: int, max_len: int,
                    abstract: bool = False) -> Dict[str, Any]:
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": _maybe(shape, cfg.cdtype, abstract),
            "v": _maybe(shape, cfg.cdtype, abstract)}


def update_full_cache(ck: jax.Array, cv: jax.Array, k: jax.Array,
                      v: jax.Array, pos: jax.Array
                      ) -> Tuple[jax.Array, jax.Array]:
    """Insert one token per sequence. ck/cv: (B,Smax,H,D); k/v: (B,1,H,D)."""
    b = jnp.arange(ck.shape[0])
    ck = ck.at[b, pos].set(k[:, 0].astype(ck.dtype))
    cv = cv.at[b, pos].set(v[:, 0].astype(cv.dtype))
    return ck, cv


# -------------------------------------------------------------------- MLA
def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                   abstract: bool = False) -> Dict[str, Any]:
    L = cfg.n_layers
    return {
        "latent": _maybe((L, batch, max_len, cfg.kv_lora_rank), cfg.cdtype,
                         abstract),
        "k_rope": _maybe((L, batch, max_len, 1, cfg.qk_rope_dim), cfg.cdtype,
                         abstract),
    }


def update_mla_cache(clat: jax.Array, crope: jax.Array, latent: jax.Array,
                     k_rope: jax.Array, pos: jax.Array
                     ) -> Tuple[jax.Array, jax.Array]:
    b = jnp.arange(clat.shape[0])
    clat = clat.at[b, pos].set(latent[:, 0].astype(clat.dtype))
    crope = crope.at[b, pos].set(k_rope[:, 0].astype(crope.dtype))
    return clat, crope


# ------------------------------------------------------------------ window
def init_window_cache(cfg: ModelConfig, n_layers: int, batch: int,
                      abstract: bool = False) -> Dict[str, Any]:
    W = cfg.attn_window
    shape = (n_layers, batch, W, cfg.n_kv_heads, cfg.head_dim)
    return {"k": _maybe(shape, cfg.cdtype, abstract),
            "v": _maybe(shape, cfg.cdtype, abstract),
            "pos": _maybe_full((n_layers, batch, W), -1, jnp.int32, abstract)}


def update_window_cache(ck: jax.Array, cv: jax.Array, cpos: jax.Array,
                        k: jax.Array, v: jax.Array, pos: jax.Array
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Ring insert at slot pos % W. ck/cv: (B,W,H,D), cpos: (B,W)."""
    W = ck.shape[1]
    b = jnp.arange(ck.shape[0])
    slot = pos % W
    ck = ck.at[b, slot].set(k[:, 0].astype(ck.dtype))
    cv = cv.at[b, slot].set(v[:, 0].astype(cv.dtype))
    cpos = cpos.at[b, slot].set(pos)
    return ck, cv, cpos


# -------------------------------------------------------------------- rwkv
def init_rwkv_state(cfg: ModelConfig, batch: int,
                    abstract: bool = False) -> Dict[str, Any]:
    L, d = cfg.n_layers, cfg.d_model
    H, hs = cfg.rwkv_n_heads, cfg.rwkv_head_size
    return {
        "wkv": _maybe((L, batch, H, hs, hs), jnp.float32, abstract),
        "att_prev": _maybe((L, batch, d), cfg.cdtype, abstract),
        "ffn_prev": _maybe((L, batch, d), cfg.cdtype, abstract),
    }


# --------------------------------------------------------------------- lru
def init_hybrid_cache(cfg: ModelConfig, batch: int,
                      abstract: bool = False) -> Dict[str, Any]:
    n_rec = sum(1 for i in range(cfg.n_layers)
                if cfg.block_pattern[i % len(cfg.block_pattern)] == "rec")
    n_attn = cfg.n_layers - n_rec
    return {
        "h": _maybe((n_rec, batch, cfg.lru_width), jnp.float32, abstract),
        "conv": _maybe((n_rec, batch, cfg.conv_width - 1, cfg.lru_width),
                       cfg.cdtype, abstract),
        "attn": init_window_cache(cfg, n_attn, batch, abstract),
    }


# ------------------------------------------------------------------ encdec
def init_encdec_cache(cfg: ModelConfig, batch: int, max_len: int,
                      abstract: bool = False) -> Dict[str, Any]:
    Ld = cfg.n_layers
    cross_shape = (Ld, batch, cfg.cross_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "self": init_full_cache(cfg, Ld, batch, max_len, abstract),
        "cross_k": _maybe(cross_shape, cfg.cdtype, abstract),
        "cross_v": _maybe(cross_shape, cfg.cdtype, abstract),
    }


# ---------------------------------------------------------------- dispatch
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               abstract: bool = False) -> Dict[str, Any]:
    if cfg.family == "ssm":
        return init_rwkv_state(cfg, batch, abstract)
    if cfg.family == "hybrid":
        return init_hybrid_cache(cfg, batch, abstract)
    if cfg.is_encdec:
        return init_encdec_cache(cfg, batch, max_len, abstract)
    if cfg.use_mla:
        return init_mla_cache(cfg, batch, max_len, abstract)
    return init_full_cache(cfg, cfg.n_layers, batch, max_len, abstract)
