"""Parameter initialization (stacked-layer pytrees).

``init_params`` materializes real arrays; ``abstract_params`` returns
ShapeDtypeStructs via ``jax.eval_shape`` so the multi-pod dry-run never
allocates 405B-parameter models on the host.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .rwkv6 import DECAY_LORA, TM_LORA


def _mk(rng_and_counter, shape, std=0.02, dtype=None, kind="normal"):
    rng, counter, pdtype = rng_and_counter
    counter[0] += 1
    key = jax.random.fold_in(rng, counter[0])
    dtype = dtype or pdtype
    if kind == "normal":
        return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32)
                * std).astype(dtype)
    if kind == "zeros":
        return jnp.zeros(shape, dtype)
    if kind == "ones":
        return jnp.ones(shape, dtype)
    if kind == "const":
        return jnp.full(shape, std, dtype)
    raise ValueError(kind)


def _gqa_attn(mk, cfg: ModelConfig, L: int) -> Dict[str, Any]:
    d, H, Hkv, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": mk((L, d, H * D)),
        "wk": mk((L, d, Hkv * D)),
        "wv": mk((L, d, Hkv * D)),
        "wo": mk((L, H * D, d), std=0.02 / (2 * cfg.n_layers) ** 0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = mk((L, H * D), kind="zeros")
        p["bk"] = mk((L, Hkv * D), kind="zeros")
        p["bv"] = mk((L, Hkv * D), kind="zeros")
    if cfg.qk_norm:
        p["q_norm"] = mk((L, D), kind="ones")
        p["k_norm"] = mk((L, D), kind="ones")
    return p


def _mla_attn(mk, cfg: ModelConfig, L: int) -> Dict[str, Any]:
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq_a": mk((L, d, cfg.q_lora_rank)),
        "q_norm": mk((L, cfg.q_lora_rank), kind="ones"),
        "wq_b": mk((L, cfg.q_lora_rank, H * (dn + dr))),
        "wkv_a": mk((L, d, cfg.kv_lora_rank + dr)),
        "kv_norm": mk((L, cfg.kv_lora_rank), kind="ones"),
        "wkv_b": mk((L, cfg.kv_lora_rank, H * (dn + dv))),
        "wo": mk((L, H * dv, d), std=0.02 / (2 * cfg.n_layers) ** 0.5),
    }


def _mlp(mk, cfg: ModelConfig, L: int, gated: bool = True) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    down_std = 0.02 / (2 * cfg.n_layers) ** 0.5
    if cfg.n_experts:
        E = cfg.n_experts * cfg.moe_expert_split
        fs = f // cfg.moe_expert_split
        return {
            "router": mk((L, d, cfg.n_experts), dtype=jnp.float32),
            "w_gate": mk((L, E, d, fs)),
            "w_up": mk((L, E, d, fs)),
            "w_down": mk((L, E, fs, d), std=down_std),
        }
    if gated:
        return {"w_gate": mk((L, d, f)), "w_up": mk((L, d, f)),
                "w_down": mk((L, f, d), std=down_std)}
    return {"w_up": mk((L, d, f)), "w_down": mk((L, f, d), std=down_std)}


# --------------------------------------------------------------- families
def _init_lm(mk, cfg: ModelConfig) -> Dict[str, Any]:
    L, d, V = cfg.n_layers, cfg.d_model, cfg.vocab_size
    attn = _mla_attn(mk, cfg, L) if cfg.use_mla else _gqa_attn(mk, cfg, L)
    params = {
        "embed": mk((V, d)),
        "ln_f": mk((d,), kind="ones"),
        "blocks": {
            "ln1": mk((L, d), kind="ones"),
            "ln2": mk((L, d), kind="ones"),
            "attn": attn,
            "mlp": _mlp(mk, cfg, L),
        },
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = mk((V, d))
    return params


def _init_rwkv(mk, cfg: ModelConfig) -> Dict[str, Any]:
    L, d, f = cfg.n_layers, cfg.d_model, cfg.d_ff
    H, hs = cfg.rwkv_n_heads, cfg.rwkv_head_size
    att = {
        "mu_x": mk((L, d), kind="const", std=0.5),
        "mu_w": mk((L, d), kind="const", std=0.5),
        "mu_k": mk((L, d), kind="const", std=0.5),
        "mu_v": mk((L, d), kind="const", std=0.5),
        "mu_r": mk((L, d), kind="const", std=0.5),
        "mu_g": mk((L, d), kind="const", std=0.5),
        "tm_w1": mk((L, d, 5 * TM_LORA)),
        "tm_w2": mk((L, 5, TM_LORA, d), kind="zeros"),
        "decay_w1": mk((L, d, DECAY_LORA)),
        "decay_w2": mk((L, DECAY_LORA, d), kind="zeros"),
        "w0": mk((L, d), kind="const", std=-5.0),
        "u": mk((L, H, hs), std=0.3),
        "wr": mk((L, d, d)), "wk": mk((L, d, d)), "wv": mk((L, d, d)),
        "wg": mk((L, d, d)),
        "wo": mk((L, d, d), std=0.02 / (2 * L) ** 0.5),
        "ln_x_w": mk((L, d), kind="ones"),
        "ln_x_b": mk((L, d), kind="zeros"),
    }
    ffn = {
        "mu_k": mk((L, d), kind="const", std=0.5),
        "mu_r": mk((L, d), kind="const", std=0.5),
        "w_k": mk((L, d, f)),
        "w_v": mk((L, f, d), std=0.02 / (2 * L) ** 0.5),
        "w_r": mk((L, d, d)),
    }
    return {
        "embed": mk((cfg.vocab_size, d)),
        "lm_head": mk((cfg.vocab_size, d)),
        "ln0_w": mk((d,), kind="ones"), "ln0_b": mk((d,), kind="zeros"),
        "ln_f_w": mk((d,), kind="ones"), "ln_f_b": mk((d,), kind="zeros"),
        "blocks": {
            "ln1_w": mk((L, d), kind="ones"), "ln1_b": mk((L, d), kind="zeros"),
            "ln2_w": mk((L, d), kind="ones"), "ln2_b": mk((L, d), kind="zeros"),
            "att": att, "ffn": ffn,
        },
    }


def _init_hybrid(mk, cfg: ModelConfig) -> Dict[str, Any]:
    d, f, W = cfg.d_model, cfg.d_ff, cfg.lru_width
    kinds = [cfg.block_pattern[i % len(cfg.block_pattern)]
             for i in range(cfg.n_layers)]
    Lr = sum(1 for k in kinds if k == "rec")
    La = cfg.n_layers - Lr
    nb = cfg.n_heads                        # gate blocks
    bw = W // nb
    down_std = 0.02 / (2 * cfg.n_layers) ** 0.5
    rec_blocks = {
        "ln1": mk((Lr, d), kind="zeros"),   # gemma (1+w) convention
        "ln2": mk((Lr, d), kind="zeros"),
        "rec": {
            "w_y": mk((Lr, d, W)),
            "w_x": mk((Lr, d, W)),
            "conv_w": mk((Lr, cfg.conv_width, W), std=0.1),
            "conv_b": mk((Lr, W), kind="zeros"),
            "gate_a_w": mk((Lr, nb, bw, bw), std=bw ** -0.5),
            "gate_a_b": mk((Lr, W), kind="zeros"),
            "gate_i_w": mk((Lr, nb, bw, bw), std=bw ** -0.5),
            "gate_i_b": mk((Lr, W), kind="zeros"),
            "lam": mk((Lr, W), kind="const", std=0.65),
            "w_o": mk((Lr, W, d), std=down_std),
        },
        "mlp": {"w_gate": mk((Lr, d, f)), "w_up": mk((Lr, d, f)),
                "w_down": mk((Lr, f, d), std=down_std)},
    }
    attn_blocks = {
        "ln1": mk((La, d), kind="zeros"),
        "ln2": mk((La, d), kind="zeros"),
        "attn": _gqa_attn(mk, cfg, La),
        "mlp": {"w_gate": mk((La, d, f)), "w_up": mk((La, d, f)),
                "w_down": mk((La, f, d), std=down_std)},
    }
    return {
        "embed": mk((cfg.vocab_size, d)),
        "lm_head": mk((cfg.vocab_size, d)),
        "ln_f": mk((d,), kind="zeros"),
        "rec_blocks": rec_blocks,
        "attn_blocks": attn_blocks,
    }


def _init_encdec(mk, cfg: ModelConfig) -> Dict[str, Any]:
    d, Le, Ld = cfg.d_model, cfg.n_enc_layers, cfg.n_layers
    H, D = cfg.n_heads, cfg.head_dim
    down_std = 0.02 / (2 * (Le + Ld)) ** 0.5
    enc_blocks = {
        "ln1": mk((Le, d), kind="ones"), "ln2": mk((Le, d), kind="ones"),
        "attn": _gqa_attn(mk, cfg, Le),
        "mlp": {"w_up": mk((Le, d, cfg.d_ff)),
                "w_down": mk((Le, cfg.d_ff, d), std=down_std)},
    }
    dec_blocks = {
        "ln1": mk((Ld, d), kind="ones"),
        "ln_cross": mk((Ld, d), kind="ones"),
        "ln2": mk((Ld, d), kind="ones"),
        "attn": _gqa_attn(mk, cfg, Ld),
        "cross": {
            "wq": mk((Ld, d, H * D)), "wk": mk((Ld, d, H * D)),
            "wv": mk((Ld, d, H * D)),
            "wo": mk((Ld, H * D, d), std=down_std),
        },
        "mlp": {"w_up": mk((Ld, d, cfg.d_ff)),
                "w_down": mk((Ld, cfg.d_ff, d), std=down_std)},
    }
    return {
        "embed": mk((cfg.vocab_size, d)),
        "lm_head": mk((cfg.vocab_size, d)),
        "enc_blocks": enc_blocks, "enc_ln_f": mk((d,), kind="ones"),
        "dec_blocks": dec_blocks, "ln_f": mk((d,), kind="ones"),
    }


# ---------------------------------------------------------------- public
def init_params(cfg: ModelConfig, rng: jax.Array) -> Dict[str, Any]:
    counter = [0]
    mk = lambda shape, std=0.02, dtype=None, kind="normal": _mk(
        (rng, counter, cfg.pdtype), shape, std, dtype, kind)
    if cfg.family == "ssm":
        return _init_rwkv(mk, cfg)
    if cfg.family == "hybrid":
        return _init_hybrid(mk, cfg)
    if cfg.is_encdec:
        return _init_encdec(mk, cfg)
    return _init_lm(mk, cfg)


def abstract_params(cfg: ModelConfig) -> Dict[str, Any]:
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.key(0)))


def count_params(cfg: ModelConfig) -> int:
    tree = abstract_params(cfg)
    import math
    return sum(math.prod(x.shape) for x in jax.tree.leaves(tree))
