"""Decoder-only transformer LMs: dense (GQA/MLA), MoE, and VLM (M-RoPE).

All variants share one block body; layers are stacked and driven by
``jax.lax.scan`` (compact HLO at 126 layers), with optional per-layer remat.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed import shard_hint
from .config import ModelConfig
from .kv_cache import update_full_cache, update_mla_cache
from .layers import (attention_scores_mask, embed_tokens, gqa_attend,
                     gqa_project, lm_logits, mla_attend, mla_latent,
                     mla_project_q, moe_ffn, rms_norm, swiglu_mlp)


# ------------------------------------------------------------------ blocks
def block_fwd(x: jax.Array, p: Dict[str, Any], cfg: ModelConfig,
              positions: jax.Array, mask: jax.Array
              ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array], jax.Array]:
    """One decoder block (train/prefill). Returns (x, kv_for_cache, aux).

    The residual stream is re-constrained at the block boundary with the
    "carry_seq" logical axis: when a per-arch rule maps it to "model", the
    remat-saved scan carry is sequence-sharded (16x less HBM for saved
    activations at 126 layers) while the block *interior* stays batch+head
    sharded — an all-gather on entry / slice on exit, Megatron-SP style.
    """
    if x.shape[1] > 1:
        # pin first (anchors the remat-saved carry's sharding), then gather
        x = shard_hint(x, "batch", "carry_seq", None)
        x = shard_hint(x, "batch", None, None)    # gather for the interior
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        qq = mla_project_q(h, p["attn"], cfg, positions)
        latent, k_rope = mla_latent(h, p["attn"], cfg, positions)
        attn = mla_attend(qq, latent, k_rope, mask, p["attn"], cfg)
        kv = (latent, k_rope)
    else:
        q, k, v = gqa_project(h, p["attn"], cfg, positions)
        attn = gqa_attend(q, k, v, mask, p["attn"], cfg)
        kv = (k, v)
    x = x + attn
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        ff, aux = moe_ffn(h, p["mlp"], cfg)
    else:
        ff, aux = swiglu_mlp(h, p["mlp"]), jnp.zeros((), jnp.float32)
    out = x + ff
    if out.shape[1] > 1:
        out = shard_hint(out, "batch", "carry_seq", None)  # boundary carry
    return out, kv, aux


def block_decode(x: jax.Array, p: Dict[str, Any], cfg: ModelConfig,
                 cache_l: Dict[str, jax.Array], pos: jax.Array
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decoder block, single-token decode against the layer's KV cache.

    x: (B,1,d); pos: (B,) absolute position of the new token.
    """
    B = x.shape[0]
    positions = pos[:, None]                                    # (B,1)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        lat_new, rope_new = mla_latent(h, p["attn"], cfg, positions)
        lat, ropek = update_mla_cache(cache_l["latent"], cache_l["k_rope"],
                                      lat_new, rope_new, pos)
        mask = _cache_mask(pos, lat.shape[1])
        qq = mla_project_q(h, p["attn"], cfg, positions)
        attn = mla_attend(qq, lat, ropek, mask, p["attn"], cfg)
        new_cache = {"latent": lat, "k_rope": ropek}
    else:
        q, k_new, v_new = gqa_project(h, p["attn"], cfg, positions)
        ck, cv = update_full_cache(cache_l["k"], cache_l["v"],
                                   k_new, v_new, pos)
        mask = _cache_mask(pos, ck.shape[1])
        ck_a = shard_hint(ck, "batch", "kv_seq", None, None)
        cv_a = shard_hint(cv, "batch", "kv_seq", None, None)
        attn = gqa_attend(q, ck_a, cv_a, mask, p["attn"], cfg)
        new_cache = {"k": ck, "v": cv}
    x = x + attn
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        ff, _ = moe_ffn(h, p["mlp"], cfg)
    else:
        ff = swiglu_mlp(h, p["mlp"])
    return x + ff, new_cache


def _cache_mask(pos: jax.Array, max_len: int) -> jax.Array:
    """(B,1,T) additive mask: valid cache slots are those <= current pos."""
    B = pos.shape[0]
    kpos = jnp.broadcast_to(jnp.arange(max_len, dtype=jnp.int32)[None],
                            (B, max_len))
    kpos = jnp.where(kpos <= pos[:, None], kpos, -1)
    return attention_scores_mask(pos[:, None], kpos, causal=False)


# ------------------------------------------------------------------ model
def embed_inputs(params: Dict[str, Any], cfg: ModelConfig,
                 inputs: Dict[str, jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Token (or merged token/patch) embeddings + positions."""
    tokens = inputs["tokens"]
    x = embed_tokens(tokens, params["embed"], scale=cfg.embed_scale)
    if cfg.family == "vlm" and "embeds" in inputs:
        # vision frontend stub: precomputed patch embeddings replace token
        # embeddings where embed_mask is set (dynamic-resolution images)
        x = jnp.where(inputs["embed_mask"][..., None],
                      inputs["embeds"].astype(x.dtype), x)
    if "positions" in inputs:
        positions = inputs["positions"]           # (B,S) or (3,B,S) M-RoPE
    else:
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return x.astype(cfg.cdtype), positions


def forward(params: Dict[str, Any], cfg: ModelConfig,
            inputs: Dict[str, jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward (train). Returns (hidden, aux_loss)."""
    x, positions = embed_inputs(params, cfg, inputs)
    mask = None   # masks are built lazily (chunked) inside the attention
    # the initial carry must match the block-boundary sharding, or the while
    # loop unifies every iteration's carry to the replicated layout
    x = shard_hint(x, "batch", "carry_seq", None)

    def body(carry, p_l):
        h, aux = carry
        h2, _, aux_l = block_fwd(h, p_l, cfg, positions, mask)
        return (h2, aux + aux_l), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    x = shard_hint(x, "batch", None, None)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return x, aux


def prefill(params: Dict[str, Any], cfg: ModelConfig,
            inputs: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Prefill: forward + emit the per-layer KV cache.
    Returns (last-token logits (B,V), cache)."""
    x, positions = embed_inputs(params, cfg, inputs)
    mask = None   # masks are built lazily (chunked) inside the attention

    def body(h, p_l):
        h2, kv, _ = block_fwd(h, p_l, cfg, positions, mask)
        return h2, kv

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, kvs = jax.lax.scan(body_fn, x, params["blocks"])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = lm_logits(x[:, -1:], _out_table(params, cfg), cfg.logit_softcap)
    if cfg.use_mla:
        cache = {"latent": kvs[0], "k_rope": kvs[1]}
    else:
        cache = {"k": kvs[0], "v": kvs[1]}
    return logits[:, 0], cache


def decode_step(params: Dict[str, Any], cfg: ModelConfig,
                cache: Dict[str, jax.Array], tokens: jax.Array,
                pos: jax.Array
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step. tokens: (B,1); pos: (B,). Returns (logits(B,V), cache)."""
    x = embed_tokens(tokens, params["embed"], scale=cfg.embed_scale)
    x = x.astype(cfg.cdtype)

    if cfg.decode_carry_cache:
        # §Perf variant: thread the whole cache through the scan *carry* so
        # XLA updates it in place (one buffer), instead of streaming it as
        # xs -> stacked ys (two buffers: 2x cache HBM at 405B/32k).
        def body_carry(carry, xs):
            h, c = carry
            p_l, i = xs
            cache_l = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0,
                                                       keepdims=False), c)
            h2, new_cache_l = block_decode(h, p_l, cfg, cache_l, pos)
            c = jax.tree.map(
                lambda a, n: jax.lax.dynamic_update_index_in_dim(
                    a, n.astype(a.dtype), i, 0), c, new_cache_l)
            return (h2, c), None

        L = jax.tree.leaves(cache)[0].shape[0]
        (x, new_cache), _ = jax.lax.scan(
            body_carry, (x, cache),
            (params["blocks"], jnp.arange(L, dtype=jnp.int32)))
    else:
        def body(h, xs):
            p_l, cache_l = xs
            h2, new_cache_l = block_decode(h, p_l, cfg, cache_l, pos)
            return h2, new_cache_l

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = lm_logits(x, _out_table(params, cfg), cfg.logit_softcap)
    return logits[:, 0], new_cache


def _out_table(params: Dict[str, Any], cfg: ModelConfig) -> jax.Array:
    return params["embed"] if cfg.tie_embeddings else params["lm_head"]
