"""RWKV6 "Finch" — attention-free LM with data-dependent decay.

Time-mixing uses the WKV6 linear recurrence over per-head (Dk x Dv) states:

    a_t   = k_t^T v_t                      (outer product)
    out_t = r_t (S_t + diag(u) a_t)
    S_t+1 = diag(w_t) S_t + a_t            (w_t data-dependent, per channel)

plus LoRA-based data-dependent token-shift interpolation (ddlerp) for the
five mix targets (w,k,v,r,g) and a LoRA'd decay.  Channel-mixing is the
squared-ReLU RWKV FFN.  State is O(1) in sequence length — ``long_500k`` runs.

The time recurrence here is the jnp reference (lax.scan over T); the Pallas
chunked kernel in ``repro.kernels.rwkv6_scan`` is the TPU-optimized path.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed import shard_hint
from .config import ModelConfig
from .layers import embed_tokens, group_norm, linear, lm_logits

TM_LORA = 32      # ddlerp LoRA dim
DECAY_LORA = 64   # decay LoRA dim


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dtype)


# ------------------------------------------------------------- time mixing
def _ddlerp(x: jax.Array, xx: jax.Array, p: Dict[str, Any]
            ) -> Tuple[jax.Array, ...]:
    """Data-dependent lerp between x_t and x_{t-1} for the 5 mix targets."""
    B, T, d = x.shape
    base = x + xx * p["mu_x"].astype(x.dtype)
    stacked = jnp.tanh(linear(base, p["tm_w1"])).reshape(B, T, 5, TM_LORA)
    delta = jnp.einsum("btki,kid->btkd", stacked,
                       p["tm_w2"].astype(x.dtype))          # (B,T,5,d)
    mus = jnp.stack([p["mu_w"], p["mu_k"], p["mu_v"], p["mu_r"], p["mu_g"]])
    mixed = x[:, :, None] + xx[:, :, None] * (mus.astype(x.dtype) + delta)
    return tuple(mixed[:, :, i] for i in range(5))


def wkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, state: jax.Array
             ) -> Tuple[jax.Array, jax.Array]:
    """Reference WKV6 recurrence (fp32 state).

    r/k/v/w: (B,T,H,D); u: (H,D); state: (B,H,D,D) [k-dim x v-dim].
    Returns (out (B,T,H,D), final state).
    """
    r32, k32, v32, w32 = (t.astype(jnp.float32) for t in (r, k, v, w))
    u32 = u.astype(jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                            # (B,H,D)
        a = k_t[..., :, None] * v_t[..., None, :]           # (B,H,Dk,Dv)
        out = jnp.einsum("bhk,bhkv->bhv", r_t,
                         S + u32[None, :, :, None] * a)
        S = w_t[..., :, None] * S + a
        return S, out

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r32, k32, v32, w32))
    state, outs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype), state


def time_mix(x: jax.Array, x_prev: jax.Array, wkv_state: jax.Array,
             p: Dict[str, Any], cfg: ModelConfig, use_kernel: bool = False
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """RWKV6 attention replacement.

    x: (B,T,d); x_prev: (B,d) last token of previous chunk;
    wkv_state: (B,H,D,D) fp32.  Returns (out, new_x_prev, new_state).
    """
    B, T, d = x.shape
    H, hs = cfg.rwkv_n_heads, cfg.rwkv_head_size
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    xx = shifted - x
    xw, xk, xv, xr, xg = _ddlerp(x, xx, p)

    r = linear(xr, p["wr"]).reshape(B, T, H, hs)
    k = linear(xk, p["wk"]).reshape(B, T, H, hs)
    v = linear(xv, p["wv"]).reshape(B, T, H, hs)
    g = jax.nn.silu(linear(xg, p["wg"]))

    # data-dependent decay, fp32: w = exp(-exp(w0 + lora(xw)))
    dec = linear(jnp.tanh(linear(xw, p["decay_w1"])), p["decay_w2"])
    logw = p["w0"].astype(jnp.float32) + dec.astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logw)).reshape(B, T, H, hs)

    if use_kernel:
        from ..kernels.rwkv6_scan import ops as wkv_ops
        out, new_state = wkv_ops.wkv6(r, k, v, w, p["u"], wkv_state)
    else:
        out, new_state = wkv6_ref(r, k, v, w, p["u"], wkv_state)

    out = group_norm(out.reshape(B, T, d), p["ln_x_w"], p["ln_x_b"],
                     H, eps=1e-5 * 8)  # rwkv convention: eps*head_size/8
    out = linear(out * g, p["wo"])
    return shard_hint(out, "batch", "seq", None), x[:, -1], new_state


# ---------------------------------------------------------- channel mixing
def channel_mix(x: jax.Array, x_prev: jax.Array, p: Dict[str, Any]
                ) -> Tuple[jax.Array, jax.Array]:
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    xx = shifted - x
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xr = x + xx * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(linear(xk, p["w_k"])))
    k = shard_hint(k, "batch", None, "tp")
    out = jax.nn.sigmoid(linear(xr, p["w_r"]).astype(jnp.float32)).astype(x.dtype) \
        * linear(k, p["w_v"])
    return shard_hint(out, "batch", "seq", None), x[:, -1]


# ------------------------------------------------------------------ blocks
def block(x: jax.Array, state_l: Dict[str, jax.Array], p: Dict[str, Any],
          cfg: ModelConfig, use_kernel: bool = False
          ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    h = layer_norm(x, p["ln1_w"], p["ln1_b"], cfg.norm_eps)
    att, att_prev, wkv = time_mix(h, state_l["att_prev"], state_l["wkv"],
                                  p["att"], cfg, use_kernel)
    x = x + att
    h = layer_norm(x, p["ln2_w"], p["ln2_b"], cfg.norm_eps)
    ffn, ffn_prev = channel_mix(h, state_l["ffn_prev"], p["ffn"])
    x = x + ffn
    return x, {"wkv": wkv, "att_prev": att_prev, "ffn_prev": ffn_prev}


# ------------------------------------------------------------------- model
def forward(params: Dict[str, Any], cfg: ModelConfig,
            inputs: Dict[str, jax.Array], state: Dict[str, jax.Array],
            use_kernel: bool = False, emit_state: bool = True
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunk forward (train: full seq with zero state; serve: continuation).
    Returns (hidden, new_state)."""
    x = embed_tokens(inputs["tokens"], params["embed"]).astype(cfg.cdtype)
    x = layer_norm(x, params["ln0_w"], params["ln0_b"], cfg.norm_eps)

    def body(h, xs):
        p_l, state_l = xs
        h2, new_state_l = block(h, state_l, p_l, cfg, use_kernel)
        return h2, (new_state_l if emit_state else None)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, new_state = jax.lax.scan(body_fn, x, (params["blocks"], state))
    x = layer_norm(x, params["ln_f_w"], params["ln_f_b"], cfg.norm_eps)
    return x, (new_state if emit_state else state)


def decode_step(params: Dict[str, Any], cfg: ModelConfig,
                state: Dict[str, jax.Array], tokens: jax.Array,
                pos: jax.Array
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token step: T=1 forward carrying the recurrent state."""
    del pos  # rwkv has no positional input
    x, new_state = forward(params, cfg, {"tokens": tokens}, state)
    logits = lm_logits(x, params["lm_head"], cfg.logit_softcap)
    return logits[:, -1], new_state
