"""Griffin-style hybrid blocks (RecurrentGemma): RG-LRU + local attention.

Layer pattern (rec, rec, attn) repeating.  The recurrent block:

    y = gelu(W_y x)                               (gate branch)
    u = conv1d_causal(W_x x)                      (depthwise, width 4)
    r_t = sigmoid(blockdiag(A_r) u_t)             (recurrence gate)
    i_t = sigmoid(blockdiag(A_i) u_t)             (input gate)
    a_t = exp(-c * softplus(L) * r_t)             (data-dependent decay, c=8)
    h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t u_t)   (RG-LRU)
    out = W_o (h * y)

The first-order linear recurrence is evaluated with
``jax.lax.associative_scan`` (O(log T) depth — TPU-native adaptation of the
paper's GPU linear-scan kernel); the Pallas blocked kernel in
``repro.kernels.rglru_scan`` is the fused fast path.  Local attention uses a
ring-buffer window cache, so ``long_500k`` decode state stays bounded.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed import shard_hint
from .config import ModelConfig
from .kv_cache import update_window_cache
from .layers import (attention_scores_mask, embed_tokens, gqa_attend,
                     gqa_project, linear, lm_logits, rms_norm)

RGLRU_C = 8.0


# ----------------------------------------------------------------- RG-LRU
def _gates(u: jax.Array, p: Dict[str, Any], n_blocks: int
           ) -> Tuple[jax.Array, jax.Array]:
    """Block-diagonal gate projections (RecurrentGemma convention)."""
    B, T, W = u.shape
    ub = u.reshape(B, T, n_blocks, W // n_blocks)
    ra = jnp.einsum("btnw,nwv->btnv", ub,
                    p["gate_a_w"].astype(u.dtype)).reshape(B, T, W)
    ia = jnp.einsum("btnw,nwv->btnv", ub,
                    p["gate_i_w"].astype(u.dtype)).reshape(B, T, W)
    r = jax.nn.sigmoid(ra + p["gate_a_b"].astype(u.dtype))
    i = jax.nn.sigmoid(ia + p["gate_i_b"].astype(u.dtype))
    return r, i


def rglru_ref(u: jax.Array, r: jax.Array, i: jax.Array,
              lam: jax.Array, h0: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """RG-LRU via associative scan, fp32. u/r/i: (B,T,W); h0: (B,W).
    Returns (h (B,T,W), final state)."""
    u32, r32, i32 = (t.astype(jnp.float32) for t in (u, r, i))
    log_a = -RGLRU_C * jax.nn.softplus(lam.astype(jnp.float32)) * r32
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i32 * u32)
    # prepend h0 as the t=0 element: h_t = a_t h_{t-1} + b_t
    a_ext = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
    b_ext = jnp.concatenate([h0[:, None].astype(jnp.float32), gated], axis=1)

    def combine(l, rgt):
        al, bl = l
        ar, br = rgt
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a_ext, b_ext), axis=1)
    return h[:, 1:].astype(u.dtype), h[:, -1]


def rglru_step(u: jax.Array, r: jax.Array, i: jax.Array,
               lam: jax.Array, h0: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """Single decode step (T=1)."""
    u32, r32, i32 = (t.astype(jnp.float32) for t in (u[:, 0], r[:, 0], i[:, 0]))
    log_a = -RGLRU_C * jax.nn.softplus(lam.astype(jnp.float32)) * r32
    a = jnp.exp(log_a)
    h = a * h0 + jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i32 * u32)
    return h[:, None].astype(u.dtype), h


def causal_conv1d(u: jax.Array, w: jax.Array, b: jax.Array,
                  tail: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. u: (B,T,W); w: (cw,W); tail: (B,cw-1,W).
    Returns (out (B,T,W), new tail)."""
    cw = w.shape[0]
    ext = jnp.concatenate([tail.astype(u.dtype), u], axis=1)   # (B,cw-1+T,W)
    out = jnp.zeros_like(u)
    for j in range(cw):
        out = out + ext[:, j:j + u.shape[1]] * w[cw - 1 - j][None, None]
    out = out + b[None, None].astype(u.dtype)
    new_tail = ext[:, -(cw - 1):] if cw > 1 else tail
    return out, new_tail


# ------------------------------------------------------------------ blocks
def recurrent_block(x: jax.Array, p: Dict[str, Any], cfg: ModelConfig,
                    h0: jax.Array, conv_tail: jax.Array, decode: bool
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Temporal-mix via RG-LRU. Returns (out, new_h, new_conv_tail)."""
    y = jax.nn.gelu(linear(x, p["w_y"]))
    u = linear(x, p["w_x"])
    u = shard_hint(u, "batch", None, "tp")
    u, new_tail = causal_conv1d(u, p["conv_w"], p["conv_b"], conv_tail)
    r, i = _gates(u, p, cfg.n_heads)
    if decode:
        h, hT = rglru_step(u, r, i, p["lam"], h0)
    else:
        h, hT = rglru_ref(u, r, i, p["lam"], h0)
    out = linear(h * y, p["w_o"])
    return shard_hint(out, "batch", "seq", None), hT, new_tail


def hybrid_block(x: jax.Array, kind: str, p: Dict[str, Any],
                 cfg: ModelConfig, state: Dict[str, jax.Array],
                 positions: jax.Array, mask: Any, decode: bool,
                 pos: jax.Array
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One (temporal-mix + MLP) griffin block; kind in {rec, attn}."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps, offset=1.0)
    new_state = dict(state)
    if kind == "rec":
        out, hT, tail = recurrent_block(h, p["rec"], cfg, state["h"],
                                        state["conv"], decode)
        new_state["h"], new_state["conv"] = hT, tail
    else:
        if decode:
            q, k_new, v_new = gqa_project(h, p["attn"], cfg, pos[:, None])
            ck, cv, cpos = update_window_cache(
                state["k"], state["v"], state["pos"], k_new, v_new, pos)
            amask = attention_scores_mask(pos[:, None], cpos, causal=False,
                                          window=cfg.attn_window)
            out = gqa_attend(q, ck, cv, amask, p["attn"], cfg)
            new_state.update({"k": ck, "v": cv, "pos": cpos})
        else:
            q, k, v = gqa_project(h, p["attn"], cfg, positions)
            out = gqa_attend(q, k, v, mask, p["attn"], cfg)
            new_state.update(window_cache_from_chunk(k, v, cfg.attn_window))
    x = x + out
    h = rms_norm(x, p["ln2"], cfg.norm_eps, offset=1.0)
    # GeGLU MLP (gemma convention)
    ff = jax.nn.gelu(linear(h, p["mlp"]["w_gate"])) * linear(h, p["mlp"]["w_up"])
    ff = shard_hint(ff, "batch", None, "tp")
    x = x + linear(ff, p["mlp"]["w_down"])
    return x, new_state


def window_cache_from_chunk(k: jax.Array, v: jax.Array,
                            W: int) -> Dict[str, jax.Array]:
    """Build the ring cache from a prefill chunk: the last W tokens land at
    slot pos % W so subsequent decode inserts stay consistent."""
    B, S = k.shape[:2]
    if S >= W:
        last_pos = jnp.arange(S - W, S, dtype=jnp.int32)
        slots = last_pos % W
        ck = jnp.zeros((B, W) + k.shape[2:], k.dtype).at[:, slots].set(
            k[:, -W:])
        cv = jnp.zeros((B, W) + v.shape[2:], v.dtype).at[:, slots].set(
            v[:, -W:])
        cpos = jnp.zeros((B, W), jnp.int32).at[:, slots].set(
            jnp.broadcast_to(last_pos, (B, W)))
    else:
        pos = jnp.arange(S, dtype=jnp.int32)
        ck = jnp.zeros((B, W) + k.shape[2:], k.dtype).at[:, :S].set(k)
        cv = jnp.zeros((B, W) + v.shape[2:], v.dtype).at[:, :S].set(v)
        cpos = jnp.full((B, W), -1, jnp.int32).at[:, :S].set(
            jnp.broadcast_to(pos, (B, S)))
    return {"k": ck, "v": cv, "pos": cpos}


# ------------------------------------------------------------------- model
def _pattern_layout(cfg: ModelConfig):
    """Group layers into full pattern repeats + remainder; returns
    (n_groups, remainder_kinds)."""
    P = len(cfg.block_pattern)
    n_groups = cfg.n_layers // P
    rem = tuple(cfg.block_pattern[i % P] for i in range(n_groups * P,
                                                        cfg.n_layers))
    return n_groups, rem


def forward(params: Dict[str, Any], cfg: ModelConfig,
            inputs: Dict[str, jax.Array], cache: Dict[str, Any],
            decode: bool, pos: jax.Array, emit_cache: bool = True
            ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Scan over pattern groups; remainder layers run unrolled.

    cache: {"h": (Lr,B,W), "conv": (Lr,B,cw-1,W), "attn": window cache}.
    ``emit_cache=False`` (training) skips stacking per-layer state outputs.
    """
    x = embed_tokens(inputs["tokens"], params["embed"],
                     scale=cfg.embed_scale).astype(cfg.cdtype)
    B, S = inputs["tokens"].shape
    if decode:
        positions, mask = pos[:, None], None
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        mask = None   # lazy/chunked masks inside the attention

    n_groups, rem = _pattern_layout(cfg)
    P = len(cfg.block_pattern)
    rec_per_group = sum(1 for k in cfg.block_pattern if k == "rec")
    attn_per_group = P - rec_per_group

    def group_body(h, xs):
        pg, rec_state, attn_state = xs
        ri = ai = 0
        new_rec, new_attn = [], []
        for kind in cfg.block_pattern:
            if kind == "rec":
                st = {"h": rec_state["h"][ri], "conv": rec_state["conv"][ri]}
                h, ns = hybrid_block(h, kind, _ith(pg["rec"], ri), cfg, st,
                                     positions, mask, decode, pos)
                new_rec.append({"h": ns["h"], "conv": ns["conv"]})
                ri += 1
            else:
                st = {"k": attn_state["k"][ai], "v": attn_state["v"][ai],
                      "pos": attn_state["pos"][ai]}
                h, ns = hybrid_block(h, kind, _ith(pg["attn"], ai), cfg, st,
                                     positions, mask, decode, pos)
                new_attn.append({k: ns[k] for k in ("k", "v", "pos")})
                ai += 1
        stack = lambda ds: {k: jnp.stack([d[k] for d in ds]) for k in ds[0]}
        if not emit_cache:
            return h, None
        return h, (stack(new_rec), stack(new_attn))

    body_fn = jax.checkpoint(group_body) if cfg.remat else group_body

    # split stacked params/caches into scan groups + remainder
    Lr_scan = n_groups * rec_per_group
    La_scan = n_groups * attn_per_group
    rec_p_scan = jax.tree.map(lambda a: _regroup(a, n_groups),
                              _take(params["rec_blocks"], 0, Lr_scan))
    attn_p_scan = jax.tree.map(lambda a: _regroup(a, n_groups),
                               _take(params["attn_blocks"], 0, La_scan))
    rec_c_scan = jax.tree.map(lambda a: _regroup(a, n_groups),
                              _take_cache(cache, "rec", 0, Lr_scan))
    attn_c_scan = jax.tree.map(lambda a: _regroup(a, n_groups),
                               _take_cache(cache, "attn", 0, La_scan))

    x, scanned = jax.lax.scan(
        body_fn, x, ({"rec": rec_p_scan, "attn": attn_p_scan},
                     rec_c_scan, attn_c_scan))

    # remainder layers (unrolled)
    ri, ai = Lr_scan, La_scan
    rec_tail, attn_tail = [], []
    for kind in rem:
        if kind == "rec":
            st = {"h": cache["h"][ri], "conv": cache["conv"][ri]}
            x, ns = hybrid_block(x, kind,
                                 jax.tree.map(lambda a: a[ri],
                                              params["rec_blocks"]),
                                 cfg, st, positions, mask, decode, pos)
            rec_tail.append({"h": ns["h"], "conv": ns["conv"]})
            ri += 1
        else:
            st = {k: cache["attn"][k][ai] for k in ("k", "v", "pos")}
            x, ns = hybrid_block(x, kind,
                                 jax.tree.map(lambda a: a[ai],
                                              params["attn_blocks"]),
                                 cfg, st, positions, mask, decode, pos)
            attn_tail.append({k: ns[k] for k in ("k", "v", "pos")})
            ai += 1

    x = rms_norm(x, params["ln_f"], cfg.norm_eps, offset=1.0)
    if not emit_cache:
        return x, cache

    new_rec, new_attn = scanned
    new_rec = jax.tree.map(_flatten_groups, new_rec)
    new_attn = jax.tree.map(_flatten_groups, new_attn)

    def cat(head, tail_list, key):
        if not tail_list:
            return head
        tail = jnp.stack([t[key] for t in tail_list])
        return jnp.concatenate([head, tail.astype(head.dtype)], axis=0)

    new_cache = {
        "h": cat(new_rec["h"].astype(jnp.float32), rec_tail, "h"),
        "conv": cat(new_rec["conv"], rec_tail, "conv"),
        "attn": {k: cat(new_attn[k], attn_tail, k)
                 for k in ("k", "v", "pos")},
    }
    return x, new_cache


def decode_step(params: Dict[str, Any], cfg: ModelConfig,
                cache: Dict[str, Any], tokens: jax.Array, pos: jax.Array
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    x, new_cache = forward(params, cfg, {"tokens": tokens}, cache,
                           decode=True, pos=pos)
    logits = lm_logits(x, params["lm_head"], cfg.logit_softcap)
    return logits[:, -1], new_cache


# -------------------------------------------------------------- utilities
def _ith(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _take(tree, start, end):
    return jax.tree.map(lambda a: a[start:end], tree)


def _take_cache(cache, which, start, end):
    if which == "rec":
        return {"h": cache["h"][start:end], "conv": cache["conv"][start:end]}
    return {k: cache["attn"][k][start:end] for k in ("k", "v", "pos")}


def _regroup(a: jax.Array, n_groups: int) -> jax.Array:
    """(G*n, ...) -> (G, n, ...) for scan-over-groups."""
    return a.reshape((n_groups, a.shape[0] // n_groups) + a.shape[1:])


def _flatten_groups(a: jax.Array) -> jax.Array:
    """(G, n, ...) -> (G*n, ...)."""
    return a.reshape((a.shape[0] * a.shape[1],) + a.shape[2:])
