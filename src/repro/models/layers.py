"""Shared neural-net layers (pure JAX, sharding-hint annotated).

Numerics policy: parameters/compute in bf16, softmax + normalization +
recurrence states in fp32.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed import shard_hint
from .config import ModelConfig

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------- norms
def rms_norm(x: jax.Array, weight: jax.Array, eps: float,
             *, offset: float = 0.0) -> jax.Array:
    """RMSNorm in fp32; ``offset=1`` gives the Gemma (1+w) convention."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (offset + weight.astype(jnp.float32))).astype(dtype)


def group_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               n_groups: int, eps: float) -> jax.Array:
    """GroupNorm over the last dim (RWKV's ln_x), fp32."""
    dtype = x.dtype
    *lead, d = x.shape
    x = x.astype(jnp.float32).reshape(*lead, n_groups, d // n_groups)
    mean = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    x = x.reshape(*lead, d)
    return (x * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dtype)


# ----------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               sections: Tuple[int, ...] = ()) -> jax.Array:
    """Rotary embedding (NeoX half-rotation layout).

    x: (B, S, H, D); positions: (B, S) — or (3, B, S) for M-RoPE, where the
    three planes are the temporal / height / width position components and
    ``sections`` splits the D/2 frequency channels among them (Qwen2-VL).
    """
    dtype = x.dtype
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                                  # (D/2,)
    if positions.ndim == 3:
        assert sections and sum(sections) == d // 2, (sections, d)
        # freqs per component plane, then select by section
        f = positions[..., None].astype(jnp.float32) * inv      # (3, B, S, D/2)
        sel = jnp.repeat(jnp.arange(len(sections)),
                         jnp.asarray(sections), total_repeat_length=d // 2)
        idx = jnp.broadcast_to(sel[None, None, None, :],
                               (1,) + f.shape[1:3] + (d // 2,))
        freqs = jnp.take_along_axis(f, idx, axis=0)[0]          # (B, S, D/2)
    else:
        freqs = positions[..., None].astype(jnp.float32) * inv  # (B, S, D/2)
    cos = jnp.cos(freqs)[:, :, None, :]                         # (B, S, 1, D/2)
    sin = jnp.sin(freqs)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


# ------------------------------------------------------------- attention
def _softcap(scores: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0:
        scores = jnp.tanh(scores / cap) * cap
    return scores


def attention_scores_mask(q_pos: jax.Array, k_pos: jax.Array,
                          window: int = 0,
                          causal: bool = True) -> jax.Array:
    """Additive fp32 mask (..., Q, K) built from absolute positions.
    Negative k positions mark unwritten cache slots (always invalid)."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    valid = kp >= 0
    if causal:
        valid &= kp <= qp
    if window and window > 0:
        valid &= kp > qp - window
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


def multi_head_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         mask: Optional[jax.Array], *,
                         softcap: float = 0.0,
                         scale: Optional[float] = None) -> jax.Array:
    """Grouped-query attention. q: (B,S,Hq,D), k/v: (B,T,Hkv,D[v]).

    Softmax in fp32.  mask: broadcastable to (B, 1|H, S, T), additive.
    """
    B, S, Hq, D = q.shape
    _, T, Hkv, Dv = v.shape
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, S, Hkv, g, D)
    scores = jnp.einsum("bshgd,bthd->bhgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    scores = _softcap(scores, softcap)
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None, None, None]
        elif mask.ndim == 3:
            mask = mask[:, None, None]                 # (B,1,1,S,T)
        elif mask.ndim == 4:
            mask = mask[:, :, None]                    # (B,H?,1,S,T)
        scores = scores + mask
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs.astype(v.dtype), v)
    return out.reshape(B, S, Hq, Dv)


# ------------------------------------------------------------ projections
def linear(x: jax.Array, w: jax.Array,
           b: Optional[jax.Array] = None) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def gqa_project(x: jax.Array, p: dict, cfg: ModelConfig,
                positions: jax.Array
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Project + norm + rope. Returns q (B,S,H,D), k/v (B,S,Hkv,D)."""
    B, S, _ = x.shape
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear(x, p["wq"], p.get("bq")).reshape(B, S, H, D)
    k = linear(x, p["wk"], p.get("bk")).reshape(B, S, Hkv, D)
    v = linear(x, p["wv"], p.get("bv")).reshape(B, S, Hkv, D)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_sections)
    q = shard_hint(q, "batch", None, "tp", None)
    return q, k, v


# XLA-level flash attention: above this q length, attention runs as a
# remat'd scan over q blocks with lazily-built per-block masks, so neither
# the (S,T) score tensor nor the (S,T) mask is ever materialized in full.
ATTN_CHUNK_THRESHOLD = 4096
ATTN_CHUNK = 128


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *,
         q_pos: Optional[jax.Array] = None,
         k_pos: Optional[jax.Array] = None,
         causal: bool = True, window: int = 0,
         softcap: float = 0.0, scale: Optional[float] = None,
         threshold: Optional[int] = None) -> jax.Array:
    """Scaled-dot-product GQA attention with lazy masks + q-block chunking.

    q: (B,S,Hq,D); k/v: (B,T,Hkv,D). ``q_pos``/``k_pos``: (B,S)/(B,T)
    absolute positions (negative k positions = invalid slots).  When both
    are None and not causal/windowed, no mask is built at all.
    """
    B, S = q.shape[:2]
    T = k.shape[1]

    def mask_for(qp: Optional[jax.Array]) -> Optional[jax.Array]:
        if not causal and not window and k_pos is None:
            return None
        kp = k_pos
        if kp is None:
            kp = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None],
                                  (B, T))
        if qp is None:
            qp = jnp.broadcast_to(
                jnp.arange(T - S, T, dtype=jnp.int32)[None], (B, S))
        return attention_scores_mask(qp, kp, window=window, causal=causal)

    thr = ATTN_CHUNK_THRESHOLD if threshold is None else threshold
    if S <= thr or S % ATTN_CHUNK != 0:
        return multi_head_attention(q, k, v, mask_for(q_pos),
                                    softcap=softcap, scale=scale)

    C = ATTN_CHUNK
    nb = S // C
    qb = jnp.moveaxis(q.reshape(B, nb, C, *q.shape[2:]), 1, 0)
    qp = q_pos
    if qp is None:
        qp = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    qpb = jnp.moveaxis(qp.reshape(B, nb, C), 1, 0)

    def body(_, xs):
        qi, qpi = xs
        out = multi_head_attention(qi, k, v, mask_for(qpi),
                                   softcap=softcap, scale=scale)
        return None, out

    _, outs = jax.lax.scan(jax.checkpoint(body), None, (qb, qpb))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, q.shape[2], v.shape[-1])


def sdpa_online(q: jax.Array, k: jax.Array, v: jax.Array, *,
                causal: bool = True, window: int = 0,
                softcap: float = 0.0, scale: Optional[float] = None,
                bq: int = 128, bk: int = 512) -> jax.Array:
    """Flash-style attention in pure XLA: nested scans over (q, kv) blocks
    with the online-softmax running state (m, l, acc) carried between kv
    blocks.  Partial (bq x bk) score tiles are fusion-local — the S x T
    score tensor never reaches HBM, exactly the Pallas kernel's schedule.
    Wrapped in remat per q block so the backward recomputes tiles too.
    """
    B, S, Hq, D = q.shape
    _, T, Hkv, Dv = v.shape
    g = Hq // Hkv
    scale = D ** -0.5 if scale is None else scale
    bq = min(bq, S)
    bk = min(bk, T)
    if S % bq or T % bk:
        return sdpa(q, k, v, causal=causal, window=window, softcap=softcap,
                    scale=scale)
    nq, nk = S // bq, T // bk
    q_off = T - S

    qb = jnp.moveaxis(q.reshape(B, nq, bq, Hq, D), 1, 0)        # (nq,B,bq,H,D)
    kb = jnp.moveaxis(k.reshape(B, nk, bk, Hkv, D), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, bk, Hkv, Dv), 1, 0)

    def q_block(_, xs):
        qi_idx, qblk = xs                                        # (B,bq,H,D)
        qg = qblk.reshape(B, bq, Hkv, g, D)

        def kv_block(carry, kxs):
            m, l, acc = carry
            ki_idx, kblk, vblk = kxs
            s = jnp.einsum("bshgd,bthd->bhgst", qg, kblk,
                           preferred_element_type=jnp.float32) * scale
            if softcap:
                s = jnp.tanh(s / softcap) * softcap
            qp = qi_idx * bq + q_off \
                + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kp = ki_idx * bk \
                + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            valid = jnp.ones((bq, bk), bool)
            if causal:
                valid &= kp <= qp
            if window:
                valid &= kp > qp - window
            s = jnp.where(valid[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgst,bthd->bhgsd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hkv, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, bq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, g, bq, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0),
            (jnp.arange(nk, dtype=jnp.int32), kb, vb))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return None, jnp.moveaxis(out, 3, 1).reshape(B, bq, Hq, Dv)

    _, outs = jax.lax.scan(jax.checkpoint(q_block), None,
                           (jnp.arange(nq, dtype=jnp.int32), qb))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, Hq, Dv).astype(q.dtype)


def gqa_attend(q: jax.Array, k: jax.Array, v: jax.Array,
               mask: Optional[jax.Array], p: dict,
               cfg: ModelConfig, *,
               q_pos: Optional[jax.Array] = None,
               k_pos: Optional[jax.Array] = None,
               causal: bool = True) -> jax.Array:
    """Attention + output projection. Returns (B,S,d).

    If ``mask`` is given it is used directly (decode paths); otherwise the
    mask is built lazily from positions inside :func:`sdpa` (chunked for
    long q), or via the online-softmax (flash) path when ``cfg.attn_online``.
    """
    B, S, H, D = q.shape
    if mask is not None:
        out = multi_head_attention(q, k, v, mask,
                                   softcap=cfg.attn_logit_softcap)
    elif cfg.attn_online and S > 1 and q_pos is None and k_pos is None:
        out = sdpa_online(q, k, v, causal=causal, window=cfg.attn_window,
                          softcap=cfg.attn_logit_softcap)
    else:
        out = sdpa(q, k, v, q_pos=q_pos, k_pos=k_pos, causal=causal,
                   window=cfg.attn_window, softcap=cfg.attn_logit_softcap,
                   threshold=cfg.attn_chunk_threshold)
    out = linear(out.reshape(B, S, H * v.shape[-1]), p["wo"])
    return shard_hint(out, "batch", "seq", None)


def gqa_attention_block(x: jax.Array, p: dict, cfg: ModelConfig,
                        positions: jax.Array, mask: Optional[jax.Array],
                        ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full GQA attention (train/prefill). Returns (output, (k, v))."""
    q, k, v = gqa_project(x, p, cfg, positions)
    return gqa_attend(q, k, v, mask, p, cfg), (k, v)


# ----------------------------------------------------------------- MLA
def mla_project_q(x: jax.Array, p: dict, cfg: ModelConfig,
                  positions: jax.Array) -> jax.Array:
    """Queries through the low-rank path: (B,S,H,nope+rope)."""
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    ql = rms_norm(linear(x, p["wq_a"]), p["q_norm"], cfg.norm_eps)
    q = linear(ql, p["wq_b"]).reshape(B, S, H, dn + dr)
    q_rope = apply_rope(q[..., dn:], positions, cfg.rope_theta)
    q = jnp.concatenate([q[..., :dn], q_rope], axis=-1)
    return shard_hint(q, "batch", None, "tp", None)


def mla_latent(x: jax.Array, p: dict, cfg: ModelConfig,
               positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """The compressed KV: latent (B,S,kv_lora) + shared k_rope (B,S,1,dr).
    This pair is exactly what MLA caches for decode."""
    kv_a = linear(x, p["wkv_a"])
    latent = rms_norm(kv_a[..., :cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv_a[..., None, cfg.kv_lora_rank:], positions,
                        cfg.rope_theta)
    return latent, k_rope


def mla_attend(qq: jax.Array, latent: jax.Array, k_rope: jax.Array,
               mask: Optional[jax.Array], p: dict, cfg: ModelConfig, *,
               q_pos: Optional[jax.Array] = None,
               k_pos: Optional[jax.Array] = None,
               causal: bool = True) -> jax.Array:
    """Latent-space ("weight-absorbed") MLA attention.

    Instead of expanding the latent to per-head K/V — (B,T,H,dn+dv), 343 GB
    at 32k context for MiniCPM3 — the up-projection W_uk is absorbed into
    the query and W_uv into the output:

        scores = (q_nope @ W_uk) . latent + q_rope . k_rope
        out    = (softmax(scores) @ latent) @ W_uv

    so the only T-sized tensors are the latent (r=256/channel) and the
    shared rotary key — exactly what the MLA cache stores.  Long q is
    chunked like :func:`sdpa`.
    """
    B, S = qq.shape[:2]
    H, dn, dr, dv = (cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    r = cfg.kv_lora_rank
    T = latent.shape[1]
    scale = (dn + dr) ** -0.5

    w = p["wkv_b"].reshape(r, H, dn + dv)
    w_uk, w_uv = w[..., :dn], w[..., dn:]
    q_nope, q_rope = qq[..., :dn], qq[..., dn:]
    q_abs = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk.astype(qq.dtype))
    kr = k_rope[:, :, 0]                                     # (B,T,dr)

    def mask_for(qp):
        if mask is not None:
            return mask
        if not causal and k_pos is None:
            return None
        kp = k_pos
        if kp is None:
            kp = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None],
                                  (B, T))
        if qp is None:
            qp = jnp.broadcast_to(
                jnp.arange(T - S, T, dtype=jnp.int32)[None], (B, S))
        return attention_scores_mask(qp, kp, causal=causal)

    def attend_block(qa, qr, qp):
        s = (jnp.einsum("bshr,btr->bhst", qa, latent,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bshp,btp->bhst", qr, kr,
                          preferred_element_type=jnp.float32)) * scale
        m = mask_for(qp)
        if m is not None:
            if m.ndim == 3:
                m = m[:, None]
            s = s + m
        probs = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr", probs.astype(latent.dtype),
                         latent)
        return jnp.einsum("bshr,rhv->bshv", ctx, w_uv.astype(ctx.dtype))

    if S <= cfg.attn_chunk_threshold or S % ATTN_CHUNK != 0 \
            or mask is not None:
        out = attend_block(q_abs, q_rope, q_pos)
    else:
        C = ATTN_CHUNK
        nb = S // C
        qp = q_pos
        if qp is None:
            qp = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                  (B, S))
        xs = (jnp.moveaxis(q_abs.reshape(B, nb, C, H, r), 1, 0),
              jnp.moveaxis(q_rope.reshape(B, nb, C, H, dr), 1, 0),
              jnp.moveaxis(qp.reshape(B, nb, C), 1, 0))

        def body(_, x):
            return None, attend_block(*x)

        _, outs = jax.lax.scan(jax.checkpoint(body), None, xs)
        out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, dv)

    out = linear(out.reshape(B, S, H * dv), p["wo"])
    return shard_hint(out, "batch", "seq", None)


def mla_attention_block(x: jax.Array, p: dict, cfg: ModelConfig,
                        positions: jax.Array, mask: Optional[jax.Array],
                        ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full MLA attention (train/prefill). Returns (output, (latent, k_rope))."""
    qq = mla_project_q(x, p, cfg, positions)
    latent, k_rope = mla_latent(x, p, cfg, positions)
    return mla_attend(qq, latent, k_rope, mask, p, cfg), (latent, k_rope)


# ----------------------------------------------------------------- MLPs
def swiglu_mlp(x: jax.Array, p: dict) -> jax.Array:
    h = jax.nn.silu(linear(x, p["w_gate"])) * linear(x, p["w_up"])
    h = shard_hint(h, "batch", None, "tp")
    return shard_hint(linear(h, p["w_down"]), "batch", "seq", None)


def gelu_mlp(x: jax.Array, p: dict) -> jax.Array:
    h = jax.nn.gelu(linear(x, p["w_up"]))
    h = shard_hint(h, "batch", None, "tp")
    return shard_hint(linear(h, p["w_down"]), "batch", "seq", None)


# ------------------------------------------------------------------ MoE
def moe_ffn(x: jax.Array, p: dict, cfg: ModelConfig,
            capacity_factor: Optional[float] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Top-k routed SwiGLU experts, sort-based capacity dispatch.

    Tokens are grouped per batch row; within each group, (token, expert)
    assignments are sorted by expert id and scattered into an
    (E, C) capacity buffer — pure XLA ops (argsort/cumsum/scatter), no ragged
    support needed.  Over-capacity assignments are dropped (counted in the
    aux output).  Returns (output, aux_loss).
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    cf = capacity_factor if capacity_factor is not None \
        else cfg.moe_capacity_factor
    C = max(int(S * K / E * cf), K)

    gate_logits = linear(x, p["router"].astype(x.dtype)) \
        .astype(jnp.float32)                                       # (B,S,E)
    probs = jax.nn.softmax(gate_logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                         # (B,S,K)
    top_w = top_w / jnp.clip(top_w.sum(-1, keepdims=True), 1e-9)   # renorm

    # ---- load-balancing aux loss (Switch-style), fp32
    me = probs.mean(axis=(0, 1))                                   # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(
        jnp.ones((B * S * K,), jnp.float32)) / (B * S * K)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # ---- per-group sort-based dispatch
    flat_e = top_e.reshape(B, S * K)                               # (B, N)
    flat_w = top_w.reshape(B, S * K).astype(x.dtype)
    tok_id = jnp.repeat(jnp.arange(S), K)[None].repeat(B, 0)       # (B, N)

    order = jnp.argsort(flat_e, axis=-1)                           # stable
    e_sorted = jnp.take_along_axis(flat_e, order, -1)
    t_sorted = jnp.take_along_axis(tok_id, order, -1)
    w_sorted = jnp.take_along_axis(flat_w, order, -1)

    # position within expert = index - start-of-segment (the assignments are
    # sorted by expert id, so segments are contiguous).  O(N) — no (N,E)
    # one-hot cumsum, which would be TB-scale at 32k x top-8 x 64e.
    N = e_sorted.shape[1]
    idx = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32)[None], e_sorted.shape)
    is_new = jnp.concatenate(
        [jnp.ones((B, 1), bool), e_sorted[:, 1:] != e_sorted[:, :-1]], axis=1)
    seg_start = jax.lax.cummax(jnp.where(is_new, idx, 0), axis=1)
    pos_in_e = idx - seg_start
    keep = pos_in_e < C
    slot = e_sorted * C + jnp.where(keep, pos_in_e, 0)             # (B,N)

    # gathers/scatters are expressed per batch row via vmap so they lower
    # with operand batching dims — GSPMD keeps the batch axis sharded
    # (a flat .at[arange(B)[:,None], idx] scatter replicates the (B,S,d)
    # operand and all-reduces it over BOTH mesh axes: measured 3 TB/step
    # of fp32 all-reduce on grok-1 before this change)
    xs = jax.vmap(lambda row, t: jnp.take(row, t, axis=0))(
        x, t_sorted)                                               # (B,N,d)
    buf = jax.vmap(lambda s, v: jnp.zeros((E * C, d), x.dtype)
                   .at[s].add(v))(slot, jnp.where(keep[..., None], xs, 0))
    buf = buf.reshape(B, E, C, d)
    buf = shard_hint(buf, "batch", "experts", None, None)

    # ---- expert SwiGLU: (B,E,C,d) x (E,d,f)
    ks = cfg.moe_expert_split
    if ks > 1:
        # half-expert sharding: weights are stored pre-split as
        # (E*ks, d, f/ks); replicate each expert's tokens to its ks
        # sub-experts so compute is sub-expert-local, then reduce the ks
        # partial down-projections — a ks-chip reduction instead of a
        # TP-wide all-reduce when E*ks divides the "model" axis.
        bufs = jnp.repeat(buf, ks, axis=1)            # (B,E*ks,C,d)
        bufs = shard_hint(bufs, "batch", "experts", None, None)
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", bufs,
                                   p["w_gate"].astype(x.dtype))) \
            * jnp.einsum("becd,edf->becf", bufs, p["w_up"].astype(x.dtype))
        y_s = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))
        y_e = y_s.reshape(B, E, ks, C, d).sum(axis=2)
    else:
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf,
                                   p["w_gate"].astype(x.dtype))) \
            * jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(x.dtype))
        y_e = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))
    y_e = shard_hint(y_e, "batch", "experts", None, None).reshape(B, E * C, d)

    # ---- gather back + weighted combine (vmap'd: batch stays sharded)
    ys = jax.vmap(lambda row, s: jnp.take(row, s, axis=0))(y_e, slot)
    ys = ys * (w_sorted * keep.astype(x.dtype))[..., None]
    out = jax.vmap(lambda t, v: jnp.zeros((S, d), x.dtype)
                   .at[t].add(v))(t_sorted, ys)
    return shard_hint(out, "batch", "seq", None), aux


# ------------------------------------------------------------- embeddings
def embed_tokens(tokens: jax.Array, table: jax.Array,
                 scale: bool = False) -> jax.Array:
    x = jnp.take(table, tokens, axis=0)
    if scale:
        x = x * jnp.asarray(table.shape[-1] ** 0.5, x.dtype)
    return shard_hint(x, "batch", "seq", None)


def lm_logits(x: jax.Array, table: jax.Array,
              softcap: float = 0.0) -> jax.Array:
    logits = jnp.einsum("...d,vd->...v", x, table.astype(x.dtype))
    logits = _softcap(logits.astype(jnp.float32), softcap)
    return shard_hint(logits, "batch", None, "tp")
