"""Unified model configuration covering all assigned architecture families.

One dataclass, six families:
  dense   — GQA/MLA decoder LMs (qwen2, qwen3, llama3, minicpm3)
  moe     — mixture-of-experts decoders (olmoe, grok-1)
  ssm     — attention-free recurrent LMs (rwkv6)
  hybrid  — RG-LRU + local-attention (recurrentgemma)
  vlm     — M-RoPE decoder backbone, vision frontend stubbed (qwen2-vl)
  audio   — encoder-decoder backbone, audio frontend stubbed (seamless-m4t)
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0

    # ---- attention options -------------------------------------------------
    qkv_bias: bool = False          # qwen2
    qk_norm: bool = False           # qwen3
    rope_theta: float = 10_000.0
    attn_logit_softcap: float = 0.0         # grok-style soft capping
    attn_window: int = 0            # >0: sliding-window (local) attention

    # ---- MLA (multi-head latent attention, minicpm3) -----------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # ---- MoE ----------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    router_aux_coef: float = 0.01
    moe_capacity_factor: float = 1.25
    moe_expert_split: int = 1          # "half-expert" sharding: split each
                                       # expert's d_ff k ways so that
                                       # n_experts*k divides the TP axis and
                                       # the expert combine becomes a k-chip
                                       # (not TP-wide) reduction

    # ---- RWKV6 (ssm) ---------------------------------------------------------
    rwkv_head_size: int = 64

    # ---- hybrid (recurrentgemma / griffin) ----------------------------------
    lru_width: int = 0
    conv_width: int = 4
    block_pattern: Tuple[str, ...] = ()      # e.g. ("rec", "rec", "attn")
    embed_scale: bool = False                # gemma-style sqrt(d) embed scaling

    # ---- enc-dec (audio) ------------------------------------------------------
    n_enc_layers: int = 0                    # >0 => encoder-decoder
    cross_len: int = 4_096                   # encoder output length cached for decode

    # ---- modality frontend stub ------------------------------------------------
    frontend: str = "none"                   # none | vision | audio
    rope_sections: Tuple[int, ...] = ()      # M-RoPE (t, h, w) section split

    # ---- perf variants (hillclimb levers; see EXPERIMENTS.md §Perf) --------
    attn_chunk_threshold: int = 4096   # q length above which attention chunks
    decode_carry_cache: bool = False   # thread decode cache through the scan
                                       # carry (in-place) instead of xs->ys
    attn_online: bool = False          # online-softmax (flash) attention at
                                       # the XLA level: no S x T score tensor
                                       # ever reaches HBM

    # ---- numerics / training -----------------------------------------------
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    logit_softcap: float = 0.0

    # ------------------------------------------------------------------ props
    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve a 500k-token context with bounded state?"""
        return self.family in ("ssm", "hybrid")

    @property
    def q_dim(self) -> int:
        if self.use_mla:
            return self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def rwkv_n_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    # -------------------------------------------------------------- validate
    def validate(self) -> None:
        assert self.family in ("dense", "moe", "ssm", "hybrid", "vlm", "audio")
        if self.family in ("dense", "moe", "vlm", "audio", "hybrid"):
            assert self.n_heads > 0 and self.head_dim > 0
            if not self.use_mla:
                assert self.n_kv_heads > 0
                assert self.n_heads % self.n_kv_heads == 0
        if self.family == "moe":
            assert self.n_experts > 0 and self.experts_per_token > 0
        if self.family == "ssm":
            assert self.d_model % self.rwkv_head_size == 0
        if self.family == "hybrid":
            assert self.block_pattern and self.lru_width > 0
        if self.use_mla:
            assert self.q_lora_rank > 0 and self.kv_lora_rank > 0
            assert self.qk_nope_dim > 0 and self.qk_rope_dim > 0
            assert self.v_head_dim > 0
        if self.rope_sections:
            assert sum(self.rope_sections) * 2 == self._rope_dim(), \
                f"M-RoPE sections {self.rope_sections} must sum to head_dim/2"

    def _rope_dim(self) -> int:
        return self.qk_rope_dim if self.use_mla else self.head_dim


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (seq_len × global_batch, and which step it lowers)."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_by_name(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}")
