"""Encoder-decoder backbone (SeamlessM4T-medium).

The speech frontend is stubbed per the assignment: the encoder consumes
precomputed frame embeddings (B, S_src, d).  Standard pre-LN transformer with
RoPE self-attention; decoder adds causal masking + cross-attention to the
encoder output (cross K/V are position-free and precomputed once for decode).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed import shard_hint
from .config import ModelConfig
from .kv_cache import update_full_cache
from .layers import (attention_scores_mask, embed_tokens, gelu_mlp,
                     gqa_attend, gqa_project, linear, lm_logits,
                     rms_norm, sdpa)


def _cross_kv(enc_out: jax.Array, p: Dict[str, Any], cfg: ModelConfig
              ) -> Tuple[jax.Array, jax.Array]:
    B, T, _ = enc_out.shape
    k = linear(enc_out, p["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    v = linear(enc_out, p["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def _cross_attend(x: jax.Array, k: jax.Array, v: jax.Array,
                  p: Dict[str, Any], cfg: ModelConfig) -> jax.Array:
    B, S, _ = x.shape
    q = linear(x, p["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    q = shard_hint(q, "batch", None, "tp", None)
    out = sdpa(q, k, v, causal=False)                # chunked, mask-free
    out = linear(out.reshape(B, S, cfg.n_heads * cfg.head_dim), p["wo"])
    return shard_hint(out, "batch", "seq", None)


# ----------------------------------------------------------------- encoder
def encode(params: Dict[str, Any], cfg: ModelConfig,
           src: jax.Array) -> jax.Array:
    """src: (B, S_src, d) frame embeddings (frontend stub)."""
    x = shard_hint(src.astype(cfg.cdtype), "batch", "seq", None)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(h, p_l):
        hh = rms_norm(h, p_l["ln1"], cfg.norm_eps)
        q, k, v = gqa_project(hh, p_l["attn"], cfg, positions)
        h = h + gqa_attend(q, k, v, None, p_l["attn"], cfg,
                           causal=False)                      # bidirectional
        hh = rms_norm(h, p_l["ln2"], cfg.norm_eps)
        h = h + gelu_mlp(hh, p_l["mlp"])
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_blocks"])
    return rms_norm(x, params["enc_ln_f"], cfg.norm_eps)


# ----------------------------------------------------------------- decoder
def decode_fwd(params: Dict[str, Any], cfg: ModelConfig,
               tokens: jax.Array, enc_out: jax.Array,
               emit_cache: bool = False):
    """Decoder full-sequence pass (train / prefill).
    Returns (hidden, cache | None)."""
    x = embed_tokens(tokens, params["embed"]).astype(cfg.cdtype)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(h, p_l):
        hh = rms_norm(h, p_l["ln1"], cfg.norm_eps)
        q, k, v = gqa_project(hh, p_l["attn"], cfg, positions)
        h = h + gqa_attend(q, k, v, None, p_l["attn"], cfg)   # lazy causal
        hh = rms_norm(h, p_l["ln_cross"], cfg.norm_eps)
        ck, cv = _cross_kv(enc_out, p_l["cross"], cfg)
        h = h + _cross_attend(hh, ck, cv, p_l["cross"], cfg)
        hh = rms_norm(h, p_l["ln2"], cfg.norm_eps)
        h = h + gelu_mlp(hh, p_l["mlp"])
        return h, ((k, v, ck, cv) if emit_cache else None)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, caches = jax.lax.scan(body_fn, x, params["dec_blocks"])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    if emit_cache:
        k, v, ck, cv = caches
        return x, {"self": {"k": k, "v": v}, "cross_k": ck, "cross_v": cv}
    return x, None


def decode_step(params: Dict[str, Any], cfg: ModelConfig,
                cache: Dict[str, Any], tokens: jax.Array, pos: jax.Array
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Single-token decoder step against self- + cross-caches."""
    x = embed_tokens(tokens, params["embed"]).astype(cfg.cdtype)
    B = tokens.shape[0]
    positions = pos[:, None]

    def body(h, xs):
        p_l, self_l, ck, cv = xs
        hh = rms_norm(h, p_l["ln1"], cfg.norm_eps)
        q, k_new, v_new = gqa_project(hh, p_l["attn"], cfg, positions)
        sk, sv = update_full_cache(self_l["k"], self_l["v"],
                                   k_new, v_new, pos)
        T = sk.shape[1]
        kpos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        kpos = jnp.where(kpos <= pos[:, None], kpos, -1)
        mask = attention_scores_mask(positions, kpos, causal=False)
        h = h + gqa_attend(q, sk, sv, mask, p_l["attn"], cfg)
        hh = rms_norm(h, p_l["ln_cross"], cfg.norm_eps)
        h = h + _cross_attend(hh, ck, cv, p_l["cross"], cfg)
        hh = rms_norm(h, p_l["ln2"], cfg.norm_eps)
        h = h + gelu_mlp(hh, p_l["mlp"])
        return h, {"k": sk, "v": sv}

    x, new_self = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["self"],
                  cache["cross_k"], cache["cross_v"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = lm_logits(x, params["lm_head"], cfg.logit_softcap)
    return logits[:, -1], {"self": new_self, "cross_k": cache["cross_k"],
                           "cross_v": cache["cross_v"]}


def forward_train(params: Dict[str, Any], cfg: ModelConfig,
                  inputs: Dict[str, jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """(hidden over target tokens, aux=0)."""
    enc_out = encode(params, cfg, inputs["src"])
    x, _ = decode_fwd(params, cfg, inputs["tokens"], enc_out)
    return x, jnp.zeros((), jnp.float32)
