"""Model facade: one entry point over all six architecture families.

  Model(cfg).loss(params, batch)          — training objective
  Model(cfg).prefill(params, inputs)      — full-sequence forward + cache
  Model(cfg).decode_step(params, cache, tokens, pos)
  Model(cfg).input_specs(shape)           — ShapeDtypeStruct stand-ins for the
                                            multi-pod dry-run (no allocation)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import encdec, rglru, rwkv6, transformer
from .config import ModelConfig, ShapeConfig
from .init import abstract_params, count_params, init_params
from .kv_cache import init_cache
from .layers import lm_logits


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 1e-4) -> Tuple[jax.Array, Dict[str, Any]]:
    """Token-mean CE in fp32 with z-loss. logits: (B,S,V); labels: (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (lse - gold).mean()
    zl = z_loss * jnp.square(lse).mean()
    return ce + zl, {"ce": ce, "z_loss": zl}


class Model:
    def __init__(self, cfg: ModelConfig, use_kernels: bool = False) -> None:
        cfg.validate()
        self.cfg = cfg
        self.use_kernels = use_kernels

    # ------------------------------------------------------------ params
    def init(self, rng: jax.Array) -> Dict[str, Any]:
        return init_params(self.cfg, rng)

    def abstract_params(self) -> Dict[str, Any]:
        return abstract_params(self.cfg)

    def count_params(self) -> int:
        return count_params(self.cfg)

    def active_params(self) -> int:
        """Active (per-token) parameter count — MoE uses top-k experts."""
        cfg = self.cfg
        total = self.count_params()
        if not cfg.n_experts:
            return total
        # expert weights: 3 matrices per expert per layer
        per_expert = 3 * cfg.d_model * cfg.d_ff
        inactive = cfg.n_layers * (cfg.n_experts - cfg.experts_per_token) \
            * per_expert
        return total - inactive

    # ------------------------------------------------------------- train
    def loss(self, params: Dict[str, Any], batch: Dict[str, jax.Array]
             ) -> Tuple[jax.Array, Dict[str, Any]]:
        cfg = self.cfg
        if cfg.family == "ssm":
            B = batch["tokens"].shape[0]
            state = init_cache(cfg, B, 0)
            x, _ = rwkv6.forward(params, cfg, batch, state,
                                 use_kernel=self.use_kernels,
                                 emit_state=False)
            logits = lm_logits(x, params["lm_head"], cfg.logit_softcap)
            aux = jnp.zeros((), jnp.float32)
        elif cfg.family == "hybrid":
            B = batch["tokens"].shape[0]
            cache = init_cache(cfg, B, 0)
            pos = jnp.zeros((B,), jnp.int32)
            x, _ = rglru.forward(params, cfg, batch, cache, decode=False,
                                 pos=pos, emit_cache=False)
            logits = lm_logits(x, params["lm_head"], cfg.logit_softcap)
            aux = jnp.zeros((), jnp.float32)
        elif cfg.is_encdec:
            x, aux = encdec.forward_train(params, cfg, batch)
            logits = lm_logits(x, params["lm_head"], cfg.logit_softcap)
        else:
            x, aux = transformer.forward(params, cfg, batch)
            logits = lm_logits(x, transformer._out_table(params, cfg),
                               cfg.logit_softcap)
        loss, metrics = cross_entropy(logits, batch["labels"])
        loss = loss + aux
        metrics["aux"] = aux
        metrics["loss"] = loss
        return loss, metrics

    # ------------------------------------------------------------- serve
    def prefill(self, params: Dict[str, Any], inputs: Dict[str, jax.Array]
                ) -> Tuple[jax.Array, Any]:
        """Returns (last-token logits (B,V), cache)."""
        cfg = self.cfg
        if cfg.family == "ssm":
            B = inputs["tokens"].shape[0]
            state = init_cache(cfg, B, 0)
            x, state = rwkv6.forward(params, cfg, inputs, state,
                                     use_kernel=self.use_kernels)
            logits = lm_logits(x[:, -1:], params["lm_head"],
                               cfg.logit_softcap)
            return logits[:, 0], state
        if cfg.family == "hybrid":
            B = inputs["tokens"].shape[0]
            cache = init_cache(cfg, B, 0)
            pos = jnp.zeros((B,), jnp.int32)
            x, cache = rglru.forward(params, cfg, inputs, cache,
                                     decode=False, pos=pos)
            logits = lm_logits(x[:, -1:], params["lm_head"],
                               cfg.logit_softcap)
            return logits[:, 0], cache
        if cfg.is_encdec:
            enc_out = encdec.encode(params, cfg, inputs["src"])
            x, cache = encdec.decode_fwd(params, cfg, inputs["tokens"],
                                         enc_out, emit_cache=True)
            logits = lm_logits(x[:, -1:], params["lm_head"],
                               cfg.logit_softcap)
            return logits[:, 0], cache
        return transformer.prefill(params, cfg, inputs)

    def decode_step(self, params: Dict[str, Any], cache: Any,
                    tokens: jax.Array, pos: jax.Array
                    ) -> Tuple[jax.Array, Any]:
        cfg = self.cfg
        if cfg.family == "ssm":
            return rwkv6.decode_step(params, cfg, cache, tokens, pos)
        if cfg.family == "hybrid":
            return rglru.decode_step(params, cfg, cache, tokens, pos)
        if cfg.is_encdec:
            return encdec.decode_step(params, cfg, cache, tokens, pos)
        return transformer.decode_step(params, cfg, cache, tokens, pos)

    def init_cache(self, batch: int, max_len: int,
                   abstract: bool = False) -> Any:
        return init_cache(self.cfg, batch, max_len, abstract)

    # ----------------------------------------------------------- dry-run
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this cell.

        train  -> kwargs for loss(params, batch)
        prefill-> kwargs for prefill(params, inputs)
        decode -> kwargs for decode_step(params, cache, tokens, pos)
        """
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sd = jax.ShapeDtypeStruct

        def lm_inputs(seq: int) -> Dict[str, Any]:
            d: Dict[str, Any] = {"tokens": sd((B, seq), i32)}
            if cfg.family == "vlm":
                d["embeds"] = sd((B, seq, cfg.d_model), cfg.cdtype)
                d["embed_mask"] = sd((B, seq), jnp.bool_)
                d["positions"] = sd((3, B, seq), i32)
            if cfg.is_encdec:
                # source frames at seq_len; target at seq_len // 4
                d = {"src": sd((B, seq, cfg.d_model), cfg.cdtype),
                     "tokens": sd((B, max(seq // 4, 8)), i32)}
            return d

        if shape.kind == "train":
            batch = lm_inputs(S)
            tgt = batch["tokens"].shape
            batch["labels"] = sd(tgt, i32)
            return {"batch": batch}
        if shape.kind == "prefill":
            return {"inputs": lm_inputs(S)}
        # decode: one new token against a cache of S
        cache = self.init_cache(B, S, abstract=True)
        return {"cache": cache,
                "tokens": sd((B, 1), i32),
                "pos": sd((B,), i32)}

    def step_fn(self, kind: str):
        """The jittable callable for a given shape kind (serve side)."""
        if kind == "prefill":
            return lambda params, inputs: self.prefill(params, inputs)
        if kind == "decode":
            return lambda params, cache, tokens, pos: \
                self.decode_step(params, cache, tokens, pos)
        raise ValueError(kind)
