"""Model zoo: six families, ten assigned architectures."""
from .config import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K,
                     TRAIN_4K, ModelConfig, ShapeConfig, shape_by_name)
from .init import abstract_params, count_params, init_params
from .model import Model, cross_entropy

__all__ = [
    "Model", "ModelConfig", "ShapeConfig", "cross_entropy",
    "init_params", "abstract_params", "count_params",
    "ALL_SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "shape_by_name",
]
