"""Logical-axis sharding with divisibility auto-degrade.

Model code annotates activations with *logical* axis names
(``shard_hint(x, "batch", "seq", None)``); the active :class:`ShardCtx` maps
them to mesh axes.  A dim is sharded only when its size divides the product of
the mapped mesh axes — otherwise the rule silently degrades to replication
(e.g. qwen2-0.5b's 14 attention heads on a 16-way "model" axis).  Outside any
context the hints are identity, so model code runs unmodified on one device.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

AxisMap = Union[None, str, Tuple[str, ...]]

# Baseline logical rules: FSDP("data") x TP("model"), "pod" = pure DP.
LOGICAL_RULES: Dict[str, AxisMap] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,                 # sequence-parallel variants override
    "carry_seq": None,           # remat-saved scan carry (block boundary)
    "embed": None,
    "tp": "model",               # heads / ff / vocab activation dim
    "kv_seq": "model",           # decode KV-cache sequence dim (flash-decode)
    # weights
    "fsdp": ("pod", "data"),     # weight d_model dim (ZeRO-3, across pods)
    "wtp": "model",              # weight ff/heads/vocab dim
    "experts": "model",          # MoE expert dim
}

_TLS = threading.local()


class ShardCtx:
    def __init__(self, mesh: jax.sharding.Mesh,
                 rules: Optional[Dict[str, AxisMap]] = None) -> None:
        self.mesh = mesh
        self.rules = dict(LOGICAL_RULES)
        if rules:
            self.rules.update(rules)

    # ----------------------------------------------------------- resolution
    def _axes_for(self, name: Optional[str], dim: int) -> AxisMap:
        if name is None:
            return None
        mapped = self.rules.get(name)
        if mapped is None:
            return None
        axes = (mapped,) if isinstance(mapped, str) else tuple(mapped)
        # drop axes absent from this mesh (e.g. "pod" on the single-pod mesh)
        axes = tuple(a for a in axes if a in self.mesh.shape)
        if not axes:
            return None
        total = 1
        for a in axes:
            total *= self.mesh.shape[a]
        if dim % total != 0:
            return None  # divisibility auto-degrade
        return axes if len(axes) > 1 else axes[0]

    def spec(self, names: Sequence[Optional[str]],
             shape: Sequence[int]) -> P:
        assert len(names) == len(shape), (names, shape)
        used: set = set()
        parts = []
        for name, dim in zip(names, shape):
            ax = self._axes_for(name, dim)
            # a mesh axis may appear at most once in a PartitionSpec
            if ax is not None:
                flat = (ax,) if isinstance(ax, str) else ax
                if any(a in used for a in flat):
                    ax = None
                else:
                    used.update(flat)
            parts.append(ax)
        return P(*parts)

    def sharding(self, names: Sequence[Optional[str]],
                 shape: Sequence[int]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(names, shape))


def current_ctx() -> Optional[ShardCtx]:
    return getattr(_TLS, "ctx", None)


@contextlib.contextmanager
def use_sharding(mesh: jax.sharding.Mesh,
                 rules: Optional[Dict[str, AxisMap]] = None):
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = ShardCtx(mesh, rules)
    try:
        yield _TLS.ctx
    finally:
        _TLS.ctx = prev


def shard_hint(x: Any, *names: Optional[str]) -> Any:
    """Constrain ``x``'s sharding by logical axis names (identity w/o ctx)."""
    ctx = current_ctx()
    if ctx is None:
        return x
    if len(names) != x.ndim:
        raise ValueError(f"shard_hint: {len(names)} names for rank-{x.ndim}")
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, ctx.spec(names, x.shape)))
