"""Distribution layer: logical sharding rules, mesh helpers, fault tolerance."""
from .api import (LOGICAL_RULES, ShardCtx, current_ctx, shard_hint,
                  use_sharding)

__all__ = ["shard_hint", "use_sharding", "ShardCtx", "current_ctx",
           "LOGICAL_RULES"]
