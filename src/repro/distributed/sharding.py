"""Parameter / input / cache sharding assignment.

Walks a params pytree and assigns each leaf a logical-axis tuple by leaf
name (Megatron column/row-parallel + FSDP), then resolves it against the
mesh through :class:`ShardCtx` (divisibility auto-degrade, axis-used-once).

Key behaviors falling out of the rule engine, per arch:
  * olmoe  (64 experts): expert dim takes "model" -> expert parallelism
  * grok-1 (8 experts < 16): expert dim degrades, d_ff takes "model"
    -> tensor parallelism *inside* each expert
  * qwen2-0.5b (14 heads): attention weight TP degrades on the merged head
    dim only if 896 % 16 != 0 (it is divisible: 56/chip) — activations
    degrade instead (see models/layers.py shard hints)
  * llama3-405b decode: kv_heads (8) % 16 != 0 -> KV cache shards its
    *sequence* dim over "model" (flash-decode layout)
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding

from ..models import Model
from ..models.config import ModelConfig, ShapeConfig
from .api import ShardCtx

# Megatron column-parallel (input dim -> FSDP, output dim -> TP)
_COL = {"wq", "wk", "wv", "wg", "wr", "wq_a", "wq_b", "wkv_a", "wkv_b",
        "w_gate", "w_up", "w_k", "tm_w1", "decay_w1", "w_y", "w_x"}
# Megatron row-parallel (input dim -> TP, output dim -> FSDP)
_ROW = {"wo", "w_down", "w_v", "decay_w2", "w_o"}
_BIAS_TP = {"bq", "bk", "bv", "conv_b", "lam", "gate_a_b", "gate_i_b"}


def _leaf_name(path) -> str:
    last = path[-1]
    return getattr(last, "key", str(last))


def logical_axes_for(path, shape: Sequence[int]
                     ) -> Tuple[Optional[str], ...]:
    """Logical axis names for one parameter leaf."""
    name = _leaf_name(path)
    nd = len(shape)
    if name in ("embed", "lm_head"):
        return ("wtp", "fsdp")
    if name == "router":                      # (L, d, E): keep E whole
        return (None, "fsdp", None)
    if name in _COL:
        if nd == 4:                           # MoE expert (L, E, d, f)
            return (None, "experts", "fsdp", "wtp")
        if nd == 3:                           # (L, d, out)
            return (None, "fsdp", "wtp")
    if name in _ROW:
        if nd == 4:                           # MoE expert (L, E, f, d)
            return (None, "experts", "wtp", "fsdp")
        if nd == 3:                           # (L, in, d)
            return (None, "wtp", "fsdp")
    if name in _BIAS_TP and nd == 2:          # (L, out)
        return (None, "wtp")
    if name in ("gate_a_w", "gate_i_w") and nd == 4:  # (L, nb, bw, bw)
        return (None, "wtp", None, None)
    if name == "conv_w" and nd == 3:          # (L, cw, W)
        return (None, None, "wtp")
    if name == "tm_w2" and nd == 4:           # (L, 5, lora, d)
        return (None, None, None, "fsdp")
    # norms, scalars, token-shift mus, u, w0: replicated
    return (None,) * nd


def param_shardings(ctx: ShardCtx, params_abstract: Any) -> Any:
    """NamedSharding pytree matching ``params_abstract``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_abstract)
    out = []
    for path, leaf in flat:
        names = logical_axes_for(path, leaf.shape)
        out.append(ctx.sharding(names, leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_state_shardings(ctx: ShardCtx, opt_abstract: Any,
                        param_shards: Any) -> Any:
    """m/v follow the params; step is replicated."""
    return {
        "m": jax.tree.map(lambda s, l: s, param_shards, opt_abstract["m"]),
        "v": jax.tree.map(lambda s, l: s, param_shards, opt_abstract["v"]),
        "step": ctx.sharding((), ()),
    }


# ------------------------------------------------------------------ inputs
def batch_shardings(ctx: ShardCtx, batch_abstract: Any) -> Any:
    """Training/prefill inputs: batch over ("pod","data"), rest replicated.
    The M-RoPE positions tensor (3,B,S) carries batch in dim 1."""
    def assign(path, leaf):
        name = _leaf_name(path)
        if name == "positions" and len(leaf.shape) == 3 \
                and leaf.shape[0] == 3:
            return ctx.sharding((None, "batch", None), leaf.shape)
        names = ("batch",) + (None,) * (len(leaf.shape) - 1)
        return ctx.sharding(names, leaf.shape)

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch_abstract)
    return jax.tree_util.tree_unflatten(
        treedef, [assign(p, l) for p, l in flat])


def cache_shardings(ctx: ShardCtx, cfg: ModelConfig,
                    cache_abstract: Any) -> Any:
    """Decode caches: batch on ("pod","data"); per-head TP when the kv-head
    count divides the model axis, else sequence-sharded KV (flash-decode)."""
    tp = ctx.mesh.shape.get("model", 1)
    heads_divide = cfg.n_kv_heads > 0 and cfg.n_kv_heads % tp == 0

    def assign(path, leaf):
        name = _leaf_name(path)
        nd = len(leaf.shape)
        if name in ("k", "v", "cross_k", "cross_v") and nd == 5:
            if heads_divide:
                names = (None, "batch", None, "tp", None)
            else:
                names = (None, "batch", "kv_seq", None, None)
        elif name == "latent" and nd == 4:            # MLA (L,B,S,r)
            names = (None, "batch", "kv_seq", None)
        elif name == "k_rope" and nd == 5:            # (L,B,S,1,dr)
            names = (None, "batch", "kv_seq", None, None)
        elif name == "wkv" and nd == 5:               # rwkv (L,B,H,D,D)
            names = (None, "batch", "tp", None, None)
        elif name == "h" and nd == 3:                 # lru (Lr,B,W)
            names = (None, "batch", "tp")
        elif name == "conv" and nd == 4:              # (Lr,B,cw-1,W)
            names = (None, "batch", None, "tp")
        elif nd >= 2:
            names = (None, "batch") + (None,) * (nd - 2)
        else:
            names = (None,) * nd
        return ctx.sharding(names, leaf.shape)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_abstract)
    return jax.tree_util.tree_unflatten(
        treedef, [assign(p, l) for p, l in flat])


def step_in_shardings(ctx: ShardCtx, model: Model, shape: ShapeConfig,
                      specs: Any) -> Any:
    """in_shardings pytree matching Model.input_specs(shape) kwargs."""
    cfg = model.cfg
    if shape.kind == "train":
        return {"batch": batch_shardings(ctx, specs["batch"])}
    if shape.kind == "prefill":
        return {"inputs": batch_shardings(ctx, specs["inputs"])}
    return {
        "cache": cache_shardings(ctx, cfg, specs["cache"]),
        "tokens": ctx.sharding(("batch", None), specs["tokens"].shape),
        "pos": ctx.sharding(("batch",), specs["pos"].shape),
    }
