"""Fault tolerance for 1000+-node deployments.

Three mechanisms, all built on the paper's fiber runtime (monitoring is
wait-dominated async work — exactly the workload fibers are for):

* :class:`HeartbeatMonitor` — every host runs a heartbeat fiber; a monitor
  fiber sweeps for stale hosts, classifying them as *straggler* (late) or
  *dead* (missed N intervals), and fires callbacks that trigger
  checkpoint-restore-based eviction/elastic restart.
* :func:`elastic_reshard` — re-lay-out a checkpointed state pytree onto a
  *different* mesh (pod count changed) via ``jax.device_put`` with freshly
  resolved shardings; checkpoints store only logical shapes so this is
  always well-defined.
* :class:`TrainSupervisor` — crash/restart loop glue: owns the
  CheckpointManager, decides restore-vs-init at startup, periodically saves
  async, and on failure call-sites simply re-enter ``run()``.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from ..core import App, Compute, ServiceSpec, Sleep
from ..core.future import Future


# ------------------------------------------------------------- heartbeats
@dataclass
class HostState:
    host_id: int
    last_beat: float = 0.0
    beats: int = 0
    status: str = "alive"          # alive | straggler | dead


def _monitor_loop(svc: Any, payload: Any):
    """Monitor fiber: sweep heartbeat table, classify, fire callbacks."""
    interval = svc.state["interval"]
    while not svc.state.get("stop"):
        now = time.monotonic()
        with svc.lock:
            hosts: Dict[int, HostState] = svc.state["hosts"]
            for h in hosts.values():
                age = now - h.last_beat
                prev = h.status
                if age > 4 * interval:
                    h.status = "dead"
                elif age > 2 * interval:
                    h.status = "straggler"
                else:
                    h.status = "alive"
                if h.status != prev:
                    for cb in svc.state["callbacks"]:
                        cb(h.host_id, prev, h.status)
        yield Sleep(interval / 2)
    return "stopped"


def _beat(svc: Any, payload: Any):
    yield Compute(1e-6)
    with svc.lock:
        hosts = svc.state["hosts"]
        h = hosts.setdefault(payload["host"], HostState(payload["host"]))
        h.last_beat = time.monotonic()
        h.beats += 1
        if h.status != "alive":
            h.status = "alive"
    return {"ok": True}


def _host_loop(svc: Any, payload: Any):
    """Simulated host: sends heartbeats; can be made a straggler/killed."""
    host_id = payload["host"]
    interval = svc.state["interval"]
    while not svc.state.get("stop"):
        with svc.lock:
            behavior = svc.state["behavior"].get(host_id, "alive")
        if behavior == "dead":
            return "died"
        if behavior == "straggler":
            yield Sleep(3 * interval)
        from ..core.effects import AsyncRpc, Wait
        f = yield AsyncRpc("monitor", "beat", {"host": host_id})
        yield Wait(f)
        yield Sleep(interval)
    return "stopped"


class HeartbeatMonitor:
    """Fiber-based cluster health monitor (simulated hosts for CI)."""

    def __init__(self, n_hosts: int = 4, interval: float = 0.05,
                 backend: str = "fiber") -> None:
        self.interval = interval
        self.app = App(backend=backend)
        self.callbacks: List[Callable[[int, str, str], None]] = []
        self.app.add_service(ServiceSpec(
            "monitor", {"beat": _beat, "run": _monitor_loop}, n_workers=2,
            state={"hosts": {}, "interval": interval,
                   "callbacks": self.callbacks, "behavior": {}}))
        self.app.add_service(ServiceSpec(
            "hosts", {"run": _host_loop}, n_workers=max(n_hosts, 2),
            state={"interval": interval, "behavior": {}}))
        self.n_hosts = n_hosts

    def start(self) -> None:
        self.app.start()
        self.app.send("monitor", "run", None)
        mon = self.app.services["monitor"]
        hosts_svc = self.app.services["hosts"]
        hosts_svc.state["behavior"] = mon.state["behavior"]
        for h in range(self.n_hosts):
            self.app.send("hosts", "run", {"host": h})

    def on_transition(self, cb: Callable[[int, str, str], None]) -> None:
        self.callbacks.append(cb)

    def set_behavior(self, host: int, behavior: str) -> None:
        mon = self.app.services["monitor"]
        with mon.lock:
            mon.state["behavior"][host] = behavior

    def statuses(self) -> Dict[int, str]:
        mon = self.app.services["monitor"]
        with mon.lock:
            return {h.host_id: h.status
                    for h in mon.state["hosts"].values()}

    def stop(self) -> None:
        for name in ("monitor", "hosts"):
            self.app.services[name].state["stop"] = True
        time.sleep(2.5 * self.interval)
        self.app.stop()


# --------------------------------------------------------- elastic reshard
def elastic_reshard(state: Any, shardings: Any) -> Any:
    """Re-lay-out ``state`` onto the shardings of a (possibly different)
    mesh.  Works device->device or host->device."""
    import jax
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), state, shardings)


# ------------------------------------------------------------- supervisor
class TrainSupervisor:
    """Checkpoint-driven crash/restart glue around a train loop."""

    def __init__(self, ckpt_mgr: Any, save_every: int = 50) -> None:
        self.mgr = ckpt_mgr
        self.save_every = save_every
        self._last_save: Optional[Future] = None

    def startup(self, init_fn: Callable[[], Any], target: Any,
                shardings: Any = None):
        """Restore latest checkpoint if one exists, else initialize."""
        step = self.mgr.latest_step()
        if step is None:
            return 0, init_fn()
        return self.mgr.restore(target, shardings=shardings)

    def maybe_save(self, step: int, state: Any) -> None:
        if step % self.save_every == 0 and step > 0:
            # wait for the previous async save before starting a new one
            if self._last_save is not None and not self._last_save.done:
                self._last_save.wait(timeout=600)
            self._last_save = self.mgr.save_async(step, state)

    def finalize(self, step: int, state: Any) -> None:
        if self._last_save is not None and not self._last_save.done:
            self._last_save.wait(timeout=600)
        if self.mgr.latest_step() != step:
            self.mgr.save_async(step, state).wait(timeout=600)
