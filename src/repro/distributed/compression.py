"""Gradient compression for cross-pod data parallelism.

Two pieces:

* ``quantize_int8`` / ``dequantize_int8`` — per-tensor symmetric int8 with
  error feedback (the residual is carried between steps so quantization
  error is re-injected rather than lost).

* ``compressed_cross_pod_reduce`` — decomposes the DP gradient reduction:
  within-pod reduction stays bf16 (fast ICI), the *cross-pod* hop (slow DCI)
  moves int8 + one fp32 scale: 4x fewer bytes on the bottleneck link.
  Implemented with shard_map over the "pod" axis only; "data"/"model" stay
  in GSPMD auto mode.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric quantization. Returns (int8 values, fp32 scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads: Any, residual: Any
                           ) -> Tuple[Any, Any]:
    """Emulated compressed reduction for single-axis DP: quantize
    (grad + residual), return (dequantized grads, new residual)."""
    def one(g, r):
        g32 = g.astype(jnp.float32) + r.astype(jnp.float32)
        q, s = quantize_int8(g32)
        dq = dequantize_int8(q, s)
        return dq.astype(g.dtype), (g32 - dq).astype(r.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def _reduce_leaf_int8(g: jax.Array, n_pods: int) -> jax.Array:
    """all-gather int8 + local dequant-sum over the "pod" axis."""
    q, s = quantize_int8(g)
    q_all = jax.lax.all_gather(q, "pod")                # (n_pods, ...)
    s_all = jax.lax.all_gather(s, "pod")
    total = jnp.sum(
        q_all.astype(jnp.float32)
        * s_all.reshape((n_pods,) + (1,) * g.ndim), axis=0)
    return (total / n_pods).astype(g.dtype)


def make_pod_compressed_grad_fn(loss_fn, mesh: jax.sharding.Mesh):
    """Build ``(params, batch) -> (loss, grads)`` where each pod computes
    gradients on its pod-local batch and the cross-pod reduction moves int8
    payloads (4x fewer DCI bytes than a bf16 all-reduce).

    ``loss_fn(params, batch) -> scalar`` must average over the batch it is
    given (pod-local here).  "data"/"model" remain GSPMD-auto inside the
    shard_map region; only "pod" is manually mapped.
    """
    if "pod" not in mesh.shape:
        def plain(params, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            return loss, grads
        return plain
    n_pods = mesh.shape["pod"]

    def pod_local(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads = jax.tree.map(
            lambda g: _reduce_leaf_int8(g, n_pods), grads)
        return jax.lax.pmean(loss, "pod"), grads

    return jax.shard_map(
        pod_local, mesh=mesh,
        in_specs=(P(), P("pod")), out_specs=(P(), P()),
        axis_names={"pod"}, check_vma=False)
