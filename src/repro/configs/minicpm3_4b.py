"""MiniCPM3-4B — multi-head latent attention (MLA) [hf:openbmb/MiniCPM3-4B]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b", family="dense",
        n_layers=62, d_model=2560, d_ff=6400, vocab_size=73448,
        n_heads=40, n_kv_heads=40, head_dim=64,
        use_mla=True, q_lora_rank=768, kv_lora_rank=256,
        qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
        rope_theta=10_000.0, norm_eps=1e-5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-smoke", family="dense",
        n_layers=2, d_model=64, d_ff=128, vocab_size=512,
        n_heads=4, n_kv_heads=4, head_dim=16,
        use_mla=True, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        norm_eps=1e-5, remat=False,
    )
