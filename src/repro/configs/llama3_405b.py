"""Llama-3.1-405B — dense GQA (kv=8), 128k vocab [arXiv:2407.21783]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b", family="dense",
        n_layers=126, d_model=16384, d_ff=53248, vocab_size=128256,
        n_heads=128, n_kv_heads=8, head_dim=128,
        rope_theta=500_000.0, norm_eps=1e-5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-smoke", family="dense",
        n_layers=2, d_model=64, d_ff=208, vocab_size=512,
        n_heads=4, n_kv_heads=2, head_dim=16,
        rope_theta=500_000.0, remat=False,
    )
