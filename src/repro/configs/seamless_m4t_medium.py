"""SeamlessM4T-medium backbone — encoder-decoder, multimodal [arXiv:2308.11596].

The speech frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings to the 12-layer text/unit encoder; the 12-layer
decoder attends to encoder output via cross-attention.
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="audio",
        n_layers=12, n_enc_layers=12,
        d_model=1024, d_ff=4096, vocab_size=256206,
        n_heads=16, n_kv_heads=16, head_dim=64,
        cross_len=4096, frontend="audio",
        rope_theta=10_000.0, norm_eps=1e-5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke", family="audio",
        n_layers=2, n_enc_layers=2,
        d_model=64, d_ff=128, vocab_size=512,
        n_heads=4, n_kv_heads=4, head_dim=16,
        cross_len=32, frontend="audio", remat=False,
    )
