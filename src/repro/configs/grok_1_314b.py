"""Grok-1 314B — 8 experts, top-2 routing, attention softcap [hf:xai-org/grok-1]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", family="moe",
        n_layers=64, d_model=6144, d_ff=32768, vocab_size=131072,
        n_heads=48, n_kv_heads=8, head_dim=128,
        n_experts=8, experts_per_token=2,
        attn_logit_softcap=30.0, logit_softcap=30.0,
        rope_theta=10_000.0, norm_eps=1e-5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="grok-smoke", family="moe",
        n_layers=2, d_model=64, d_ff=128, vocab_size=512,
        n_heads=4, n_kv_heads=2, head_dim=16,
        n_experts=4, experts_per_token=2,
        attn_logit_softcap=30.0, logit_softcap=30.0, remat=False,
    )
