"""RecurrentGemma-9B — RG-LRU + local attention, 2:1 pattern [arXiv:2402.19427].

38 layers with repeating (recurrent, recurrent, local-attention) blocks:
attention at every third layer, MQA (kv=1), window 2048.
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        n_layers=38, d_model=4096, d_ff=12288, vocab_size=256000,
        n_heads=16, n_kv_heads=1, head_dim=256,
        lru_width=4096, conv_width=4, attn_window=2048,
        block_pattern=("rec", "rec", "attn"),
        embed_scale=True, rope_theta=10_000.0, norm_eps=1e-6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke", family="hybrid",
        n_layers=6, d_model=64, d_ff=128, vocab_size=512,
        n_heads=4, n_kv_heads=1, head_dim=16,
        lru_width=64, conv_width=4, attn_window=16,
        block_pattern=("rec", "rec", "attn"),
        embed_scale=True, remat=False,
    )
