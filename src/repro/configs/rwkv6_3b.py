"""RWKV6 "Finch" 3B — attention-free, data-dependent decay [arXiv:2404.05892]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", family="ssm",
        n_layers=32, d_model=2560, d_ff=8960, vocab_size=65536,
        rwkv_head_size=64,                      # 40 heads
        norm_eps=1e-5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", family="ssm",
        n_layers=2, d_model=64, d_ff=128, vocab_size=512,
        rwkv_head_size=16,                      # 4 heads
        norm_eps=1e-5, remat=False,
    )
