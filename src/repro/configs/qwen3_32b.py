"""Qwen3-32B — GQA (kv=8) with per-head qk-norm [hf:Qwen/Qwen3 family]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", family="dense",
        n_layers=64, d_model=5120, d_ff=25600, vocab_size=151936,
        n_heads=64, n_kv_heads=8, head_dim=128,
        qk_norm=True, rope_theta=1_000_000.0, norm_eps=1e-6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke", family="dense",
        n_layers=2, d_model=64, d_ff=192, vocab_size=512,
        n_heads=4, n_kv_heads=2, head_dim=16,
        qk_norm=True, remat=False,
    )
