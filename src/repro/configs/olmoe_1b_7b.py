"""OLMoE-1B-7B — 64 experts, top-8 routing, qk-norm [arXiv:2409.02060]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        n_layers=16, d_model=2048, d_ff=1024, vocab_size=50304,
        n_heads=16, n_kv_heads=16, head_dim=128,
        n_experts=64, experts_per_token=8,
        qk_norm=True, rope_theta=10_000.0, norm_eps=1e-5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-smoke", family="moe",
        n_layers=2, d_model=64, d_ff=96, vocab_size=512,
        n_heads=4, n_kv_heads=4, head_dim=16,
        n_experts=8, experts_per_token=2,
        qk_norm=True, remat=False,
    )
