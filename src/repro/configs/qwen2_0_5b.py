"""Qwen2-0.5B — GQA (kv=2) with QKV bias [arXiv:2407.10671]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b", family="dense",
        n_layers=24, d_model=896, d_ff=4864, vocab_size=151936,
        n_heads=14, n_kv_heads=2, head_dim=64,
        qkv_bias=True, tie_embeddings=True,
        rope_theta=1_000_000.0, norm_eps=1e-6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-smoke", family="dense",
        n_layers=2, d_model=64, d_ff=160, vocab_size=512,
        n_heads=4, n_kv_heads=2, head_dim=16,
        qkv_bias=True, tie_embeddings=True, remat=False,
    )
