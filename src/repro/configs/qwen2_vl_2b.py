"""Qwen2-VL-2B backbone — M-RoPE, dynamic resolution [arXiv:2409.12191].

The vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings + 3D (t, h, w) M-RoPE position ids.
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b", family="vlm",
        n_layers=28, d_model=1536, d_ff=8960, vocab_size=151936,
        n_heads=12, n_kv_heads=2, head_dim=128,
        qkv_bias=True, tie_embeddings=True,
        rope_sections=(16, 24, 24),            # t/h/w sections, sum = 64 = head_dim/2
        frontend="vision",
        rope_theta=1_000_000.0, norm_eps=1e-6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke", family="vlm",
        n_layers=2, d_model=64, d_ff=128, vocab_size=512,
        n_heads=4, n_kv_heads=2, head_dim=16,
        qkv_bias=True, tie_embeddings=True,
        rope_sections=(2, 3, 3),               # sum = 8 = head_dim/2
        frontend="vision", remat=False,
    )
