"""Assigned-architecture registry: ``--arch <id>`` resolves here.

Each module exposes ``config()`` (the exact published configuration) and
``smoke_config()`` (a reduced same-family config for CPU smoke tests).
"""
from __future__ import annotations

import importlib
from typing import List

from ..models.config import ModelConfig

ARCH_IDS: List[str] = [
    "rwkv6-3b",
    "minicpm3-4b",
    "qwen2-0.5b",
    "qwen3-32b",
    "llama3-405b",
    "olmoe-1b-7b",
    "grok-1-314b",
    "recurrentgemma-9b",
    "qwen2-vl-2b",
    "seamless-m4t-medium",
]


def _module(arch_id: str):
    mod_name = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f".{mod_name}", __package__)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    cfg = _module(arch_id).config()
    cfg.validate()
    return cfg


def get_smoke_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    cfg = _module(arch_id).smoke_config()
    cfg.validate()
    return cfg
