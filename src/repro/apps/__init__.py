"""Example microservice applications built on repro.core."""
from .socialnetwork import (WORKLOADS, build_socialnetwork, make_request_factory)

__all__ = ["build_socialnetwork", "make_request_factory", "WORKLOADS"]
