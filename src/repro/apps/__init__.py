"""DeathStarBench-style microservice applications built on repro.core.

Three canonical DSB apps on the shared substrate — SocialNetwork,
HotelReservation, MediaService — each exposing the same protocol
(``build(backend, ...)``, ``make_request_factory(workload)``, four
workloads) through :data:`REGISTRY`.
"""
from .hotelreservation import build_hotelreservation
from .mediaservice import build_mediaservice
# Legacy single-app exports (SocialNetwork was the first app; its names are
# still imported by older call sites).
from .socialnetwork import (WORKLOADS, build_socialnetwork,
                            make_request_factory)
from .registry import (APP_NAMES, BENCH_BACKENDS, REGISTRY, AppDef,
                       build_bench_app, get_app_def)

__all__ = [
    "REGISTRY", "APP_NAMES", "BENCH_BACKENDS", "AppDef", "get_app_def",
    "build_bench_app",
    "build_socialnetwork", "build_hotelreservation", "build_mediaservice",
    "make_request_factory", "WORKLOADS",
]
