"""Shared request-workload plumbing for the DeathStarBench-style apps.

Every app exposes the same four-generator protocol from the paper's
evaluation: one compose-style write, two read paths, and a weighted
``mixed`` combination.  This module factors the factory construction that
each app module previously hard-coded, so the load generator sees one
uniform :data:`repro.core.RequestFactory` shape regardless of app.
"""
from __future__ import annotations

from typing import Any, Sequence, Tuple

import numpy as np

Mix = Sequence[Tuple[str, float]]


def make_factory(workload: str, *, frontend: str,
                 workloads: Sequence[str], mix: Mix, payload: Any):
    """Build a RequestFactory for ``workload``.

    ``workload`` must be one of ``workloads``; every non-``mixed`` entry maps
    to a fixed ``(frontend, workload, payload)`` request, while ``mixed``
    samples methods from ``mix`` with the trial RNG (seeded by the load
    generator, so request sequences are reproducible across backends).
    """
    if workload not in workloads:
        raise ValueError(
            f"unknown workload {workload!r} (want one of {tuple(workloads)})")
    if workload != "mixed":
        def fixed(rng: np.random.Generator) -> Tuple[str, str, Any]:
            return (frontend, workload, payload)
        return fixed

    names = [m for m, _ in mix]
    probs = np.asarray([p for _, p in mix], dtype=np.float64)
    probs = probs / probs.sum()

    def mixed(rng: np.random.Generator) -> Tuple[str, str, Any]:
        m = names[int(rng.choice(len(names), p=probs))]
        return (frontend, m, payload)
    return mixed
