"""Shared request-workload plumbing for the DeathStarBench-style apps.

Every app exposes the same generator protocol from the paper's evaluation:
one compose-style write, two read paths, a weighted ``mixed`` combination,
and (PR 8) a session-affine ``cached`` workload with Zipfian key
popularity.  This module factors the factory construction that each app
module previously hard-coded, so the load generator sees one uniform
:data:`repro.core.RequestFactory` shape regardless of app.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import numpy as np

Mix = Sequence[Tuple[str, float]]


def make_factory(workload: str, *, frontend: str,
                 workloads: Sequence[str], mix: Mix, payload: Any):
    """Build a RequestFactory for ``workload``.

    ``workload`` must be one of ``workloads``; every non-``mixed`` entry maps
    to a fixed ``(frontend, workload, payload)`` request, while ``mixed``
    samples methods from ``mix`` with the trial RNG (seeded by the load
    generator, so request sequences are reproducible across backends).
    """
    if workload not in workloads:
        raise ValueError(
            f"unknown workload {workload!r} (want one of {tuple(workloads)})")
    if workload != "mixed":
        def fixed(rng: np.random.Generator) -> Tuple[str, str, Any]:
            return (frontend, workload, payload)
        return fixed

    names = [m for m, _ in mix]
    probs = np.asarray([p for _, p in mix], dtype=np.float64)
    probs = probs / probs.sum()

    def mixed(rng: np.random.Generator) -> Tuple[str, str, Any]:
        m = names[int(rng.choice(len(names), p=probs))]
        return (frontend, m, payload)
    return mixed


def make_zipf_factory(*, frontend: str, method: str = "cached",
                      n_keys: int = 1024, alpha: float = 1.1,
                      n_sessions: int = 64, write_frac: float = 0.05,
                      payload: Optional[Any] = None):
    """Session-affine cache workload: Zipf(``alpha``) key popularity.

    Each arrival draws a key from a Zipfian distribution over ``n_keys``
    ranks (precomputed CDF + ``searchsorted``, so the per-arrival cost is
    one uniform draw and a binary search), and returns a **4-tuple**
    ``(frontend, method, payload, session)`` — the 4th element is what
    :func:`repro.core.run_trial` turns into ``RequestContext.session``.
    The session id is derived from the key (``key % n_sessions``), so key
    skew becomes session skew: under by-session shard pinning the hot keys
    concentrate on a few shards — the hot-shard imbalance the pinning A/B
    probe measures.  A ``write_frac`` fraction of arrivals are writes
    (``payload["write"] = True``): the apps' cached read path routes those
    through the backing store plus a cache invalidation.
    """
    ranks = np.arange(1, int(n_keys) + 1, dtype=np.float64)
    weights = ranks ** -float(alpha)
    cdf = np.cumsum(weights / weights.sum())
    base = dict(payload or {})

    def zipf(rng: np.random.Generator) -> Tuple[str, str, Any, str]:
        key = int(np.searchsorted(cdf, rng.random(), side="right"))
        p = dict(base)
        p["key"] = key
        if write_frac > 0.0 and rng.random() < write_frac:
            p["write"] = True
        return (frontend, method, p, "s%d" % (key % n_sessions))
    return zipf
