"""DeathStarBench MediaService (movie reviewing) clone on repro.core.

Service graph (after Gan et al., ASPLOS'19, MediaService app):

    ComposeReview ──async──> UniqueId, Text, UserService, MovieId   (4-wide join)
        └──async──> ReviewStorage.store, UserReview.upload,
                    MovieReview.upload                              (3-wide join)

    ReadMovieReviews ──> MovieReview ──async──> ReviewStorage (batch)
    ReadUserReviews  ──> UserReview  ──async──> ReviewStorage (batch)

Structurally this is the *widest* of the three apps relative to its depth:
ComposeReview performs 7 async calls with no nested fan-out (SocialNetwork's
Text service adds 2 more a level down), so the per-request carrier count is
entirely concentrated in one service.  The paper predicts this shape is the
most sensitive to async-call spawn cost — the frontend's dispatcher pays for
every spawn itself — so the fiber backend's edge should be largest here on
the compose path and smallest on the cache-friendly read paths.

Service times model DSB's deployment: movie-title→id lookup and review reads
hit memcached first, review writes land in MongoDB.
"""
from __future__ import annotations

from typing import Any, Dict

from ..core import App, AsyncRpc, Compute, ServiceSpec, Sleep, Wait, WaitAll
from ._cache import make_cache_handlers, make_cached_read
from ._workload import make_factory, make_zipf_factory

# --- service-time model (seconds) -----------------------------------------
CPU_TINY = 20e-6     # id generation, serialization
CPU_SMALL = 60e-6    # review-text processing, rating math
IO_CACHE = 300e-6    # memcached round trip
IO_DB = 800e-6       # MongoDB round trip

FRONTEND = "frontend"


# ---------------------------------------------------------------- leaf svcs
def _unique_id(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    return {"review_id": 77}


def _text(svc: Any, payload: Any):
    yield Compute(CPU_SMALL)
    yield Sleep(IO_CACHE)
    return {"text": (payload or {}).get("text", "")}


def _user_service(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    yield Sleep(IO_CACHE)
    return {"user_id": 13}


def _movie_id(svc: Any, payload: Any):
    """Title -> movie-id lookup (memcached in front of Mongo in DSB)."""
    yield Compute(CPU_TINY)
    yield Sleep(IO_CACHE)
    return {"movie_id": "m-42",
            "rating": (payload or {}).get("rating", 5)}


def _review_storage_store(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    yield Sleep(IO_DB)
    return {"ok": True}


def _review_storage_read(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    yield Sleep(IO_DB)
    n = (payload or {}).get("n", 10)
    return {"reviews": [{"review_id": i} for i in range(n)]}


# ------------------------------------------------------------- mid services
def _user_review_upload(svc: Any, payload: Any):
    """Append to the user's review timeline (Mongo sorted insert)."""
    yield Compute(CPU_TINY)
    yield Sleep(IO_DB)
    return {"ok": True}


def _user_review_read(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    yield Sleep(IO_CACHE)  # timeline ids from memcached
    f = yield AsyncRpc("review_storage", "read", {"n": 10})
    return (yield Wait(f))


def _movie_review_upload(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    yield Sleep(IO_DB)
    return {"ok": True}


def _movie_review_read(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    yield Sleep(IO_CACHE)
    f = yield AsyncRpc("review_storage", "read", {"n": 10})
    return (yield Wait(f))


# ---------------------------------------------------------------- front svc
def _compose_review(svc: Any, payload: Any):
    """Write path: 4-wide component join, then 3-wide storage/timeline join."""
    yield Compute(CPU_SMALL)
    f_uid = yield AsyncRpc("unique_id", "get", payload)
    f_txt = yield AsyncRpc("text", "process", payload)
    f_usr = yield AsyncRpc("user", "lookup", payload)
    f_mov = yield AsyncRpc("movie_id", "resolve", payload)
    uid, text, user, movie = yield WaitAll([f_uid, f_txt, f_usr, f_mov])

    review = {**uid, **text, **user, **movie}
    f_store = yield AsyncRpc("review_storage", "store", review)
    f_ur = yield AsyncRpc("user_review", "upload", review)
    f_mr = yield AsyncRpc("movie_review", "upload", review)
    yield WaitAll([f_store, f_ur, f_mr])
    return {"review_id": uid["review_id"]}


def _read_movie(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    f = yield AsyncRpc("movie_review", "read", payload)
    return (yield Wait(f))


def _read_user(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    f = yield AsyncRpc("user_review", "read", payload)
    return (yield Wait(f))


# ------------------------------------------------------------------ wiring
def build_mediaservice(backend: str = "fiber", *, n_workers: int = 2,
                       frontend_workers: int = 4,
                       net_latency: float = 0.0,
                       overrides: Dict[str, str] | None = None,
                       resilience: Any = None) -> App:
    """Wire the MediaService app (per-service backend ``overrides`` support
    the paper's one-service-at-a-time migration experiment; ``resilience``
    is an optional :class:`repro.core.ResiliencePolicy`)."""
    overrides = overrides or {}
    app = App(backend=backend, net_latency=net_latency,
              resilience=resilience)

    def add(name: str, handlers: Dict[str, Any], workers: int) -> None:
        app.add_service(ServiceSpec(
            name=name, handlers=handlers, n_workers=workers,
            backend=overrides.get(name)))

    add(FRONTEND, {"compose": _compose_review, "read_movie": _read_movie,
                   "read_user": _read_user,
                   "cached": make_cached_read("review_storage", "store")},
        frontend_workers)
    add("cache", make_cache_handlers(), n_workers)
    add("unique_id", {"get": _unique_id}, n_workers)
    add("text", {"process": _text}, n_workers)
    add("user", {"lookup": _user_service}, n_workers)
    add("movie_id", {"resolve": _movie_id}, n_workers)
    add("review_storage", {"store": _review_storage_store,
                           "read": _review_storage_read}, n_workers)
    add("user_review", {"upload": _user_review_upload,
                        "read": _user_review_read}, n_workers)
    add("movie_review", {"upload": _movie_review_upload,
                         "read": _movie_review_read}, n_workers)
    return app


# ------------------------------------------------------------ request mixes
WORKLOADS = ("compose", "read_movie", "read_user", "mixed", "cached")

# Per-workload end-to-end deadline defaults (seconds) for the overload
# harness — generous multiples of the healthy p99 (see socialnetwork).
DEADLINES = {"compose": 0.08, "read_movie": 0.05, "read_user": 0.05,
             "mixed": 0.08, "cached": 0.05}

# movie-review traffic skews heavily toward reading a movie's reviews.
_MIX = (("compose", 0.10), ("read_movie", 0.65), ("read_user", 0.25))

_PAYLOAD = {"title": "Contact", "text": "great @scenes", "rating": 5}


def make_request_factory(workload: str):
    """Returns a RequestFactory for the load generator (``cached`` is the
    session-affine Zipf-key cache-aside workload; see _workload)."""
    if workload == "cached":
        return make_zipf_factory(frontend=FRONTEND, payload=_PAYLOAD)
    return make_factory(workload, frontend=FRONTEND, workloads=WORKLOADS,
                        mix=_MIX, payload=_PAYLOAD)
