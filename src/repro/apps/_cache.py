"""Shared cache tier for the DeathStarBench-style apps (PR 8).

DSB deployments front their MongoDB stores with memcached; until now the
apps modelled that as a fixed ``Sleep(IO_CACHE)``.  This module makes the
cache a real service with *state*: a ``cache`` service whose ``get`` is
cache-aside — a hit costs one cache round trip, a miss pays the cache
lookup **plus** the backing-store read and then populates the line — so
service time depends on the hit rate, which depends on the workload's key
distribution (see :func:`repro.apps._workload.make_zipf_factory`).  Writes
invalidate, which is what keeps a ``write_frac`` of traffic creating
future misses.

Every lookup ticks the app-wide :class:`repro.core.metrics.CacheStats`
(``svc.app.cache_stats``), which ``App.backend_stats`` surfaces as
``BackendStats.cache_hits`` / ``cache_misses`` — identical accounting on
all eight backends, so hit rates are comparable across the matrix.

The frontends use :func:`make_cached_read`, which also exercises the
request-context plumbing end to end: it reads the ambient
:class:`~repro.core.context.RequestContext` via the ``CurrentContext``
effect and keeps a per-session request counter in ``Service.state`` —
under by-session shard pinning a session's state updates all land on one
shard.
"""
from __future__ import annotations

from typing import Any, Dict

from ..core import AsyncRpc, Compute, CurrentContext, Sleep, Wait, WaitAll

# service-time model (seconds) — matches the apps' constants
CPU_TINY = 20e-6     # key hashing / serialization
IO_CACHE = 300e-6    # memcached round trip
IO_DB = 800e-6       # backing-store (MongoDB) round trip


def make_cache_handlers(*, io_cache: float = IO_CACHE,
                        io_db: float = IO_DB) -> Dict[str, Any]:
    """Handlers for a ``cache`` service over a closure-captured store.

    ``get`` is cache-aside: hit -> one ``io_cache`` round trip; miss ->
    the ``io_cache`` lookup, the ``io_db`` backing read, then populate.
    ``invalidate`` drops the line (the write path calls it).  Plain dict
    ops are atomic under the GIL; a concurrent double-miss on the same
    cold key just populates twice, as a real look-aside cache would.
    """
    store: Dict[Any, Any] = {}

    def _get(svc: Any, payload: Any):
        key = (payload or {}).get("key", 0)
        yield Compute(CPU_TINY)
        # snapshot the line at lookup time: a concurrent invalidation may
        # drop the key while this handler sleeps out the round trip, and a
        # look-aside read that raced a write legitimately returns the value
        # it found
        value = store.get(key)
        if value is not None:
            svc.app.cache_stats.hit()
            yield Sleep(io_cache)
            return {"key": key, "value": value, "cached": True}
        svc.app.cache_stats.miss()
        yield Sleep(io_cache)   # the miss still pays the lookup trip
        yield Sleep(io_db)      # then the backing-store read
        value = "v:%s" % key
        store[key] = value
        return {"key": key, "value": value, "cached": False}

    def _invalidate(svc: Any, payload: Any):
        key = (payload or {}).get("key", 0)
        yield Compute(CPU_TINY)
        store.pop(key, None)
        yield Sleep(io_cache)
        return {"ok": True, "key": key}

    return {"get": _get, "invalidate": _invalidate}


def make_cached_read(write_dest: str, write_method: str):
    """Frontend handler for the ``cached`` workload.

    Reads go cache-aside through the ``cache`` service; arrivals flagged
    ``payload["write"]`` instead update the app's backing store
    (``write_dest.write_method``) and invalidate the cache line in
    parallel.  Either way the handler bumps a per-session counter in
    ``Service.state`` keyed by the ambient ``RequestContext.session``.
    """
    def _cached(svc: Any, payload: Any):
        yield Compute(CPU_TINY)
        ctx = yield CurrentContext()
        if ctx is not None and ctx.session is not None:
            with svc.lock:  # per-session state (shard-local when pinned)
                sessions = svc.state.setdefault("sessions", {})
                sessions[ctx.session] = sessions.get(ctx.session, 0) + 1
        if (payload or {}).get("write"):
            f_db = yield AsyncRpc(write_dest, write_method, payload)
            f_inv = yield AsyncRpc("cache", "invalidate",
                                   {"key": (payload or {}).get("key", 0)})
            yield WaitAll([f_db, f_inv])
            return {"ok": True}
        f = yield AsyncRpc("cache", "get",
                           {"key": (payload or {}).get("key", 0)})
        return (yield Wait(f))
    return _cached
