"""App registry: one place that knows every DeathStarBench-style app.

The load generator, the serving benchmarks, ``benchmarks/run.py`` and
``launch_results/render_tables.py`` are all parameterized by app name
through this table instead of hard-coding SocialNetwork, so adding an app
means registering one :class:`AppDef` here.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Tuple

from ..core import App, BACKEND_NAMES
from . import hotelreservation, mediaservice, socialnetwork

# The benchmark/CI backend matrix: every registered execution backend.
# Harnesses iterate this instead of hard-coding backend pairs, so a new
# executor in core.executor.BACKEND_FACTORIES joins every sweep for free.
BENCH_BACKENDS = BACKEND_NAMES

# build(backend, *, n_workers, frontend_workers, net_latency, overrides)
BuildFn = Callable[..., App]


@dataclass(frozen=True)
class AppDef:
    """Everything the harnesses need to drive one app."""
    name: str
    build: BuildFn
    make_request_factory: Callable[[str], Any]
    workloads: Tuple[str, ...]
    frontend: str
    description: str = ""
    # workload -> default end-to-end deadline (s) for the overload harness
    deadlines: Dict[str, float] = field(default_factory=dict)
    # role -> (dest, method) edges for fault-injection scenarios
    # (benchmarks/bench_faults.py): "sick" is the write-path storage leaf a
    # scenario degrades, "healthy" the read-path method of the *same*
    # service that must stay up — the per-edge blast-radius story.  Both
    # are exercised by the app's "mixed" workload.
    fault_targets: Dict[str, Tuple[str, str]] = field(default_factory=dict)


REGISTRY: Dict[str, AppDef] = {
    "socialnetwork": AppDef(
        name="socialnetwork",
        build=socialnetwork.build_socialnetwork,
        make_request_factory=socialnetwork.make_request_factory,
        workloads=tuple(socialnetwork.WORKLOADS),
        frontend="frontend",
        description="deep graph, nested fan-out (ComposePost: 7+2 carriers)",
        deadlines=dict(socialnetwork.DEADLINES),
        fault_targets={"sick": ("post_storage", "store"),
                       "healthy": ("post_storage", "read")},
    ),
    "hotelreservation": AppDef(
        name="hotelreservation",
        build=hotelreservation.build_hotelreservation,
        make_request_factory=hotelreservation.make_request_factory,
        workloads=tuple(hotelreservation.WORKLOADS),
        frontend=hotelreservation.FRONTEND,
        description="shallow graph, 2-wide joins, CPU-heavy auth leaf",
        deadlines=dict(hotelreservation.DEADLINES),
        fault_targets={"sick": ("reservation", "make_reservation"),
                       "healthy": ("reservation", "check_availability")},
    ),
    "mediaservice": AppDef(
        name="mediaservice",
        build=mediaservice.build_mediaservice,
        make_request_factory=mediaservice.make_request_factory,
        workloads=tuple(mediaservice.WORKLOADS),
        frontend=mediaservice.FRONTEND,
        description="widest single-service fan-out (ComposeReview: 7 carriers)",
        deadlines=dict(mediaservice.DEADLINES),
        fault_targets={"sick": ("review_storage", "store"),
                       "healthy": ("review_storage", "read")},
    ),
}

APP_NAMES: Tuple[str, ...] = tuple(REGISTRY)


def get_app_def(name: str) -> AppDef:
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown app {name!r} (want one of {APP_NAMES})") from None


def build_bench_app(name: str, backend: str, **overrides: Any) -> App:
    """Build ``name`` with the benchmark pool sizing: generous thread pools
    (DSB's thread-per-connection Thrift servers) so async-call spawn cost —
    not pool size — is the binding constraint, as in the paper's setup.
    Thread-family backends (``thread``, ``thread-pool``) get the wide
    dispatcher pools; fiber-family backends (``fiber``, ``fiber-steal``,
    ``fiber-batch``, ``fiber-batch-cq``) keep the paper's small scheduler
    counts; ``event-loop`` is pinned to one worker per service — the
    executor is single-carrier by design, so extra workers would only be
    ignored — while ``event-loop-shard`` shards only where the request
    stream lands: the frontend gets the shard fan (lifting the one-loop
    Compute-serialization ceiling is the design point it exists to
    measure), leaf services stay single-loop — sharding a sleepy leaf only
    fragments its timer wheel across more GIL-contending threads."""
    if backend.startswith("thread"):
        sizing = dict(n_workers=8, frontend_workers=16)
    elif backend == "event-loop":
        sizing = dict(n_workers=1, frontend_workers=1)
    elif backend == "event-loop-shard":
        sizing = dict(n_workers=1, frontend_workers=4)
    else:
        sizing = dict(n_workers=2, frontend_workers=2)
    sizing.update(overrides)
    return get_app_def(name).build(backend, **sizing)
