"""DeathStarBench SocialNetwork clone on the repro.core substrate.

Service graph (after Gan et al., ASPLOS'19, and the paper's Figure 1):

    ComposePost ──async──> UniqueId, Text, UserService, MediaService
        │                      Text ──async──> UrlShorten, UserMention
        └─────async──> HomeTimeline, UserTimeline, PostStorage

    ReadHomeTimeline ──> HomeTimeline ──async──> PostStorage (batch)
    ReadUserTimeline ──> UserTimeline ──async──> PostStorage (batch)

Four request generators, as in the paper's evaluation: ``compose``,
``read_home``, ``read_user`` and ``mixed`` (a weighted combination).

Service times model a cache/DB-backed deployment: a small CPU slice
(serialization, hashing — *real* busy work) plus a wait-dominated I/O slice
(memcached/MongoDB round trip — timed wait).  The async-call carriers are
where the two backends differ; everything else is shared.
"""
from __future__ import annotations

from typing import Any, Dict

from ..core import (App, AsyncRpc, Compute, ServiceSpec, Sleep, Wait, WaitAll)
from ._cache import make_cache_handlers, make_cached_read
from ._workload import make_factory, make_zipf_factory

# --- service-time model (seconds) -----------------------------------------
# CPU slices are kept small (they serialize on the GIL for both backends);
# I/O slices dominate, as in a cache-backed social network.
CPU_TINY = 20e-6     # hashing / id generation
CPU_SMALL = 60e-6    # text processing, serialization
IO_CACHE = 300e-6    # memcached-style round trip
IO_DB = 800e-6       # MongoDB-style round trip


# ---------------------------------------------------------------- leaf svcs
def _unique_id(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    return {"post_id": 42}


def _url_shorten(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    yield Sleep(IO_CACHE)
    return {"urls": payload}


def _user_mention(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    yield Sleep(IO_CACHE)
    return {"mentions": payload}


def _media(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    yield Sleep(IO_CACHE)
    return {"media": payload}


def _user_service(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    yield Sleep(IO_CACHE)
    return {"user_id": 7}


def _post_storage_store(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    yield Sleep(IO_DB)
    return {"ok": True}


def _post_storage_read(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    yield Sleep(IO_DB)
    return {"posts": [{"id": i} for i in range(payload.get("n", 10))]}


# ------------------------------------------------------------- mid services
def _text(svc: Any, payload: Any):
    """Text service fans out to UrlShorten + UserMention (async, joined)."""
    yield Compute(CPU_SMALL)
    f_url = yield AsyncRpc("url_shorten", "shorten", payload)
    f_men = yield AsyncRpc("user_mention", "resolve", payload)
    urls, mentions = yield WaitAll([f_url, f_men])
    return {"text": payload, **urls, **mentions}


def _home_timeline_write(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    yield Sleep(IO_CACHE)
    return {"ok": True}


def _home_timeline_read(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    yield Sleep(IO_CACHE)  # redis timeline lookup
    f = yield AsyncRpc("post_storage", "read", {"n": 10})
    posts = yield Wait(f)
    return posts


def _user_timeline_write(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    yield Sleep(IO_DB)
    return {"ok": True}


def _user_timeline_read(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    yield Sleep(IO_CACHE)
    f = yield AsyncRpc("post_storage", "read", {"n": 10})
    posts = yield Wait(f)
    return posts


# ---------------------------------------------------------------- front svc
def _compose_post(svc: Any, payload: Any):
    """The paper's running example: four async calls joined, then three more.

    This is the service whose thread backend spends 23% of its time in
    clone/exit in the paper's simulations.
    """
    yield Compute(CPU_SMALL)
    f_uid = yield AsyncRpc("unique_id", "get", payload)
    f_txt = yield AsyncRpc("text", "process", payload)
    f_usr = yield AsyncRpc("user", "lookup", payload)
    f_med = yield AsyncRpc("media", "upload", payload)
    uid, text, user, media = yield WaitAll([f_uid, f_txt, f_usr, f_med])

    post = {**uid, **text, **user, **media}
    f_home = yield AsyncRpc("home_timeline", "write", post)
    f_user = yield AsyncRpc("user_timeline", "write", post)
    f_store = yield AsyncRpc("post_storage", "store", post)
    yield WaitAll([f_home, f_user, f_store])
    return {"post_id": uid["post_id"]}


def _read_home(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    f = yield AsyncRpc("home_timeline", "read", payload)
    return (yield Wait(f))


def _read_user(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    f = yield AsyncRpc("user_timeline", "read", payload)
    return (yield Wait(f))


# ------------------------------------------------------------------ wiring
def build_socialnetwork(backend: str = "fiber", *, n_workers: int = 2,
                        frontend_workers: int = 4,
                        net_latency: float = 0.0,
                        overrides: Dict[str, str] | None = None,
                        resilience: Any = None) -> App:
    """Wire the SocialNetwork app.

    ``overrides`` maps service name -> backend, supporting the paper's
    one-service-at-a-time migration experiment.  ``resilience`` is an
    optional :class:`repro.core.ResiliencePolicy` for overload experiments.
    """
    overrides = overrides or {}
    app = App(backend=backend, net_latency=net_latency,
              resilience=resilience)

    def add(name: str, handlers: Dict[str, Any], workers: int) -> None:
        app.add_service(ServiceSpec(
            name=name, handlers=handlers, n_workers=workers,
            backend=overrides.get(name)))

    add("frontend", {"compose": _compose_post, "read_home": _read_home,
                     "read_user": _read_user,
                     "cached": make_cached_read("post_storage", "store")},
        frontend_workers)
    add("cache", make_cache_handlers(), n_workers)
    add("unique_id", {"get": _unique_id}, n_workers)
    add("text", {"process": _text}, n_workers)
    add("user", {"lookup": _user_service}, n_workers)
    add("media", {"upload": _media}, n_workers)
    add("url_shorten", {"shorten": _url_shorten}, n_workers)
    add("user_mention", {"resolve": _user_mention}, n_workers)
    add("home_timeline", {"write": _home_timeline_write,
                          "read": _home_timeline_read}, n_workers)
    add("user_timeline", {"write": _user_timeline_write,
                          "read": _user_timeline_read}, n_workers)
    add("post_storage", {"store": _post_storage_store,
                         "read": _post_storage_read}, n_workers)
    return app


# ------------------------------------------------------------ request mixes
WORKLOADS = ("compose", "read_home", "read_user", "mixed", "cached")

# Per-workload end-to-end deadline defaults (seconds) for the overload
# harness: generous multiples of the healthy p99 so they only bite when the
# app is genuinely drowning, not on ordinary tail noise.
DEADLINES = {"compose": 0.08, "read_home": 0.05, "read_user": 0.05,
             "mixed": 0.08, "cached": 0.05}

# the paper's "mixed" generator combines the three request types; DSB's
# default mix is read-heavy.
_MIX = (("compose", 0.10), ("read_home", 0.60), ("read_user", 0.30))

_PAYLOAD = {"text": "hello @world http://x"}


def make_request_factory(workload: str):
    """Returns a RequestFactory for the load generator (``cached`` is the
    session-affine Zipf-key cache-aside workload; see _workload)."""
    if workload == "cached":
        return make_zipf_factory(frontend="frontend", payload=_PAYLOAD)
    return make_factory(workload, frontend="frontend", workloads=WORKLOADS,
                        mix=_MIX, payload=_PAYLOAD)
