"""DeathStarBench HotelReservation clone on the repro.core substrate.

Service graph (after Gan et al., ASPLOS'19, HotelReservation app):

    SearchHotel ──> Search ──async──> Geo, Rate          (joined)
        └──async──> Profile, Reservation.check           (joined)
    Recommend ──> Recommendation ──> Profile
    Reserve   ──async──> User (auth), Reservation.check  (joined)
        └──> Reservation.make

Compared with SocialNetwork this graph is *shallower* (max depth 3) and its
frontend fan-out is narrower (2-wide joins instead of the 4-wide ComposePost
join), but the reserve path adds a user-auth password hash — a CPU-heavier
leaf.  Backend sensitivity is therefore expected to be smaller than
SocialNetwork's but still thread-unfavourable at high rates: every search
still spawns 4 async carriers.

Service times model DSB's memcached+MongoDB deployment: geo and
recommendation hit Mongo (slow), rate/profile/availability hit memcached
(fast), reservation writes hit Mongo.
"""
from __future__ import annotations

from typing import Any, Dict

from ..core import App, AsyncRpc, Compute, ServiceSpec, Sleep, Wait, WaitAll
from ._cache import make_cache_handlers, make_cached_read
from ._workload import make_factory, make_zipf_factory

# --- service-time model (seconds) -----------------------------------------
CPU_TINY = 20e-6     # id lookups, serialization
CPU_SMALL = 60e-6    # distance math, rate plan merge
CPU_AUTH = 120e-6    # password hash on the user-auth path
IO_CACHE = 300e-6    # memcached round trip
IO_DB = 800e-6       # MongoDB round trip

FRONTEND = "frontend"

_NEARBY = [101, 102, 103, 104, 105]


# ---------------------------------------------------------------- leaf svcs
def _geo_nearby(svc: Any, payload: Any):
    """Geo index lookup (Mongo-backed in DSB)."""
    yield Compute(CPU_SMALL)
    yield Sleep(IO_DB)
    return {"hotel_ids": list(_NEARBY)}


def _rate_get(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    yield Sleep(IO_CACHE)
    ids = (payload or {}).get("hotel_ids", _NEARBY)
    return {"rates": {h: 100 + h % 7 for h in ids}}


def _profile_get(svc: Any, payload: Any):
    yield Compute(CPU_SMALL)
    yield Sleep(IO_CACHE)
    ids = (payload or {}).get("hotel_ids", _NEARBY)
    return {"profiles": [{"id": h, "name": f"hotel-{h}"} for h in ids]}


def _recommendation_get(svc: Any, payload: Any):
    yield Compute(CPU_SMALL)
    yield Sleep(IO_DB)
    return {"hotel_ids": list(_NEARBY[:3])}


def _user_check(svc: Any, payload: Any):
    """User auth: the CPU-heavy leaf (password hash) + credential lookup."""
    yield Compute(CPU_AUTH)
    yield Sleep(IO_CACHE)
    return {"authorized": True, "user": (payload or {}).get("user", "guest")}


def _reservation_check(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    yield Sleep(IO_CACHE)
    ids = (payload or {}).get("hotel_ids", _NEARBY)
    return {"available": {h: True for h in ids}}


def _reservation_make(svc: Any, payload: Any):
    yield Compute(CPU_TINY)
    yield Sleep(IO_DB)
    return {"confirmed": True, "hotel_id": (payload or {}).get("hotel_id", 101)}


# ------------------------------------------------------------- mid services
def _search_nearby(svc: Any, payload: Any):
    """Search fans out to Geo + Rate (async, joined)."""
    yield Compute(CPU_SMALL)
    f_geo = yield AsyncRpc("geo", "nearby", payload)
    f_rate = yield AsyncRpc("rate", "get_rates", payload)
    geo, rate = yield WaitAll([f_geo, f_rate])
    return {**geo, **rate}


# ---------------------------------------------------------------- front svc
def _search_hotel(svc: Any, payload: Any):
    """Read path 1: search, then join profiles + availability."""
    yield Compute(CPU_SMALL)
    f = yield AsyncRpc("search", "nearby", payload)
    found = yield Wait(f)
    req = {"hotel_ids": found["hotel_ids"]}
    f_prof = yield AsyncRpc("profile", "get_profiles", req)
    f_avail = yield AsyncRpc("reservation", "check_availability", req)
    prof, avail = yield WaitAll([f_prof, f_avail])
    return {**found, **prof, **avail}


def _recommend(svc: Any, payload: Any):
    """Read path 2: recommendation engine, then profiles."""
    yield Compute(CPU_TINY)
    f = yield AsyncRpc("recommendation", "get_recs", payload)
    recs = yield Wait(f)
    f_prof = yield AsyncRpc("profile", "get_profiles", recs)
    prof = yield Wait(f_prof)
    return {**recs, **prof}


def _reserve(svc: Any, payload: Any):
    """Write path: auth + availability joined, then the reservation write."""
    yield Compute(CPU_SMALL)
    f_auth = yield AsyncRpc("user", "check_user", payload)
    f_avail = yield AsyncRpc("reservation", "check_availability", payload)
    auth, avail = yield WaitAll([f_auth, f_avail])
    if not auth["authorized"]:  # pragma: no cover - auth stub always passes
        raise PermissionError("bad credentials")
    f_make = yield AsyncRpc("reservation", "make_reservation",
                            {"hotel_id": (payload or {}).get("hotel_id", 101)})
    made = yield Wait(f_make)
    return {"user": auth["user"], **made}


# ------------------------------------------------------------------ wiring
def build_hotelreservation(backend: str = "fiber", *, n_workers: int = 2,
                           frontend_workers: int = 4,
                           net_latency: float = 0.0,
                           overrides: Dict[str, str] | None = None,
                           resilience: Any = None) -> App:
    """Wire the HotelReservation app (per-service backend ``overrides``
    support the paper's one-service-at-a-time migration experiment;
    ``resilience`` is an optional :class:`repro.core.ResiliencePolicy`)."""
    overrides = overrides or {}
    app = App(backend=backend, net_latency=net_latency,
              resilience=resilience)

    def add(name: str, handlers: Dict[str, Any], workers: int) -> None:
        app.add_service(ServiceSpec(
            name=name, handlers=handlers, n_workers=workers,
            backend=overrides.get(name)))

    add(FRONTEND, {"search": _search_hotel, "recommend": _recommend,
                   "reserve": _reserve,
                   "cached": make_cached_read("reservation",
                                              "make_reservation")},
        frontend_workers)
    add("cache", make_cache_handlers(), n_workers)
    add("search", {"nearby": _search_nearby}, n_workers)
    add("geo", {"nearby": _geo_nearby}, n_workers)
    add("rate", {"get_rates": _rate_get}, n_workers)
    add("profile", {"get_profiles": _profile_get}, n_workers)
    add("recommendation", {"get_recs": _recommendation_get}, n_workers)
    add("user", {"check_user": _user_check}, n_workers)
    add("reservation", {"check_availability": _reservation_check,
                        "make_reservation": _reservation_make}, n_workers)
    return app


# ------------------------------------------------------------ request mixes
WORKLOADS = ("reserve", "search", "recommend", "mixed", "cached")

# Per-workload end-to-end deadline defaults (seconds) for the overload
# harness — generous multiples of the healthy p99 (see socialnetwork).
DEADLINES = {"reserve": 0.08, "search": 0.06, "recommend": 0.05,
             "mixed": 0.08, "cached": 0.05}

# DSB's hotel mix is search-dominated with rare writes.
_MIX = (("search", 0.60), ("recommend", 0.25), ("reserve", 0.15))

_PAYLOAD = {"user": "u7", "lat": 37.7, "lon": -122.4, "hotel_id": 103}


def make_request_factory(workload: str):
    """Returns a RequestFactory for the load generator (``cached`` is the
    session-affine Zipf-key cache-aside workload; see _workload)."""
    if workload == "cached":
        return make_zipf_factory(frontend=FRONTEND, payload=_PAYLOAD)
    return make_factory(workload, frontend=FRONTEND, workloads=WORKLOADS,
                        mix=_MIX, payload=_PAYLOAD)
