"""Async sharded checkpointing through the fiber runtime.

The paper's thesis — wait-dominated async work belongs on fibers, not
threads — applied to training I/O: checkpoint writes are *fibers* on a
dedicated scheduler that offload file writes to a small blocking pool, so
the train loop never blocks and no per-checkpoint kernel threads are spawned.

Layout (per checkpoint directory):
    manifest.json          tree structure, global shapes/dtypes, step, commit
    shard-<host>-<n>.npz   local addressable shards (one file per host)

Fault-tolerance properties:
  * atomic commit: manifest written last; restore ignores uncommitted dirs
  * rotation: keep_n most-recent committed checkpoints
  * elastic restore: arrays are re-sharded onto the *current* mesh via
    jax.device_put with the target sharding (checkpoint carries only logical
    shapes, so pod counts can change between save and restore)
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize bf16 natively: store raw uint16 bits, reinterpret on
# load using the logical dtype recorded in the manifest.
_BITCAST = {"bfloat16": (np.uint16, ml_dtypes.bfloat16),
            "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn)}


def _to_storable(x: np.ndarray) -> np.ndarray:
    name = str(x.dtype)
    if name in _BITCAST:
        return x.view(_BITCAST[name][0])
    return x


def _from_storable(x: np.ndarray, logical_dtype: str) -> np.ndarray:
    if logical_dtype in _BITCAST:
        return x.view(_BITCAST[logical_dtype][1])
    return x

from ..core.effects import Offload, Wait, WaitAll
from ..core.fiber import FiberScheduler
from ..core.future import Future
from ..core.service import OffloadPool


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3,
                 io_threads: int = 4) -> None:
        self.directory = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)
        self._pool = OffloadPool(io_threads)
        self._pool.start()
        self._sched = FiberScheduler(self, name="ckpt-fibers")
        self._sched.start()
        self._pending: List[Future] = []

    # FiberScheduler expects an app-like object with .offload / .rpc_carrier
    def offload(self, fn, *args):
        return self._pool.submit(fn, *args)

    def rpc_carrier(self, dest, method, payload,
                    ctx=None):  # pragma: no cover
        raise RuntimeError("checkpoint fibers make no RPCs")

    # ------------------------------------------------------------------ save
    def save_async(self, step: int, state: Any,
                   metadata: Optional[Dict[str, Any]] = None) -> Future:
        """Snapshot to host memory synchronously (cheap, device->host copy),
        then write + commit + rotate on fibers. Returns a commit Future."""
        leaves = _flatten_with_paths(state)
        host = [(path, np.asarray(x)) for path, x in leaves]
        manifest = {
            "step": int(step),
            "time": time.time(),
            "metadata": metadata or {},
            "leaves": [{"path": p, "shape": list(x.shape),
                        "dtype": str(x.dtype)} for p, x in host],
        }
        return self._sched.spawn_external(
            self._save_fiber(step, host, manifest), name=f"ckpt-{step}")

    def _save_fiber(self, step: int, host, manifest):
        d = os.path.join(self.directory, f"step_{step:08d}")
        tmp = d + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        host_id = jax.process_index()
        # write shards in parallel on the blocking pool
        futs = []
        chunk = max(len(host) // 4, 1)
        for i in range(0, len(host), chunk):
            part = dict()
            for p, x in host[i:i + chunk]:
                part[p] = _to_storable(x)
            path = os.path.join(tmp, f"shard-{host_id}-{i // chunk}.npz")
            fut = yield Offload(lambda path=path, part=part:
                                np.savez(path, **part))
            futs.append(fut)
        yield WaitAll(futs)
        # commit point: manifest last, then atomic rename (idempotent on
        # re-save of the same step)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(d):
            shutil.rmtree(d, ignore_errors=True)
        os.replace(tmp, d)
        self._rotate()
        return d

    def _rotate(self) -> None:
        ckpts = self.list_checkpoints()
        for old in ckpts[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.directory, old),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def list_checkpoints(self) -> List[str]:
        out = []
        for name in sorted(os.listdir(self.directory)):
            if name.startswith("step_") and not name.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.directory, name,
                                                "manifest.json")):
                out.append(name)
        return out

    def latest_step(self) -> Optional[int]:
        ckpts = self.list_checkpoints()
        return int(ckpts[-1][5:]) if ckpts else None

    def restore(self, target: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[int, Any]:
        """Restore into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        NamedShardings for elastic re-shard onto the current mesh."""
        ckpts = self.list_checkpoints()
        if not ckpts:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        name = f"step_{step:08d}" if step is not None else ckpts[-1]
        d = os.path.join(self.directory, name)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        dtypes = {leaf["path"]: leaf["dtype"] for leaf in manifest["leaves"]}
        data: Dict[str, np.ndarray] = {}
        for fname in os.listdir(d):
            if fname.endswith(".npz"):
                with np.load(os.path.join(d, fname)) as z:
                    for key in z.files:
                        data[key] = _from_storable(z[key], dtypes[key])

        paths = [p for p, _ in _flatten_with_paths(target)]
        leaves, treedef = jax.tree.flatten(target)
        shard_leaves = (jax.tree.flatten(shardings)[0]
                        if shardings is not None else [None] * len(leaves))
        out = []
        for path, ref_leaf, shd in zip(paths, leaves, shard_leaves):
            if path not in data:
                raise KeyError(f"checkpoint missing leaf {path}")
            arr = data[path]
            expect = tuple(ref_leaf.shape)
            if tuple(arr.shape) != expect:
                raise ValueError(f"{path}: shape {arr.shape} != {expect}")
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            else:
                out.append(jax.device_put(arr))
        return manifest["step"], treedef.unflatten(out)

    def wait_all(self, timeout: float = 60.0) -> None:
        pass  # futures returned by save_async are awaited by callers

    def close(self) -> None:
        self._sched.stop()
        self._pool.stop()
