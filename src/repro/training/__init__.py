"""Training substrate: optimizer, train step, checkpointing, data pipeline."""
from .optimizer import AdamWConfig, adamw_init, adamw_update
from .train_step import TrainSettings, make_train_step
from .checkpoint import CheckpointManager
from .data import SyntheticDataset, Prefetcher

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update",
    "TrainSettings", "make_train_step",
    "CheckpointManager", "SyntheticDataset", "Prefetcher",
]
