"""Train-step factory: gradient accumulation, remat, compression hooks.

The returned ``train_step(params, opt_state, batch)`` is a single jittable
function.  Gradient accumulation runs as a ``lax.scan`` over microbatches so
the HLO stays compact and XLA's latency-hiding scheduler can overlap the
reduce-scatter of microbatch *i* with the backward of *i+1* (the paper's
overlap-the-waits idea at the collective level).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import Model
from .optimizer import AdamWConfig, adamw_update, make_optimizer


@dataclass(frozen=True)
class TrainSettings:
    """Per-architecture training memory/layout knobs."""
    accum_steps: int = 1              # grad-accum microbatches
    grad_dtype: str = "float32"       # accumulation dtype ("bfloat16" at 100B+)
    opt_state_dtype: str = "float32"
    optimizer: str = "adamw"          # "adamw" | "adafactor" (factored v)
    seq_shard_activations: bool = False   # Megatron-style sequence parallelism
    compress_grads: bool = False      # int8 all-reduce w/ error feedback


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    settings: TrainSettings = TrainSettings(),
                    grad_transform: Optional[Callable[[Any], Any]] = None,
                    mesh: Optional[jax.sharding.Mesh] = None) -> Callable:
    """Build the jittable train step.

    grad_transform: optional hook applied to the accumulated grads before the
    optimizer.  With ``settings.compress_grads`` and a multi-pod mesh, the
    per-microbatch gradient computation runs pod-locally and the cross-pod
    reduction moves int8 (4x fewer DCI bytes).
    """
    A = settings.accum_steps
    gdt = jnp.dtype(settings.grad_dtype)

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    pod_grad_fn = None
    if settings.compress_grads and mesh is not None \
            and "pod" in mesh.shape:
        from ..distributed.compression import make_pod_compressed_grad_fn
        pod_grad_fn = make_pod_compressed_grad_fn(
            lambda p, b: model.loss(p, b)[0], mesh)

    def value_and_grads(params, mb):
        if pod_grad_fn is not None:
            loss, grads = pod_grad_fn(params, mb)
            return (loss, {"loss": loss}), grads
        return jax.value_and_grad(loss_fn, has_aux=True)(params, mb)

    def train_step(params: Any, opt_state: Dict[str, Any],
                   batch: Dict[str, jax.Array]
                   ) -> Tuple[Any, Dict[str, Any], Dict[str, Any]]:
        if A == 1:
            (loss, metrics), grads = value_and_grads(params, batch)
        else:
            # split the global batch into A microbatches along the batch
            # axis (axis 0; the M-RoPE positions tensor carries batch on
            # axis 1 behind a leading (t,h,w)=3 plane dim)
            def shard_mb(path, x):
                name = getattr(path[-1], "key", "")
                if name == "positions" and x.ndim >= 3 and x.shape[0] == 3:
                    B = x.shape[1]
                    assert B % A == 0, (B, A)
                    r = x.reshape((3, A, B // A) + x.shape[2:])
                    return jnp.moveaxis(r, 1, 0)
                B = x.shape[0]
                assert B % A == 0, (B, A)
                return x.reshape((A, B // A) + x.shape[1:])
            mbs = jax.tree_util.tree_map_with_path(shard_mb, batch)

            def accum_body(carry, mb):
                acc, loss_acc = carry
                (loss, _), grads = value_and_grads(params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(gdt) / A, acc, grads)
                return (acc, loss_acc + loss / A), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, gdt), params)
            (grads, loss), _ = jax.lax.scan(
                accum_body, (zeros, jnp.zeros((), jnp.float32)), mbs)
            metrics = {"loss": loss}

        if grad_transform is not None:
            grads = grad_transform(grads)

        _, update_fn = make_optimizer(settings.optimizer, opt_cfg)
        new_params, new_opt, opt_metrics = update_fn(
            grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return new_params, new_opt, metrics

    return train_step


# ------------------------------------------------------- per-arch settings
TRAIN_SETTINGS: Dict[str, TrainSettings] = {
    # 100B+ dense/MoE: bf16 optimizer + grad accumulation + sequence-parallel
    "llama3-405b": TrainSettings(accum_steps=16, grad_dtype="bfloat16",
                                 opt_state_dtype="bfloat16",
                                 seq_shard_activations=True),
    "grok-1-314b": TrainSettings(accum_steps=8, grad_dtype="bfloat16",
                                 opt_state_dtype="bfloat16",
                                 seq_shard_activations=True),
    "qwen3-32b": TrainSettings(accum_steps=2, seq_shard_activations=True),
    "recurrentgemma-9b": TrainSettings(accum_steps=4),
    # smaller archs: accumulate so per-device S x S attention-score temps
    # (the no-flash baseline) stay within the 16 GB/chip budget
    "minicpm3-4b": TrainSettings(accum_steps=16),
    "rwkv6-3b": TrainSettings(accum_steps=4),
    "olmoe-1b-7b": TrainSettings(accum_steps=2),
    "qwen2-0.5b": TrainSettings(accum_steps=4),
    "qwen2-vl-2b": TrainSettings(accum_steps=4),
    "seamless-m4t-medium": TrainSettings(accum_steps=8),
}


def settings_for(arch: str) -> TrainSettings:
    return TRAIN_SETTINGS.get(arch, TrainSettings())
