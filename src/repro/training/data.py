"""Synthetic LM data pipeline with fiber-based prefetch.

Deterministic (seeded) Zipf-distributed token streams; the Prefetcher keeps a
bounded queue of ready batches filled by a fiber that offloads generation to
the blocking pool — the train loop's ``next()`` almost never waits (the
paper's overlap-the-waits idea on the input path).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np

from ..models.config import ModelConfig


class SyntheticDataset:
    """Deterministic synthetic batches shaped for any model family."""

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 seed: int = 0) -> None:
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        cfg, B, S = self.cfg, self.batch, self.seq_len
        # zipf-ish marginal over the vocab, cheap + heavy-tailed
        z = rng.zipf(1.3, size=(B, S + 1))
        tokens_full = (z % cfg.vocab_size).astype(np.int32)
        out: Dict[str, Any] = {
            "tokens": tokens_full[:, :S],
            "labels": tokens_full[:, 1:],
        }
        if cfg.family == "vlm":
            out["embeds"] = rng.standard_normal(
                (B, S, cfg.d_model), dtype=np.float32) * 0.02
            out["embed_mask"] = (np.arange(S)[None] < S // 8).repeat(B, 0)
            pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S))
            out["positions"] = np.stack([pos, pos, pos])
        if cfg.is_encdec:
            tgt = max(S // 4, 8)
            out = {
                "src": rng.standard_normal((B, S, cfg.d_model),
                                           dtype=np.float32) * 0.02,
                "tokens": tokens_full[:, :tgt],
                "labels": tokens_full[:, 1:tgt + 1],
            }
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Bounded background prefetch (depth-N double buffering)."""

    def __init__(self, dataset: SyntheticDataset, depth: int = 2) -> None:
        self.dataset = dataset
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = False
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self) -> None:
        for batch in self.dataset:
            if self._stop:
                return
            self._q.put(batch)

    def __next__(self) -> Dict[str, np.ndarray]:
        return self._q.get()

    def __iter__(self):
        return self

    def close(self) -> None:
        self._stop = True
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
