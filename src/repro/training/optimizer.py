"""AdamW with configurable accumulator dtype (pure JAX, no optax).

For 100B+ models the fp32 m/v pair alone exceeds HBM; ``state_dtype=
"bfloat16"`` halves it (MaxText-style), with the update math still done in
fp32.  Learning-rate schedule: linear warmup + cosine decay.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: str = "float32"      # "bfloat16" for 100B+ models
    max_grad_norm: float = 1.0


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decayed = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, decayed)


def adamw_init(params: Any, cfg: AdamWConfig) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adafactor_init(params: Any, cfg: AdamWConfig) -> Dict[str, Any]:
    """Factored second moment (Shazeer & Stern) + bf16 momentum: ~2.5
    bytes/param of state vs Adam-bf16's 4 — the difference between a 405B
    model fitting a pod or not."""
    dt = jnp.dtype(cfg.state_dtype)

    def vr(p):  # row stats: drop last dim
        return jnp.zeros(p.shape[:-1], jnp.float32) if p.ndim >= 2 \
            else jnp.zeros(p.shape, jnp.float32)

    def vc(p):  # col stats: drop second-to-last dim
        return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32) \
            if p.ndim >= 2 else jnp.zeros((), jnp.float32)

    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params),
        "vr": jax.tree.map(vr, params),
        "vc": jax.tree.map(vc, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(grads: Any, state: Dict[str, Any], params: Any,
                     cfg: AdamWConfig
                     ) -> Tuple[Any, Dict[str, Any], Dict[str, Any]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.max_grad_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b2 = cfg.b2
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, vr, vc):
        g32 = g.astype(jnp.float32) * clip
        g2 = jnp.square(g32) + 1e-30
        if p.ndim >= 2:
            new_vr = b2 * vr + (1 - b2) * g2.mean(axis=-1)
            new_vc = b2 * vc + (1 - b2) * g2.mean(axis=-2)
            denom = new_vr.mean(axis=-1, keepdims=True) \
                if new_vr.ndim >= 1 else new_vr
            vhat = (new_vr[..., None] * new_vc[..., None, :]
                    / jnp.maximum(denom[..., None], 1e-30))
        else:
            new_vr = b2 * vr + (1 - b2) * g2
            new_vc = vc
            vhat = new_vr
        u = g32 * jax.lax.rsqrt(vhat + cfg.eps)
        new_m = (cfg.b1 * m.astype(jnp.float32)
                 + (1 - cfg.b1) * u).astype(sdt)
        delta = new_m.astype(jnp.float32)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, new_m, new_vr, new_vc

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_vr = treedef.flatten_up_to(state["vr"])
    flat_vc = treedef.flatten_up_to(state["vc"])
    out = [upd(p, g, m, vr, vc) for p, g, m, vr, vc
           in zip(flat_p, flat_g, flat_m, flat_vr, flat_vc)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {"m": treedef.unflatten([o[1] for o in out]),
                 "vr": treedef.unflatten([o[2] for o in out]),
                 "vc": treedef.unflatten([o[3] for o in out]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def make_optimizer(name: str, cfg: AdamWConfig):
    """(init_fn, update_fn) by name: "adamw" | "adafactor"."""
    if name == "adafactor":
        return (lambda p: adafactor_init(p, cfg),
                lambda g, s, p: adafactor_update(g, s, p, cfg))
    return (lambda p: adamw_init(p, cfg),
            lambda g, s, p: adamw_update(g, s, p, cfg))


def adamw_update(grads: Any, state: Dict[str, Any], params: Any,
                 cfg: AdamWConfig) -> Tuple[Any, Dict[str, Any], Dict[str, Any]]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.max_grad_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        # Single fused elementwise chain per leaf: the new m/v are rounded
        # to state_dtype FIRST and delta reads the rounded values, so every
        # fp32 intermediate is single-consumer and fuses — no whole-leaf
        # fp32 temporaries (matters at 405B: 1.6 GB/leaf otherwise), and
        # donation aliases p/m/v in place.
        g32 = g.astype(jnp.float32) * clip
        new_m = (cfg.b1 * m.astype(jnp.float32)
                 + (1 - cfg.b1) * g32).astype(sdt)
        new_v = (cfg.b2 * v.astype(jnp.float32)
                 + (1 - cfg.b2) * jnp.square(g32)).astype(sdt)
        mhat = new_m.astype(jnp.float32) / b1c
        vhat = new_v.astype(jnp.float32) / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                     # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, new_m, new_v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "m": treedef.unflatten([o[1] for o in out]),
        "v": treedef.unflatten([o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
