"""First-class request context threaded through the send path.

A :class:`RequestContext` is the single carrier for everything a request
needs to flow end-to-end: the *session* identity that shard pinning keys
on, the absolute *deadline* that every nested hop tightens against, the
*depth* of the hop chain, and a *trace_id* that labels the whole tree.
The load generator creates one per request (``repro.core.loadgen``
builds it from the workload factory's session field) and ``App.send``
threads it through delivery, the handler, and every nested call — both
the carrier path and the zero-handoff inline fast path.

The plain path stays zero-overhead by construction:
:meth:`RequestContext.hop` returns ``None`` when there is neither a
parent context nor a deadline to carry, so ``send(dest, method,
payload)`` never allocates a context object.
"""

from __future__ import annotations

import itertools
import zlib
from typing import Optional, Union

__all__ = ["RequestContext", "session_key"]

SessionId = Union[str, bytes, int, None]

#: Process-wide trace ticket source (atomic under the GIL).
_trace_ticket = itertools.count(1)


def session_key(session: SessionId) -> int:
    """Deterministic non-negative integer key for a session id.

    Uses CRC32 for strings/bytes rather than the builtin ``hash`` because
    the latter is randomized per process — shard pinning must agree
    across trials, app restarts, and interpreter runs.  Integers pass
    through unchanged; ``None`` maps to 0.
    """
    if session is None:
        return 0
    if isinstance(session, int):
        return session & 0xFFFFFFFF
    if isinstance(session, str):
        session = session.encode("utf-8", "surrogatepass")
    return zlib.crc32(session) & 0xFFFFFFFF


def _min_dl(a: Optional[float], b: Optional[float]) -> Optional[float]:
    # Local copy of resilience.min_deadline to keep this module leaf-level
    # (resilience imports nothing from here, but context must stay
    # importable before anything else in repro.core).
    if a is None:
        return b
    if b is None:
        return a
    return a if a <= b else b


class RequestContext:
    """Per-request carrier: session, absolute deadline, hop depth, trace.

    Immutable by convention: interpreters never mutate a context in
    place, they derive a child via :meth:`hop` at each nested call so a
    parent's view of its own deadline/depth is never clobbered by a
    child hop running on another thread.
    """

    __slots__ = ("session", "deadline", "depth", "trace_id")

    def __init__(
        self,
        session: SessionId = None,
        deadline: Optional[float] = None,
        depth: int = 0,
        trace_id: Optional[int] = None,
    ) -> None:
        self.session = session
        self.deadline = deadline
        self.depth = depth
        self.trace_id = next(_trace_ticket) if trace_id is None else trace_id

    @classmethod
    def hop(
        cls,
        parent: Optional["RequestContext"],
        deadline: Optional[float] = None,
    ) -> Optional["RequestContext"]:
        """Context for one nested hop: inherit session/trace, tighten the
        deadline, increment depth.  Returns ``None`` when there is nothing
        to carry (no parent, no deadline) — the zero-alloc plain path."""
        if parent is None:
            if deadline is None:
                return None
            return cls(deadline=deadline, depth=1)
        return cls(
            session=parent.session,
            deadline=_min_dl(parent.deadline, deadline),
            depth=parent.depth + 1,
            trace_id=parent.trace_id,
        )

    def session_shard(self, n_shards: int) -> int:
        """Deterministic shard index for this context's session."""
        return session_key(self.session) % n_shards

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return ("RequestContext(session=%r, deadline=%r, depth=%d, "
                "trace_id=%r)" % (self.session, self.deadline, self.depth,
                                  self.trace_id))
