"""Single-carrier event-loop backend: async calls as run-queue continuations.

The fourth point in the dispatch design space.  ``thread`` pays a ``clone()``
per async call, ``thread-pool`` a queue push to a carrier pool, ``fiber`` a
fiber spawn plus a scheduler handoff.  The event loop removes the carrier
concept entirely: **one OS thread** per service executor drives every
handler, and an async call is just another continuation appended to the run
queue — no clone, no pool, no carrier handoff, no cross-scheduler placement.
This is the asyncio/libuv design point, expressed on the same effect
vocabulary as every other backend so the parity suite and benchmark matrix
cover it unchanged.

Mechanics
---------
* A **continuation** is ``(generator, reply_future, resume)``; the loop runs
  one until it parks (unresolved ``Wait``/``WaitAll``, ``Sleep``) or
  finishes.
* ``AsyncRpc``/``SpawnLocal`` push the carrier generator straight onto the
  owner-thread run queue and resume the caller immediately — the cheapest
  possible spawn path in this repo.
* Parked joins register a done-callback that re-injects the continuation
  through a mutex-protected inbox (resolutions arrive from other services'
  executor threads).
* Timed parks live on the shared :class:`repro.core.timers.TimerWheel` —
  the same wheel, with the same ordering guarantees, that
  :class:`repro.core.fiber.FiberScheduler` uses.

The trade is the classic one: zero dispatch overhead and perfect locality,
but zero intra-service parallelism — ``Compute`` effects serialize on the
loop.  The paper's wait-dominated DeathStarBench service models are exactly
the regime where that trade can win.

:class:`ShardedEventLoopExecutor` (the ``event-loop-shard`` backend) lifts
the serialization ceiling without reintroducing carriers: **N independent
loops**, each the plain single-threaded executor above, with every incoming
request hashed onto one shard (nginx worker / SO_REUSEPORT style — a real
deployment would hash the connection id).  Requests whose
:class:`~repro.core.context.RequestContext` carries a session id hash that
(stable across trials and restarts, so per-session state stays shard-local);
anonymous requests fall back to a per-executor request ticket.  A request
and all of its continuations stay pinned to their shard, keeping the event
loop's locality story, while a CPU-heavy handler only stalls 1/N-th of the
service.

Note on exclusivity: loop serialization is a *scheduling* property, not a
mutual-exclusion guarantee handlers may rely on.  With the zero-handoff
fast path (PR 4), a co-scheduled cooperative caller may run this service's
handlers inline on *its* thread, concurrently with the loop — exactly as
handlers of any service already run on multiple dispatcher threads or
schedulers under the ``thread``/``fiber`` backends with ``n_workers > 1``.
Shared ``Service.state`` must go through ``Service.lock`` on every backend;
``App(inline_budget=0)`` restores strict loop-exclusivity if an experiment
needs it.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Any, Generator, List, Optional, Tuple

from . import instrument
from .calibrate import burn
from .context import RequestContext, session_key
from .effects import (AsyncRpc, Compute, CurrentContext, Offload, Sleep,
                      SpawnLocal, Wait, WaitAll)
from .future import CompletedFuture, Future, Once
from .metrics import BackendStats
from .resilience import DeadlineExceeded
from .timers import TimerWheel

# a parked continuation resumes with ("send", value) or ("throw", exc)
Resume = Optional[Tuple[str, Any]]

# Tag for deadline entries on the timer wheel.  A parked continuation with a
# deadline arms ``(_EL_DEADLINE, claim, gen, fut, ctx)`` at its expiry;
# the loop intercepts these in ``pop_due`` (everything else on the wheel is
# an ordinary ready continuation).  The ``claim`` (a ``Once``) is shared
# with the park's resume callback, so exactly one of {resolution, expiry}
# resumes the generator — the race is settled by a ticket, not a lock.
_EL_DEADLINE = object()


class EventLoopExecutor:
    """Single-threaded cooperative executor (duck-typed ``Executor``).

    ``n_workers`` is accepted for registry-signature parity and ignored: a
    second loop thread would reintroduce the carrier-placement problem this
    backend exists to delete.
    """

    # accepts zero-handoff inline execution of its handlers on a
    # co-scheduled cooperative caller (see Service.inline_handler)
    cooperative = True

    def __init__(self, app: Any, name: str, n_workers: int = 1) -> None:
        self.app = app
        self.name = name
        self._cond = threading.Condition()
        self._inbox: deque = deque()   # cross-thread injections (locked)
        self._run: deque = deque()     # owner-thread-only run queue
        self._timers = TimerWheel()    # owner-thread-only
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        # ambient RequestContext of the continuation the loop is currently
        # driving (owner thread only; saved/restored around inline drives)
        self._cur_ctx: Optional[RequestContext] = None
        # --- instrumentation (see metrics.BackendStats) ------------------
        self.spawns = 0            # async-call continuations created
        self.switches = 0          # continuations resumed by the loop
        self.queue_depth_hwm = 0   # run queue + inbox high-water
        # --- zero-handoff fast path (owner/loop thread only) -------------
        self._inline_depth = 0
        self.inline_calls = 0
        self.inline_depth_hwm = 0
        self.fast_futures = 0
        self.slow_futures = 0

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        """Start the loop thread."""
        self._stop = False
        self._thread = threading.Thread(target=self._loop,
                                        name=f"{self.name}-loop", daemon=True)
        self._thread.start()
        h = instrument.hooks
        if h is not None:
            h.carrier_start(self, f"{self.name}-loop")

    def stop(self) -> None:
        """Signal the loop thread to exit and join it (bounded)."""
        with self._cond:
            self._stop = True
            self._cond.notify()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        h = instrument.hooks
        if h is not None:
            h.carrier_stop(self)

    def deliver(self, gen: Generator, reply: Future,
                ctx: Optional[RequestContext] = None) -> None:
        """Inject the request as a continuation on the loop's inbox."""
        h = instrument.hooks
        if h is not None:
            h.loop_spawn(self, reply)
        self._inject(gen, reply, None, ctx)

    # ------------------------------------------------------------ injection
    def _inject(self, gen: Generator, fut: Future, resume: Resume,
                ctx: Optional[RequestContext] = None) -> None:
        h = instrument.hooks
        if h is not None:
            h.queue_put(self)
        with self._cond:
            self._inbox.append((gen, fut, resume, ctx))
            depth = len(self._inbox) + len(self._run)
            if depth > self.queue_depth_hwm:
                self.queue_depth_hwm = depth
            self._cond.notify()

    def _push_local(self, gen: Generator, fut: Future,
                    ctx: Optional[RequestContext] = None) -> None:
        """Owner thread only: no lock, no wakeup — the loop is already awake."""
        h = instrument.hooks
        if h is not None:
            h.loop_spawn(self, fut)
        self._run.append((gen, fut, None, ctx))
        depth = len(self._run) + len(self._inbox)
        if depth > self.queue_depth_hwm:
            self.queue_depth_hwm = depth

    # ------------------------------------------------------------ main loop
    def _loop(self) -> None:
        h = instrument.hooks
        if h is not None:
            h.sched_loop(self)
        while True:
            with self._cond:
                drained = bool(self._inbox)
                while self._inbox:
                    self._run.append(self._inbox.popleft())
                if not self._run:
                    if self._stop:
                        return
                    timeout = self._timers.seconds_until_next(time.monotonic())
                    if timeout is None or timeout > 0:
                        self._cond.wait(timeout=timeout)
                    drained = drained or bool(self._inbox)
                    while self._inbox:
                        self._run.append(self._inbox.popleft())
            if drained:
                h = instrument.hooks
                if h is not None:
                    h.queue_take(self)
            for cont in self._timers.pop_due(time.monotonic()):
                if cont and cont[0] is _EL_DEADLINE:
                    _, claim, gen, fut, ctx = cont
                    if claim.claim():  # expiry beat the resolution callback
                        self._count_timeout()
                        self._run.append(
                            (gen, fut,
                             ("throw", DeadlineExceeded(
                                 "deadline expired while parked")),
                             ctx))
                    continue  # claim lost: the resolution already resumed it
                self._run.append(cont)
            if self._run:
                gen, fut, resume, ctx = self._run.popleft()
                self.switches += 1
                self._step(gen, fut, resume, ctx)

    def _count_timeout(self) -> None:
        app = getattr(self, "app", None)
        if app is not None:
            app._res_stats.timeout()

    # ---------------------------------------------------- continuation step
    def _step(self, gen: Generator, fut: Future, resume: Resume,
              ctx: Optional[RequestContext] = None) -> None:
        """Drive one continuation until it parks or finishes."""
        self._cur_ctx = ctx
        deadline = ctx.deadline if ctx is not None else None
        send_value: Any = None
        throw_exc: Optional[BaseException] = None
        if resume is not None:
            kind, payload = resume
            if kind == "throw":
                throw_exc = payload
            else:
                send_value = payload
        if (deadline is not None and throw_exc is None
                and time.monotonic() >= deadline):
            # dequeue check: the continuation sat in the run queue past its
            # deadline — fail it now instead of burning the loop on dead work
            self._count_timeout()
            throw_exc = DeadlineExceeded("deadline expired in run queue")
        while True:
            try:
                if throw_exc is not None:
                    exc, throw_exc = throw_exc, None
                    eff = gen.throw(exc)
                else:
                    eff = gen.send(send_value)
            except StopIteration as stop:
                fut.set_result(stop.value)
                self._classify(fut)
                return
            except BaseException as exc:
                fut.set_exception(exc)
                self._classify(fut)
                return

            if isinstance(eff, (Wait, WaitAll)):
                waits = ([eff.future] if isinstance(eff, Wait)
                         else list(eff.futures))
                if all(w.done for w in waits):
                    try:
                        send_value = (waits[0].result()
                                      if isinstance(eff, Wait)
                                      else [w.result() for w in waits])
                        throw_exc = None
                    except BaseException as exc:
                        send_value, throw_exc = None, exc
                    continue
                self._park(gen, fut, eff, waits, ctx)
                return

            if isinstance(eff, Sleep):
                self._sleep(gen, fut, eff.seconds, ctx)
                return

            try:
                send_value = self._interpret(eff)
                throw_exc = None
            except BaseException as exc:
                throw_exc = exc

    def _sleep(self, gen: Generator, fut: Future, seconds: float,
               ctx: Optional[RequestContext]) -> None:
        """Timer-park a sleeping continuation, truncated at its deadline."""
        h = instrument.hooks
        if h is not None:
            h.loop_spawn(self, fut)
        deadline = ctx.deadline if ctx is not None else None
        wake = time.monotonic() + max(seconds, 0.0)
        if deadline is not None and deadline <= wake:
            # the sleep outlives the deadline: wake at the deadline with the
            # expiry instead of completing a doomed sleep first
            self._timers.push(deadline,
                              (_EL_DEADLINE, Once(), gen, fut, ctx))
            return
        self._timers.push(wake, (gen, fut, ("send", None), ctx))

    def _classify(self, fut: Future) -> None:
        """fast = resolved without a kernel Condition ever materializing."""
        if fut.blocking_waited():
            self.slow_futures += 1
        else:
            self.fast_futures += 1

    def _interpret(self, eff: Any) -> Any:
        if isinstance(eff, AsyncRpc):
            hop = RequestContext.hop(self._cur_ctx, eff.deadline)
            dl = hop.deadline if hop is not None else None
            if dl is not None and time.monotonic() >= dl:
                # hop check at submission: dead calls never enter the queue
                self._count_timeout()
                raise DeadlineExceeded(
                    f"rpc {eff.dest}.{eff.method}: deadline expired")
            app = self.app
            if app is not None and app.net_latency == 0 \
                    and app.inline_budget > 0:
                # zero-handoff fast path: inline the cooperative callee,
                # else elide the carrier (the reply future IS the result —
                # see FiberScheduler._interpret for the two tiers).
                # Breaker/retry/bulkhead policies inline with per-edge
                # accounting; only a mailbox bound skips the inline tier.
                fut = (self._try_inline(eff, app, hop)
                       if app._inline_rpc_ok else None)
                if fut is not None:
                    return fut
                return app.send(eff.dest, eff.method, eff.payload, ctx=hop)
            fut = Future()
            self.spawns += 1
            self._push_local(
                self.app.rpc_carrier(eff.dest, eff.method, eff.payload, hop),
                fut, hop)
            return fut

        if isinstance(eff, Compute):
            burn(eff.seconds)  # serializes on the loop — the backend's trade
            return None

        if isinstance(eff, Offload):
            return self.app.offload(eff.fn, *eff.args)

        if isinstance(eff, SpawnLocal):
            fut = Future()
            self.spawns += 1
            self._push_local(eff.genfn(*eff.args), fut, self._cur_ctx)
            return fut

        if isinstance(eff, CurrentContext):
            return self._cur_ctx

        raise TypeError(f"Unknown effect: {eff!r}")

    # ------------------------------------------------ zero-handoff fast path
    def _try_inline(self, eff: Any, app: Any,
                    ctx: Optional[RequestContext] = None) -> Optional[Future]:
        """Same-carrier call inlining on the loop thread; see
        FiberScheduler._try_inline for the contract.  Policy admission and
        outcome recording live in ``App._inline_call``; the loop gates only
        its own depth budget."""
        if self._inline_depth >= app.inline_budget:
            return None
        return app._inline_call(eff.dest, eff.method, eff.payload, ctx,
                                self._inline_drive)

    def _inline_drive(self, gen: Generator,
                      ctx: Optional[RequestContext]) -> Future:
        """Loop-side bookkeeping around :meth:`_drive_inline` (mirror of
        ``FiberScheduler._inline_drive``): inline counters plus the
        ``_cur_ctx`` save/restore so the callee's nested hops tighten
        against the inline call's effective context."""
        self.inline_calls += 1
        self._inline_depth += 1
        if self._inline_depth > self.inline_depth_hwm:
            self.inline_depth_hwm = self._inline_depth
        prev_ctx = self._cur_ctx
        self._cur_ctx = ctx
        try:
            return self._drive_inline(gen, ctx)
        finally:
            self._cur_ctx = prev_ctx
            self._inline_depth -= 1

    def _drive_inline(self, gen: Generator,
                      ctx: Optional[RequestContext] = None) -> Future:
        """Run an inlined callee up to its first suspension point: a
        CompletedFuture when it never suspends, else the remainder parks as
        an ordinary continuation of this loop."""
        send_value: Any = None
        throw_exc: Optional[BaseException] = None
        while True:
            try:
                if throw_exc is not None:
                    exc, throw_exc = throw_exc, None
                    eff = gen.throw(exc)
                else:
                    eff = gen.send(send_value)
            except StopIteration as stop:
                self.fast_futures += 1
                return CompletedFuture(stop.value)
            except BaseException as exc:
                self.fast_futures += 1
                return CompletedFuture(exc=exc)

            if isinstance(eff, (Wait, WaitAll)):
                waits = ([eff.future] if isinstance(eff, Wait)
                         else list(eff.futures))
                if all(w.done for w in waits):
                    try:
                        send_value = (waits[0].result()
                                      if isinstance(eff, Wait)
                                      else [w.result() for w in waits])
                        throw_exc = None
                    except BaseException as exc:
                        send_value, throw_exc = None, exc
                    continue
                fut = Future()
                self.spawns += 1  # the remainder becomes a continuation,
                self._park(gen, fut, eff, waits, ctx)  # fiber-fallback
                return fut

            if isinstance(eff, Sleep):
                fut = Future()
                self.spawns += 1
                self._sleep(gen, fut, eff.seconds, ctx)
                return fut

            try:
                send_value = self._interpret(eff)
                throw_exc = None
            except BaseException as exc:
                throw_exc = exc

    # -------------------------------------------------------------- parking
    def _park(self, gen: Generator, fut: Future, eff: Any,
              waits: List[Future],
              ctx: Optional[RequestContext] = None) -> None:
        deadline = ctx.deadline if ctx is not None else None
        h = instrument.hooks
        if h is not None:
            h.loop_spawn(self, fut)
            for w in waits:
                h.future_join(w)
        claim: Optional[Once] = None
        if deadline is not None:
            # arm the expiry on the loop's own wheel (we ARE the owner
            # thread here); the claim decides resolution-vs-expiry
            claim = Once()
            self._timers.push(deadline,
                              (_EL_DEADLINE, claim, gen, fut, ctx))

        if isinstance(eff, Wait):
            def _resume_one(w: Future) -> None:
                if claim is not None and not claim.claim():
                    return  # the deadline fired first; expiry resumed it
                try:
                    resume: Tuple[str, Any] = ("send", w.result())
                except BaseException as exc:
                    resume = ("throw", exc)
                self._inject(gen, fut, resume, ctx)
            waits[0].add_done_callback(_resume_one)
            return

        remaining = [len(waits)]
        rlock = threading.Lock()

        def _resume_all(_w: Future) -> None:
            with rlock:
                remaining[0] -= 1
                if remaining[0]:
                    return
            if claim is not None and not claim.claim():
                return  # the deadline fired first; expiry resumed it
            try:
                resume: Tuple[str, Any] = ("send",
                                           [w.result() for w in waits])
            except BaseException as exc:
                resume = ("throw", exc)
            self._inject(gen, fut, resume, ctx)

        for w in waits:
            w.add_done_callback(_resume_all)

    # ---------------------------------------------------------------- stats
    def stats(self) -> BackendStats:
        """Snapshot this loop's counters."""
        return BackendStats(spawns=self.spawns, switches=self.switches,
                            queue_depth_hwm=self.queue_depth_hwm,
                            inline_calls=self.inline_calls,
                            inline_depth_hwm=self.inline_depth_hwm,
                            fast_futures=self.fast_futures,
                            slow_futures=self.slow_futures)


class ShardedEventLoopExecutor:
    """N independent event loops, requests hashed to a shard by session —
    or by request ticket when anonymous (duck-typed ``Executor``; the
    ``event-loop-shard`` backend).

    ``n_workers`` is the shard count.  Each shard is a full
    :class:`EventLoopExecutor` — own thread, run queue, inbox, timer wheel —
    so a shard never synchronizes with its siblings; the only shared state
    is the placement ticket.  Placement prefers the request's
    :class:`~repro.core.context.RequestContext` session: requests carrying
    ``ctx.session`` hash its stable :func:`~repro.core.context.session_key`
    onto a shard, so the same session always lands on the same shard — per
    trial, per run, and across ``App.start()`` restarts — which is what
    makes per-session service state shard-local.  Sessionless requests fall
    back to a deterministic multiplicative hash of the per-executor request
    ticket (the stand-in for a connection id, see the module docstring):
    the same delivery sequence always lands on the same shards, which is
    what keeps the parity suite exact, and Fibonacci hashing spreads the
    sequential ticket stream evenly instead of striping it.  Set
    ``app.shard_by_session = False`` to force ticket placement even for
    sessioned traffic (the A/B lever the benchmarks flip).

    Continuations spawned by a handler (``AsyncRpc`` fallbacks,
    ``SpawnLocal``) stay on the shard that runs it — sharding decides
    placement once, at delivery, exactly like hashing a connection to an
    nginx/libuv worker.
    """

    cooperative = True  # shard handlers may inline on a cooperative caller

    # Knuth's multiplicative constant (2^32 / phi): consecutive request ids
    # scatter across shards without the modulo-striping a bare `id % n`
    # would give when n divides the arrival pattern.
    _HASH_MULT = 2654435761

    def __init__(self, app: Any, name: str, n_workers: int = 2) -> None:
        self.app = app
        self.name = name
        self.n_shards = max(int(n_workers), 1)
        self._shards = [EventLoopExecutor(app, f"{name}-shard{i}")
                        for i in range(self.n_shards)]
        self._ticket = itertools.count()  # atomic under the GIL

    @classmethod
    def shard_for(cls, request_id: int, n_shards: int) -> int:
        """Deterministic request-id -> shard placement (pure function, so
        tests can pin it and a trace can be replayed)."""
        return ((request_id * cls._HASH_MULT) & 0xFFFFFFFF) % n_shards

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        """Start every shard loop.  The placement ticket resets so the
        Nth anonymous delivery after a restart lands on the same shard as
        the Nth before it — placement is a function of delivery order, not
        executor lifetime."""
        self._ticket = itertools.count()
        for s in self._shards:
            s.start()

    def stop(self) -> None:
        """Stop every shard loop."""
        for s in self._shards:
            s.stop()

    def deliver(self, gen: Generator, reply: Future,
                ctx: Optional[RequestContext] = None) -> None:
        """Hash the request onto its shard (pinned for life): by session
        key when the context carries one (and the app hasn't opted out via
        ``shard_by_session = False``), else by request ticket."""
        if (ctx is not None and ctx.session is not None
                and getattr(self.app, "shard_by_session", True)):
            shard = self.shard_for(session_key(ctx.session), self.n_shards)
        else:
            shard = self.shard_for(next(self._ticket), self.n_shards)
        h = instrument.hooks
        if h is not None:
            h.shard_handoff(self, shard)
        if ctx is None:  # common path keeps the pre-context signature
            self._shards[shard].deliver(gen, reply)
        else:
            self._shards[shard].deliver(gen, reply, ctx)

    # ---------------------------------------------------------------- stats
    @property
    def spawns(self) -> int:
        """Spawns across shards (always 0: loops spawn no carriers)."""
        return sum(s.spawns for s in self._shards)

    def stats(self) -> BackendStats:
        """Aggregate counters across shards (+ the shard-width gauge)."""
        agg = BackendStats()
        for s in self._shards:
            agg.add(s.stats())
        agg.shards = self.n_shards  # gauge: shard width of this executor
        return agg
