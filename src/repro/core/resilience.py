"""Overload-survival policy objects: deadlines, retries, breakers, bounds.

The paper's peak-throughput numbers say nothing about the regime production
actually lives in — *past* peak, where microservice graphs amplify queueing
hop-by-hop and every unshed request makes the backlog worse.  This module
holds the policy half of the resilience layer:

* :class:`DeadlineExceeded` / :class:`CircuitOpenError` / :class:`Rejected`
  — the three fail-fast reply exceptions the transport can resolve a reply
  :class:`Future` with instead of queueing work it cannot finish in time.
* :class:`RetryPolicy` — jittered exponential backoff, capped attempts.
* :class:`RetryBudget` — a token bucket refilled by *successes*, so retries
  can never amplify offered load unboundedly (the classic 10%-retry-budget
  discipline: a dead downstream earns no tokens, so retries dry up).
* :class:`CircuitBreaker` — closed -> open -> half-open on error/timeout
  rate over a rolling window; fail-fast while open.
* :class:`Bulkhead` — per-edge in-flight *attempt* cap (caller-side
  admission), distinct from the service-side ``mailbox_bound``.
* :class:`ResiliencePolicy` — the bundle an :class:`~repro.core.App` is
  built with; ``None`` keeps the pre-resilience fast path bit-for-bit.

Which layer enforces what
-------------------------
This module is *policy only* — pure state machines, no scheduling.  The
enforcement points live one layer up:

* **Deadlines** are checked by the executors at discrete events, never by
  polling: ``App.send`` / ``Service.deliver`` at admission, the
  interpreters (``FiberScheduler._interpret`` /
  ``EventLoopExecutor._interpret``) at every ``AsyncRpc`` hop, and parked
  waits arm the expiry on the cooperative backends' timer wheel
  (``repro.core.timers.TimerWheel``) or the thread family's kernel-timed
  waits.  Docs: ``docs/RESILIENCE.md``.
* **Breakers, retries and bulkheads** are driven by
  ``App._send_resilient`` / ``App._drive_attempts`` on the carrier path
  and by ``App._inline_resilient`` on the zero-handoff inline fast path —
  both feed the *same* per-destination :class:`CircuitBreaker` window and
  the same app-wide :class:`RetryBudget`, so inlining a call never changes
  a breaker decision (the PR 7 breaker-aware-inlining contract, proven by
  ``tests/test_inline_resilience.py``).
* **Mailbox bounds** are enforced by ``Service.deliver`` at admission on
  the destination's own queue; the inline fast path steps aside entirely
  when a policy carries one (an inlined call never occupies the mailbox
  the bound is leveling).

This module is deliberately stdlib-only.
"""
from __future__ import annotations

import itertools
import random
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Optional


# Backoff-jitter RNG.  A dedicated seeded instance, not the module-global
# ``random`` functions: repro.core is deterministic-by-construction (lint
# rule A102), and jitter drawn from an unseeded global would make retry
# schedules — and therefore breaker windows — unreproducible across runs.
# One shared instance is fine: jitter needs decorrelation, not statistical
# independence, and draws are a single C-level call under the GIL.
_JITTER_RNG = random.Random(0x5EED)


class DeadlineExceeded(RuntimeError):
    """The request's deadline expired before a reply was produced."""


class CircuitOpenError(RuntimeError):
    """Fail-fast reply: the destination's circuit breaker is open."""


class Rejected(RuntimeError):
    """Fail-fast reply: the destination's bounded mailbox is full."""


def min_deadline(a: Optional[float], b: Optional[float]) -> Optional[float]:
    """Tighter of two absolute (``time.monotonic``) deadlines; None = none."""
    if a is None:
        return b
    if b is None:
        return a
    return a if a <= b else b


@dataclass(frozen=True)
class RetryPolicy:
    """Jittered exponential backoff.  ``max_attempts`` counts the first try."""

    max_attempts: int = 3
    base_backoff: float = 0.002     # s, delay after the first failure
    max_backoff: float = 0.050      # s, exponential growth cap
    jitter: float = 0.5             # +/- fraction of the computed delay
    budget_initial: float = 8.0     # retry tokens available before any success
    budget_ratio: float = 0.1       # tokens earned per successful reply
    budget_cap: float = 64.0        # token bucket ceiling

    def backoff_for(self, attempt: int) -> float:
        """Delay before attempt ``attempt + 1`` (``attempt`` >= 1 failed)."""
        raw = min(self.max_backoff, self.base_backoff * (2 ** (attempt - 1)))
        lo = 1.0 - self.jitter
        return raw * (lo + 2.0 * self.jitter * _JITTER_RNG.random())


class RetryBudget:
    """Token bucket: every retry spends one token; every success earns
    ``ratio``.  Under a hard outage nothing succeeds, the bucket drains, and
    the retry storm self-extinguishes — offered load cannot be amplified by
    more than ``initial + ratio * successes`` extra requests."""

    def __init__(self, policy: RetryPolicy) -> None:
        self._lock = threading.Lock()
        self._tokens = float(policy.budget_initial)
        self._ratio = policy.budget_ratio
        self._cap = policy.budget_cap

    @property
    def tokens(self) -> float:
        """Current token balance (racy read, for tests/telemetry)."""
        return self._tokens

    def credit(self) -> None:
        """Earn ``ratio`` tokens for one successful reply (capped)."""
        with self._lock:
            self._tokens = min(self._cap, self._tokens + self._ratio)

    def try_spend(self) -> bool:
        """Spend one token for a retry; False when the bucket is dry."""
        with self._lock:
            if self._tokens >= 1.0:
                self._tokens -= 1.0
                return True
            return False


class CircuitBreaker:
    """Per-edge closed -> open -> half-open state machine.

    Outcomes are recorded into a rolling window of the last ``window``
    replies; once at least ``min_volume`` samples are present and the
    failure ratio reaches ``threshold`` the breaker opens (fail fast).
    After ``reset_timeout`` seconds it admits exactly one half-open probe;
    the probe's outcome closes or re-opens it.  ``clock`` is injectable so
    unit tests can drive transitions without sleeping.
    """

    def __init__(self, *, threshold: float = 0.5, window: int = 32,
                 min_volume: int = 8, reset_timeout: float = 0.25,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self.threshold = threshold
        self.window = window
        self.min_volume = min_volume
        self.reset_timeout = reset_timeout
        self._clock = clock
        self._lock = threading.Lock()
        self._samples: deque = deque(maxlen=window)  # True = ok
        self._state = "closed"
        self._opened_at = 0.0
        self._probing = False
        self.opens = 0  # monotonic open-transition count (-> breaker_opens)

    @property
    def state(self) -> str:
        """One of ``"closed"`` / ``"open"`` / ``"half-open"``."""
        return self._state

    def allow(self) -> bool:
        """May a call be attempted on this edge right now?"""
        with self._lock:
            if self._state == "closed":
                return True
            if self._state == "open":
                if self._clock() - self._opened_at < self.reset_timeout:
                    return False
                self._state = "half-open"
                self._probing = True
                return True
            # half-open: one probe in flight at a time
            if self._probing:
                return False
            self._probing = True
            return True

    def record(self, ok: bool) -> None:
        """Record a reply outcome for a call previously admitted."""
        with self._lock:
            if self._state == "half-open":
                self._probing = False
                if ok:
                    self._state = "closed"
                    self._samples.clear()
                else:
                    self._trip()
                return
            if self._state == "open":
                return  # stale outcome from before the trip
            self._samples.append(ok)
            if len(self._samples) < self.min_volume:
                return
            failures = self._samples.count(False)
            if failures / len(self._samples) >= self.threshold:
                self._trip()

    def abort_probe(self) -> None:
        """Release the half-open probe slot without recording an outcome.

        For probes that failed fast on a *downstream* open circuit: the
        admitted call never exercised this edge, so it is evidence of
        neither health nor sickness.  Without this release the breaker
        would sit in half-open forever — probe slot consumed, every other
        call failing fast, and (since no traffic flows) the downstream
        breaker never getting the probe *it* needs to close: a whole-graph
        recovery deadlock.  No-op in closed/open states."""
        with self._lock:
            if self._state == "half-open":
                self._probing = False

    def _trip(self) -> None:
        # caller holds self._lock
        self._state = "open"
        self._opened_at = self._clock()
        self._probing = False
        self.opens += 1
        self._samples.clear()


class Bulkhead:
    """Per-edge in-flight concurrency cap (caller-side admission).

    One bulkhead guards one ``App.send`` destination: every *attempt* —
    first try, retry, or zero-handoff inlined call — must acquire a slot
    before it runs and releases it when its reply future resolves.  An
    attempt that finds the bulkhead full is rejected immediately
    (:class:`Rejected`), without exercising the edge, so a slow or wedged
    destination can pin at most ``limit`` of the caller's concurrency
    instead of dragging the whole app down — the ship-compartment
    isolation pattern.

    Distinct from ``ResiliencePolicy.mailbox_bound``: the mailbox bound is
    enforced by the *destination service* on its admitted queue depth (an
    inlined call never enters that queue), while the bulkhead is enforced
    by the *caller* on every attempt, inlined ones included, which is why
    the zero-handoff fast path can keep running under a bulkhead policy.
    """

    __slots__ = ("limit", "_lock", "_inflight")

    def __init__(self, limit: int) -> None:
        self.limit = int(limit)
        self._lock = threading.Lock()
        self._inflight = 0

    @property
    def inflight(self) -> int:
        """Attempts currently holding a slot (racy read, for tests)."""
        return self._inflight

    def try_acquire(self) -> bool:
        """Claim one slot; False when all ``limit`` slots are in flight."""
        with self._lock:
            if self._inflight < self.limit:
                self._inflight += 1
                return True
            return False

    def release(self, _fut: object = None) -> None:
        """Return a slot.  Accepts (and ignores) a future argument so it
        can be registered directly as a reply's done-callback."""
        with self._lock:
            self._inflight -= 1


@dataclass(frozen=True)
class ResiliencePolicy:
    """Everything an :class:`App` needs to survive past peak.

    ``deadline`` is the default per-request budget (seconds) stamped onto
    root sends that did not pass one explicitly; propagation downstream is
    automatic.  ``retry`` enables budgeted retry-with-backoff on every
    ``App.send`` edge.  ``breakers`` enables one :class:`CircuitBreaker`
    per destination service.  ``bulkhead`` caps per-destination in-flight
    attempts on the *caller* side (one :class:`Bulkhead` per destination;
    inlined calls count).  ``mailbox_bound`` caps per-service admitted
    in-flight requests on the *destination* side; excess arrivals are
    rejected immediately (queue-based load leveling) instead of building
    unbounded backlog.
    """

    deadline: Optional[float] = 0.05
    retry: Optional[RetryPolicy] = None
    breakers: bool = True
    breaker_threshold: float = 0.5
    breaker_window: int = 32
    breaker_min_volume: int = 8
    breaker_reset: float = 0.25
    bulkhead: Optional[int] = None
    mailbox_bound: Optional[int] = None

    def make_breaker(self,
                     clock: Callable[[], float] = time.monotonic
                     ) -> CircuitBreaker:
        """Build one per-edge :class:`CircuitBreaker` from the policy knobs."""
        return CircuitBreaker(threshold=self.breaker_threshold,
                              window=self.breaker_window,
                              min_volume=self.breaker_min_volume,
                              reset_timeout=self.breaker_reset,
                              clock=clock)


class ResilienceStats:
    """Lock-free app-wide resilience counters.

    Same idiom as ``Service._req_ticket``: each event consumes one ticket
    from an atomic ``itertools.count`` (a single C-level operation under
    the GIL — no lost updates across executor threads), and reads parse
    the next value back out of the counter's repr.
    """

    __slots__ = ("_timeouts", "_retries", "_rejections",
                 "_bulkhead_rejections")

    def __init__(self) -> None:
        self._timeouts = itertools.count(1)
        self._retries = itertools.count(1)
        self._rejections = itertools.count(1)
        self._bulkhead_rejections = itertools.count(1)

    @staticmethod
    def _read(counter: "itertools.count") -> int:
        r = repr(counter)                    # e.g. "count(42)"
        return int(r[r.index("(") + 1:-1]) - 1

    def timeout(self) -> None:
        """Count one deadline expiry."""
        next(self._timeouts)

    def retry(self) -> None:
        """Count one scheduled retry attempt."""
        next(self._retries)

    def rejection(self) -> None:
        """Count one bounded-mailbox rejection."""
        next(self._rejections)

    def bulkhead_rejection(self) -> None:
        """Count one caller-side bulkhead rejection."""
        next(self._bulkhead_rejections)

    @property
    def timeouts(self) -> int:
        """Deadline expiries so far."""
        return self._read(self._timeouts)

    @property
    def retries(self) -> int:
        """Retry attempts scheduled so far."""
        return self._read(self._retries)

    @property
    def rejections(self) -> int:
        """Bounded-mailbox rejections so far."""
        return self._read(self._rejections)

    @property
    def bulkhead_rejections(self) -> int:
        """Caller-side bulkhead rejections so far."""
        return self._read(self._bulkhead_rejections)
