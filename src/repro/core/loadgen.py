"""Open-loop Poisson load generator + peak-throughput search + overload mode.

Mirrors the paper's evaluation protocol:

* *peak throughput*: "increase the request rate ... until the number of
  processed requests per second does not increase anymore" — implemented as a
  geometric ramp; the peak is the best achieved rate across the ramp;
* *tail latency vs rate*: fixed-rate open-loop trials reporting p99;
* *overload* (:func:`run_overload`): drive a fixed multiple of the measured
  peak, score **goodput** (completions within the per-request deadline / s),
  then probe at a sustainable rate until goodput recovers — the
  time-to-recover after the overload window.

Arrivals are generated open-loop (Poisson, seeded) so queueing delay shows up
as latency rather than throttling the generator — the regime where the thread
backend's spawn cost collapses, per the paper.

Trial isolation
---------------
A trial that ends with in-flight requests (the drain window timed out) used
to leak them into its successor: their done-callbacks fired mid-next-trial,
decrementing a stale ``outstanding`` counter, polluting the next trial's
``BackendStats`` delta, and racing the summary read.  :func:`run_trial` now
*severs* each trial: every callback checks a per-trial liveness flag under
the trial lock before touching any counter, leftovers are counted as
``abandoned`` and parked on ``app._loadgen_leftovers``, and the next trial
waits (bounded by ``settle``) for them to finish before snapshotting
``stats_before``.  The latency summary is computed only after the sever, so
it reads a frozen recorder instead of racing late completions.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple

import numpy as np

from . import instrument
from .context import RequestContext
from .metrics import BackendStats, LatencyRecorder, PeakResult, TrialResult
from .service import App

# Per-arrival request chooser, called with the trial RNG.  Returns
# ``(dest, method, payload)`` or — for session-affine workloads —
# ``(dest, method, payload, session)``; a non-None 4th element becomes
# ``RequestContext.session`` on the send, which is what session-affine
# executors (``event-loop-shard``) use for placement.
RequestFactory = Callable[[np.random.Generator], Tuple[str, str, Any]]


def _settle(app: App, budget: float) -> None:
    """Wait (bounded) for the previous trial's abandoned requests to finish
    so their executor-side completions don't pollute this trial's
    ``BackendStats`` delta."""
    leftovers = getattr(app, "_loadgen_leftovers", None)
    if not leftovers:
        return
    end = time.monotonic() + max(budget, 0.0)
    for f in leftovers:
        rem = end - time.monotonic()
        if rem <= 0:
            break
        f.wait_done(timeout=rem)
    app._loadgen_leftovers = []


def run_trial(app: App, make_request: RequestFactory, rate: float,
              duration: float, *, seed: int = 0, max_outstanding: int = 4096,
              drain: float = 2.0, deadline: Optional[float] = None,
              enforce_deadline: bool = False,
              settle: float = 1.0,
              arm_faults: Optional[bool] = None) -> TrialResult:
    """Offer ``rate`` req/s for ``duration`` seconds; measure completions.

    ``deadline`` (seconds, relative) classifies completions as *good* when
    they finish within it; with ``enforce_deadline=True`` it is also stamped
    onto every send (as ``RequestContext.deadline``), so the app's
    resilience layer fails slow requests instead of letting them queue
    forever.  When ``make_request`` returns a 4-tuple, the 4th element is
    the request's session id: the trial mints a :class:`RequestContext`
    carrying it, which session-affine executors use for shard placement and
    handlers can read back via the ``CurrentContext`` effect.

    ``arm_faults`` controls the app's installed
    :class:`~repro.core.faults.FaultPlan` (no-op when none is installed):
    ``None`` (default) arms it at trial start only if it is not armed yet,
    so rule windows read as seconds into the *first* trial and later probe
    trials (recovery sweeps) run on the same schedule clock; ``True``
    re-arms at every trial start ("the fault schedule replays each trial"
    — what a paired A/B probe wants); ``False`` never touches it.

    Sever-point / leftovers contract (the trial-isolation guarantee):

    * After the offered window, in-flight requests get a bounded ``drain``
      window to finish.  When it closes, the trial is **severed** under the
      trial lock: the liveness flag flips, and from that instant no late
      completion can touch this trial's recorder, counters, or the
      ``BackendStats`` delta — the summary below reads frozen state.
    * Requests still in flight at the sever are reported as ``abandoned``
      (never silently dropped) and parked on ``app._loadgen_leftovers``.
    * The *next* trial on the same app settles on those leftovers first
      (:func:`_settle`, bounded by ``settle`` seconds) before snapshotting
      ``stats_before``, so one trial's stragglers can neither pollute its
      successor's counter delta nor decrement a stale outstanding window.
    """
    rng = np.random.default_rng(seed)
    rec = LatencyRecorder()
    outstanding = [0]
    shed = [0]
    offered = [0]
    good = [0]
    live = [True]  # trial epoch: severed before the summary is read
    inflight: set = set()
    lock = threading.Lock()
    _settle(app, settle)
    stats_before = app.backend_stats()
    plan = getattr(app, "fault_plan", None)
    if plan is not None and arm_faults is not False:
        if arm_faults or not plan.armed:
            plan.arm()  # fault-rule windows start on this trial's clock

    t_start = time.perf_counter()
    t_end = t_start + duration
    next_arrival = t_start + float(rng.exponential(1.0 / rate))

    while True:
        now = time.perf_counter()
        if now >= t_end:
            break
        # fire every arrival that is due (catch-up batching keeps the
        # generator open-loop even when pacing sleep overshoots)
        while next_arrival <= now:
            next_arrival += float(rng.exponential(1.0 / rate))
            offered[0] += 1
            with lock:
                if outstanding[0] >= max_outstanding:
                    shed[0] += 1
                    continue
                outstanding[0] += 1
            req = make_request(rng)
            dest, method, payload = req[0], req[1], req[2]
            session = req[3] if len(req) > 3 else None
            t0 = time.perf_counter()

            def _done(fut: Any, t0: float = t0) -> None:
                # the WHOLE body runs under the trial lock: the liveness
                # check, the counter updates, and the recorder write are one
                # atomic unit, so severing the trial (live[0] = False, same
                # lock) guarantees no late callback mutates anything the
                # summary reads
                with lock:
                    if not live[0]:
                        return  # late completion of an abandoned request
                    outstanding[0] -= 1
                    inflight.discard(fut)
                    try:
                        fut.result()
                    except BaseException:
                        rec.record_error()
                        return
                    dt = time.perf_counter() - t0
                    rec.record(dt)
                    if deadline is None or dt <= deadline:
                        good[0] += 1

            dl = (time.monotonic() + deadline
                  if enforce_deadline and deadline is not None else None)
            # the load generator is where a request's RequestContext is
            # born; plain sessionless/deadline-less sends stay ctx=None so
            # the zero-overhead path never allocates a carrier
            ctx = (RequestContext(session=session, deadline=dl)
                   if session is not None or dl is not None else None)
            fut = app.send(dest, method, payload, ctx=ctx)
            with lock:
                if not fut.done:
                    inflight.add(fut)
            fut.add_done_callback(_done)
        pause = min(next_arrival - time.perf_counter(), 0.001)
        if pause > 0:
            time.sleep(pause)

    # drain: give in-flight requests a bounded window to finish
    drain_end = time.perf_counter() + drain
    while time.perf_counter() < drain_end:
        with lock:
            if outstanding[0] == 0:
                break
        time.sleep(0.005)

    # sever the trial: late completions must not touch this trial's
    # recorder/counters (bugfix: they used to decrement a stale counter and
    # pollute the NEXT trial's BackendStats delta)
    with lock:
        live[0] = False
        abandoned = outstanding[0]
        leftovers = list(inflight)
        inflight.clear()
    h = instrument.hooks
    if h is not None:
        h.trial_sever(rec)
    app._loadgen_leftovers = leftovers  # next trial settles on these

    elapsed = duration  # completions attributed to the offered window
    s = rec.summary()   # safe: the recorder is frozen after the sever
    return TrialResult(
        offered_rps=rate,
        achieved_rps=rec.completed / elapsed,
        duration=elapsed,
        p50=s["p50"], p99=s["p99"], mean=s["mean"],
        completed=rec.completed, shed=shed[0], errors=rec.errors,
        backend_stats=BackendStats.delta(stats_before,
                                        app.backend_stats()).as_dict(),
        offered=offered[0],
        good=good[0],
        goodput_rps=good[0] / elapsed,
        abandoned=abandoned,
    )


def warmup(app: App, make_request: RequestFactory, *, rate: float = 100.0,
           duration: float = 0.3, seed: int = 99) -> TrialResult:
    """Short unmeasured trial: touches the Compute calibration and every
    code path of the workload before a measured trial begins.  Every
    benchmark previously open-coded this."""
    return run_trial(app, make_request, rate, duration, seed=seed)


def find_peak_throughput(app: App, make_request: RequestFactory, *,
                         start_rate: float = 50.0, growth: float = 1.6,
                         duration: float = 1.5, seed: int = 0,
                         max_trials: int = 18,
                         verbose: bool = False) -> PeakResult:
    """Geometric ramp; stop after achieved throughput plateaus/regresses."""
    trials: List[TrialResult] = []
    rate = start_rate
    best = 0.0
    stall = 0
    for i in range(max_trials):
        tr = run_trial(app, make_request, rate, duration, seed=seed + i)
        trials.append(tr)
        if verbose:
            print("   ", tr.row(), flush=True)
        if tr.achieved_rps > best * 1.05:
            best = max(best, tr.achieved_rps)
            stall = 0
        else:
            best = max(best, tr.achieved_rps)
            stall += 1
            if stall >= 2:
                break
        rate *= growth
    return PeakResult(peak_rps=best, trials=trials)


def latency_sweep(app: App, make_request: RequestFactory, rates: List[float],
                  *, duration: float = 1.5, seed: int = 0,
                  verbose: bool = False) -> List[TrialResult]:
    """p99-vs-rate curve (the paper's second figure)."""
    out = []
    for i, r in enumerate(rates):
        tr = run_trial(app, make_request, r, duration, seed=seed + 100 + i)
        out.append(tr)
        if verbose:
            print("   ", tr.row(), flush=True)
    return out


@dataclass
class OverloadResult:
    """Goodput past the peak + time-to-recover after the overload window."""
    peak_rps: float
    overload_rps: float          # offered rate during the overload window
    overload: TrialResult        # the overload trial (goodput_rps is the score)
    recovery_rate: float         # sustainable probe rate used for recovery
    recovery_time: float         # s from overload end to first healthy probe
    recovered: bool              # False: never healthy within the timeout
    probes: List[TrialResult] = field(default_factory=list)


def run_overload(app: App, make_request: RequestFactory, *,
                 peak_rps: float, deadline: float, multiple: float = 3.0,
                 duration: float = 1.0, recovery_rate: Optional[float] = None,
                 recovery_duration: float = 0.25,
                 recovery_timeout: float = 5.0,
                 recovery_threshold: float = 0.9, seed: int = 0,
                 max_outstanding: int = 4096, enforce_deadline: bool = True,
                 verbose: bool = False) -> OverloadResult:
    """Drive ``multiple``× the measured peak, then probe until goodput
    recovers.

    The overload trial uses a short drain so the backlog it built persists
    into the recovery phase — recovery time measures how fast the app sheds
    that backlog, not how patient the drain window was.  A probe is
    *healthy* when its goodput reaches ``recovery_threshold`` of the probe
    rate (``recovery_rate``, default half the peak — comfortably
    sustainable, so only residual backlog can make a probe fail).
    """
    overload_rps = multiple * peak_rps
    tr = run_trial(app, make_request, overload_rps, duration, seed=seed,
                   max_outstanding=max_outstanding, drain=0.25,
                   deadline=deadline, enforce_deadline=enforce_deadline,
                   settle=1.0)
    if verbose:
        print("    overload", tr.row(), flush=True)
    t_over_end = time.monotonic()

    rrate = recovery_rate if recovery_rate is not None else 0.5 * peak_rps
    probes: List[TrialResult] = []
    recovered = False
    recovery_time = float("inf")
    i = 0
    while time.monotonic() - t_over_end < recovery_timeout:
        p = run_trial(app, make_request, rrate, recovery_duration,
                      seed=seed + 1000 + i, max_outstanding=max_outstanding,
                      drain=0.25, deadline=deadline,
                      enforce_deadline=enforce_deadline, settle=0.0)
        probes.append(p)
        if verbose:
            print("    probe   ", p.row(), flush=True)
        if p.goodput_rps >= recovery_threshold * rrate:
            recovered = True
            recovery_time = time.monotonic() - t_over_end
            break
        i += 1
    return OverloadResult(peak_rps=peak_rps, overload_rps=overload_rps,
                          overload=tr, recovery_rate=rrate,
                          recovery_time=recovery_time, recovered=recovered,
                          probes=probes)
