"""Open-loop Poisson load generator + peak-throughput search.

Mirrors the paper's evaluation protocol:

* *peak throughput*: "increase the request rate ... until the number of
  processed requests per second does not increase anymore" — implemented as a
  geometric ramp; the peak is the best achieved rate across the ramp;
* *tail latency vs rate*: fixed-rate open-loop trials reporting p99.

Arrivals are generated open-loop (Poisson, seeded) so queueing delay shows up
as latency rather than throttling the generator — the regime where the thread
backend's spawn cost collapses, per the paper.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, List, Tuple

import numpy as np

from .metrics import BackendStats, LatencyRecorder, PeakResult, TrialResult
from .service import App

# (method, payload) chooser — called per arrival with the trial RNG
RequestFactory = Callable[[np.random.Generator], Tuple[str, str, Any]]


def run_trial(app: App, make_request: RequestFactory, rate: float,
              duration: float, *, seed: int = 0, max_outstanding: int = 4096,
              drain: float = 2.0) -> TrialResult:
    """Offer ``rate`` req/s for ``duration`` seconds; measure completions."""
    rng = np.random.default_rng(seed)
    rec = LatencyRecorder()
    outstanding = [0]
    shed = [0]
    lock = threading.Lock()
    stats_before = app.backend_stats()

    t_start = time.perf_counter()
    t_end = t_start + duration
    next_arrival = t_start + float(rng.exponential(1.0 / rate))

    while True:
        now = time.perf_counter()
        if now >= t_end:
            break
        # fire every arrival that is due (catch-up batching keeps the
        # generator open-loop even when pacing sleep overshoots)
        while next_arrival <= now:
            next_arrival += float(rng.exponential(1.0 / rate))
            with lock:
                if outstanding[0] >= max_outstanding:
                    shed[0] += 1
                    continue
                outstanding[0] += 1
            dest, method, payload = make_request(rng)
            t0 = time.perf_counter()

            def _done(fut: Any, t0: float = t0) -> None:
                with lock:
                    outstanding[0] -= 1
                try:
                    fut.result()
                    rec.record(time.perf_counter() - t0)
                except BaseException:
                    rec.record_error()

            app.send(dest, method, payload).add_done_callback(_done)
        pause = min(next_arrival - time.perf_counter(), 0.001)
        if pause > 0:
            time.sleep(pause)

    # drain: give in-flight requests a bounded window to finish
    deadline = time.perf_counter() + drain
    while time.perf_counter() < deadline:
        with lock:
            if outstanding[0] == 0:
                break
        time.sleep(0.005)

    elapsed = duration  # completions attributed to the offered window
    s = rec.summary()
    return TrialResult(
        offered_rps=rate,
        achieved_rps=rec.completed / elapsed,
        duration=elapsed,
        p50=s["p50"], p99=s["p99"], mean=s["mean"],
        completed=rec.completed, shed=shed[0], errors=rec.errors,
        backend_stats=BackendStats.delta(stats_before,
                                        app.backend_stats()).as_dict(),
    )


def warmup(app: App, make_request: RequestFactory, *, rate: float = 100.0,
           duration: float = 0.3, seed: int = 99) -> TrialResult:
    """Short unmeasured trial: touches the Compute calibration and every
    code path of the workload before a measured trial begins.  Every
    benchmark previously open-coded this."""
    return run_trial(app, make_request, rate, duration, seed=seed)


def find_peak_throughput(app: App, make_request: RequestFactory, *,
                         start_rate: float = 50.0, growth: float = 1.6,
                         duration: float = 1.5, seed: int = 0,
                         max_trials: int = 18,
                         verbose: bool = False) -> PeakResult:
    """Geometric ramp; stop after achieved throughput plateaus/regresses."""
    trials: List[TrialResult] = []
    rate = start_rate
    best = 0.0
    stall = 0
    for i in range(max_trials):
        tr = run_trial(app, make_request, rate, duration, seed=seed + i)
        trials.append(tr)
        if verbose:
            print("   ", tr.row(), flush=True)
        if tr.achieved_rps > best * 1.05:
            best = max(best, tr.achieved_rps)
            stall = 0
        else:
            best = max(best, tr.achieved_rps)
            stall += 1
            if stall >= 2:
                break
        rate *= growth
    return PeakResult(peak_rps=best, trials=trials)


def latency_sweep(app: App, make_request: RequestFactory, rates: List[float],
                  *, duration: float = 1.5, seed: int = 0,
                  verbose: bool = False) -> List[TrialResult]:
    """p99-vs-rate curve (the paper's second figure)."""
    out = []
    for i, r in enumerate(rates):
        tr = run_trial(app, make_request, r, duration, seed=seed + 100 + i)
        out.append(tr)
        if verbose:
            print("   ", tr.row(), flush=True)
    return out
