"""Effect vocabulary for service handlers.

Handlers are written **once** as generator functions that ``yield`` effects;
the executor (thread- or fiber-backed) interprets them.  This mirrors the
paper's migration path: the service *logic* is untouched, only the async-call
implementation underneath changes (``std::async`` → ``boost::fiber::async``).

Effects
-------
AsyncRpc(dest, method, payload)
    Fire an asynchronous RPC.  Resumes *immediately* with a :class:`Future`.
    The interpreter spawns a **carrier** — a kernel thread (thread backend,
    faithful to ``std::async``'s thread-per-call policy) or a fiber (fiber
    backend) — whose body performs the transport send and waits for the reply.
Wait(future) / WaitAll(futures)
    Join.  Thread backend blocks the kernel thread; fiber backend suspends the
    fiber and frees the scheduler to run other fibers.
Sleep(seconds)
    Wait-dominated I/O time (DB/network).  Thread: ``time.sleep``; fiber:
    timer-heap suspension.
Compute(seconds)
    Calibrated *real* CPU burn — models the service's on-CPU work.
Offload(fn, *args)
    Run a blocking callable (e.g. a jitted JAX step) on the shared offload
    pool; resumes with a Future.  Used by the serving engine so device work
    never blocks the fiber scheduler.
SpawnLocal(genfn, *args)
    Run another handler generator asynchronously on the *same* service
    (local async function, no transport); resumes with a Future.
CurrentContext()
    Resume immediately with the request's ambient ``RequestContext`` (or
    ``None`` on the plain path); lets a handler read its session id,
    deadline, or hop depth without any new parameter plumbing.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple


class Effect:
    """Marker base class for everything a handler may ``yield``."""

    __slots__ = ()


@dataclass
class AsyncRpc(Effect):
    """Fire an async RPC; resumes immediately with the reply Future."""

    dest: str
    method: str
    payload: Any = None
    # Absolute ``time.monotonic()`` deadline for this call, or None.  The
    # interpreter tightens it against the calling request's own inherited
    # deadline and propagates the result downstream (each hop re-checks, so
    # an expired request fails fast instead of queueing dead work).
    deadline: Optional[float] = None


@dataclass
class Wait(Effect):
    """Join one future; resumes with its result (or raises its error)."""

    future: Any


@dataclass
class WaitAll(Effect):
    """Join a list of futures; resumes with their results, in order."""

    futures: List[Any]


@dataclass
class Sleep(Effect):
    """Wait-dominated I/O time (DB/network); never burns CPU."""

    seconds: float


@dataclass
class Compute(Effect):
    """Calibrated *real* CPU burn — the service's on-CPU work."""

    seconds: float


@dataclass
class Offload(Effect):
    """Run a blocking callable on the shared offload pool; resumes with a
    Future."""

    fn: Callable[..., Any]
    args: Tuple[Any, ...] = field(default_factory=tuple)


@dataclass
class SpawnLocal(Effect):
    """Run another handler generator async on the *same* service (no
    transport); resumes with a Future."""

    genfn: Callable[..., Any]
    args: Tuple[Any, ...] = field(default_factory=tuple)


@dataclass
class CurrentContext(Effect):
    """Resume immediately with the ambient :class:`~repro.core.context.
    RequestContext` of the running request (or ``None`` on the plain
    zero-context path).  Never suspends — handlers use it to read their
    session id, remaining deadline, or hop depth."""


def sync_rpc(dest: str, method: str, payload: Any = None):
    """Convenience sub-generator: async call + immediate join."""
    fut = yield AsyncRpc(dest, method, payload)
    result = yield Wait(fut)
    return result
