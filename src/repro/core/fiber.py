"""Cooperative user-space fiber scheduler (the paper's technique).

``boost::fiber`` semantics, adapted to Python:

* many **fibers** (resumable handler generators) multiplexed on **one OS
  thread** per scheduler;
* spawning a fiber is a heap allocation + deque push — no ``clone``/``exit``
  syscalls, no kernel run-queue contention;
* a fiber that *waits* (future join, timed I/O) is parked and the scheduler
  immediately runs another ready fiber, overlapping waiting times exactly as
  the paper's Figure 2 illustrates for ComposePost;
* only one fiber runs at a time per scheduler — fibers trade parallelism for
  scheduling cost, the trade the paper shows wins at high request rates.

External events (future resolutions from other schedulers/threads, new
requests, timer expiries) are *injected* through a mutex-protected queue and
wake the scheduler via its condition variable.

Two placement algorithms, mirroring boost.fiber's stock schedulers:

* **work-sharing** (default): the executor round-robins new work across
  schedulers and each fiber stays pinned to the scheduler that received it —
  the ``boost::fibers::algo::shared_work`` analogue.  The ready deque is
  owner-thread-only, so switches are lock-free.
* **work-stealing** (``steal=True``): schedulers form a :class:`StealGroup`;
  an idle scheduler pulls parked-ready fibers from the back of a loaded
  sibling's deque instead of sleeping — the
  ``boost::fibers::algo::work_stealing`` analogue.  Ready-deque accesses are
  then guarded by the scheduler's condition-variable lock (owner pops the
  front, thieves pop the back), and a scheduler that accumulates surplus
  ready work nudges one idle sibling awake.

A third variant, :class:`BatchFiberScheduler` (the ``fiber-batch`` backend),
keeps work-sharing placement but buffers same-tick ``AsyncRpc`` submissions
in a per-scheduler ring and flushes them as one batch carrier fiber —
io_uring-style submission — amortizing per-call dispatch across a whole
fan-out.  A fourth, :class:`CQBatchFiberScheduler` (``fiber-batch-cq``),
adds the completion-side mirror: a :class:`CompletionRing` that callee-side
resolution callbacks append resumptions to instead of firing one injected
wakeup per reply, drained as a single batch on size / timeout / idle — the
io_uring CQ to the submission ring's SQ.  Timed parks for all variants
(``Sleep`` effects, flush deadlines) share the
:class:`repro.core.timers.TimerWheel`.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Any, Generator, List, Optional, Tuple

from . import instrument
from .calibrate import burn
from .context import RequestContext
from .effects import (AsyncRpc, Compute, CurrentContext, Effect, Offload,
                      Sleep, SpawnLocal, Wait, WaitAll)
from .future import CompletedFuture, Future, Once
from .resilience import DeadlineExceeded
from .timers import TimerWheel

_RAISE = object()  # sentinel: send value is an exception to throw into the fiber
_FLUSH = object()  # timer payload: a batch scheduler's ring flush deadline
_CQ_FLUSH = object()  # timer payload: a completion ring's drain deadline
_DEADLINE = object()  # timer payload: a parked fiber's deadline expiry


class Fiber:
    """A resumable handler: generator + completion future.

    ``ctx`` is the request's :class:`~repro.core.context.RequestContext`
    (or None on the plain path): session id, hop depth, and the inherited
    absolute deadline the scheduler checks at every hop (AsyncRpc) and
    arms on the timer wheel whenever the fiber parks — expiry needs no
    polling."""

    __slots__ = ("gen", "future", "name", "ctx")
    _count = itertools.count()

    def __init__(self, gen: Generator, future: Optional[Future] = None,
                 name: str = "",
                 ctx: Optional[RequestContext] = None) -> None:
        self.gen = gen
        self.future = future if future is not None else Future()
        self.name = name or f"fiber-{next(Fiber._count)}"
        self.ctx = ctx

    @property
    def deadline(self) -> Optional[float]:
        """The context's absolute expiry (None without one)."""
        return self.ctx.deadline if self.ctx is not None else None


class StealGroup:
    """Shared state for a set of sibling schedulers in work-stealing mode:
    the membership list plus the set of members currently idle-parked, so a
    loaded scheduler can wake exactly one sleeper instead of broadcasting."""

    def __init__(self) -> None:
        self.members: List["FiberScheduler"] = []
        self._lock = threading.Lock()
        self._idle: "set[FiberScheduler]" = set()

    def attach(self, sched: "FiberScheduler") -> None:
        """Add a scheduler to the steal group."""
        self.members.append(sched)

    def register_idle(self, sched: "FiberScheduler") -> None:
        """Mark a scheduler as out of ready fibers (steal target picker)."""
        with self._lock:
            self._idle.add(sched)

    def unregister_idle(self, sched: "FiberScheduler") -> None:
        """Mark a scheduler busy again."""
        with self._lock:
            self._idle.discard(sched)

    def pick_idle(self, exclude: "FiberScheduler") -> Optional["FiberScheduler"]:
        """Claim one idle sibling (removing it so two pushers never both
        target the same sleeper); None when everyone is busy."""
        if not self._idle:      # racy fast path: skip the lock when nobody
            return None         # is parked (the common under-load case)
        with self._lock:
            for s in self._idle:
                if s is not exclude:
                    self._idle.discard(s)
                    return s
        return None


class FiberScheduler:
    """One OS thread running many fibers cooperatively."""

    # Safety-net poll while idle in steal mode.  Wake-on-surplus notifies are
    # the primary signal; the only miss window is a waker reading the idle
    # set just before this scheduler registers, which the surplus re-check
    # right before parking (see run()) shrinks to a few instructions.  The
    # poll backstops that sliver and exotic schedules; it is kept long
    # because frequent polls across many schedulers turn into a GIL-handoff
    # storm that starves Compute-heavy fibers.
    _IDLE_STEAL_POLL = 0.05

    def __init__(self, app: "Any", name: str = "sched",
                 steal_group: Optional[StealGroup] = None) -> None:
        self.app = app
        self.name = name
        self._ready: deque[Tuple[Fiber, Any]] = deque()
        # Timed parks (Sleep effects, subclass flush deadlines) live on the
        # shared TimerWheel (repro.core.timers) — owner-thread-only.
        self._timers = TimerWheel()
        self._cond = threading.Condition()
        self._injected: deque[Tuple[Fiber, Any]] = deque()
        # True only while the run loop is inside cond.wait (maintained under
        # _cond).  Completion-ring appenders consult it to skip the arming
        # notify entirely when the owner is demonstrably awake — the cond
        # lock serializes the flag against the pre-park pending re-check, so
        # the skip can never lose a wakeup (see CQBatchFiberScheduler).
        self._parked = False
        self._ident: Optional[int] = None  # run()-thread id, set per life
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        self._group = steal_group
        self._steal = steal_group is not None
        if steal_group is not None:
            steal_group.attach(self)
        # --- instrumentation (read by benchmarks) -----------------------
        self.fibers_spawned = 0
        self.switches = 0
        self.steals = 0
        # --- zero-handoff fast path (see _try_inline) -------------------
        # owner-thread-only: _interpret runs on whichever scheduler thread
        # is driving the fiber, and each scheduler has its own counters.
        self._inline_depth = 0
        self.inline_calls = 0
        self.inline_depth_hwm = 0
        self.fast_futures = 0
        self.slow_futures = 0
        # ambient RequestContext of the inline call currently being driven
        # (the inlined callee has no Fiber yet); owner-thread-only,
        # save/restored around each _drive_inline so nesting works.
        self._inline_ctx: Optional[RequestContext] = None

    # ------------------------------------------------------------ external
    def spawn_external(self, gen: Generator, future: Optional[Future] = None,
                       name: str = "",
                       ctx: Optional[RequestContext] = None) -> Future:
        """Thread-safe: create a fiber from outside the scheduler thread."""
        fib = Fiber(gen, future, name, ctx)
        h = instrument.hooks
        if h is not None:
            h.fiber_spawn(self, fib)
            h.queue_put(self)
        with self._cond:
            self._injected.append((fib, None))
            self._cond.notify()
        return fib.future

    def _inject(self, fib: Fiber, value: Any) -> None:
        h = instrument.hooks
        if h is not None:
            h.fiber_resume(self, fib)
            h.queue_put(self)
        with self._cond:
            self._injected.append((fib, value))
            self._cond.notify()

    def start(self) -> None:
        """Start (or restart) the scheduler's owner thread."""
        # reset the stop latch so a stopped scheduler can be restarted (an
        # App stop()->start() round trip re-enters every executor); without
        # this the fresh thread would observe the stale flag and exit at
        # its first idle check.
        with self._cond:
            self._stop = False
        self._thread = threading.Thread(target=self.run, name=self.name,
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        """Signal the owner thread to exit and join it (bounded)."""
        with self._cond:
            self._stop = True
            self._cond.notify()
        if self._thread is not None:
            self._thread.join(timeout=5.0)

    # ----------------------------------------------------------- main loop
    def run(self) -> None:
        """Owner-thread main loop: inject, drive ready fibers, idle-park."""
        self._ident = threading.get_ident()  # owner ident for this life
        h = instrument.hooks
        if h is not None:
            h.sched_loop(self)
        while True:
            # 1. pull external events / decide idle sleep under the lock
            with self._cond:
                drained = bool(self._injected)
                while self._injected:
                    self._ready.append(self._injected.popleft())
                have_ready = bool(self._ready)
                surplus = self._steal and len(self._ready) > 1
                stopping = self._stop
            if drained:
                h = instrument.hooks
                if h is not None:
                    h.queue_take(self)
            if surplus:
                # round-robin delivery / resumptions piled up here while a
                # sibling may be parked: hand it a chance to steal.
                self._wake_idle_peer()
            if not have_ready and self._steal and not stopping:
                have_ready = self._try_steal()
            if not have_ready and self._harvest_completions():
                # completion-ring drain (fiber-batch-cq "idle" flush): the
                # scheduler ran out of ready work, so pending completions
                # become the next batch instead of a park/wake round trip
                have_ready = True
            if not have_ready:
                with self._cond:
                    drained = bool(self._injected)
                    while self._injected:
                        self._ready.append(self._injected.popleft())
                    if not self._ready:
                        if self._stop:
                            return
                        # publish intent-to-park, THEN re-check the ring:
                        # an appender reads the flag only after its append,
                        # so either it sees _parked and notifies, or this
                        # re-check sees its entry and skips the wait — the
                        # cond lock (held through check and wait) makes the
                        # interleaving safe in both directions
                        self._parked = True
                        if not self._has_pending_completions():
                            timeout = self._timers.seconds_until_next(
                                time.monotonic())
                            if self._steal:
                                timeout = (self._IDLE_STEAL_POLL
                                           if timeout is None
                                           else min(timeout,
                                                    self._IDLE_STEAL_POLL))
                            if timeout is None or timeout > 0:
                                if self._group is not None:
                                    self._group.register_idle(self)
                                try:
                                    # surplus re-check after registering: a
                                    # waker that read the idle set as empty
                                    # just before we registered will not
                                    # notify, so don't park if a sibling
                                    # visibly has spare work
                                    if self._group is None or not any(
                                            len(s._ready) > 1
                                            for s in self._group.members
                                            if s is not self):
                                        self._cond.wait(timeout=timeout)
                                finally:
                                    if self._group is not None:
                                        self._group.unregister_idle(self)
                        self._parked = False
                        drained = drained or bool(self._injected)
                        while self._injected:
                            self._ready.append(self._injected.popleft())
                if drained:
                    h = instrument.hooks
                    if h is not None:
                        h.queue_take(self)
            # 2. fire due timers (the timer wheel is owner-thread-only; the
            #    resumed fibers go through _push_ready so thieves see them)
            for item in self._timers.pop_due(time.monotonic()):
                self._on_timer(item)
            self._arm_completion_timer()
            # 3. run one ready fiber to its next suspension point
            item = self._pop_ready()
            if item is not None:
                fib, value = item
                self.switches += 1
                self._run_fiber(fib, value)

    def _on_timer(self, item: Any) -> None:
        """A wheel entry came due.  Base schedulers park fibers and deadline
        expiries on the wheel; :class:`BatchFiberScheduler` adds flush
        deadlines."""
        if isinstance(item, tuple) and len(item) == 3 \
                and item[0] is _DEADLINE:
            _, claim, fib = item
            if claim.claim():
                # first writer wins: the completion callback for this park
                # lost (or will lose) the claim and becomes a no-op, so the
                # fiber is resumed exactly once — with the expiry thrown in
                self._count_timeout()
                self._push_ready((fib, (_RAISE, DeadlineExceeded(
                    f"{fib.name}: deadline expired while parked"))))
            return
        self._push_ready(item)

    def _count_timeout(self) -> None:
        app = self.app
        if app is not None:
            app._res_stats.timeout()

    # ------------------------------------------------- completion-ring hooks
    # No-ops on every scheduler except CQBatchFiberScheduler, whose
    # CompletionRing batches cross-thread resumptions (see below).  They sit
    # in the base run loop so the CQ variant does not have to duplicate it.
    def _harvest_completions(self) -> bool:
        """Drain any pending completion batch into the ready deque; returns
        True if work was produced (the run loop then skips parking)."""
        return False

    def _has_pending_completions(self) -> bool:
        """Racy park guard: True while completions are buffered."""
        return False

    def _arm_completion_timer(self) -> None:
        """Owner thread: ensure a drain deadline covers a non-empty ring."""

    # ------------------------------------------------ ready deque + stealing
    # Work-sharing mode: the ready deque is touched only by the owner thread,
    # so access is lock-free.  Steal mode: owner and thieves synchronize on
    # self._cond's lock — owner pushes/pops the front, thieves pop the back.
    def _push_ready(self, item: Tuple[Fiber, Any]) -> None:
        if not self._steal:
            self._ready.append(item)
            return
        h = instrument.hooks
        if h is not None:
            h.queue_put(self)  # thieves may take this push cross-thread
        with self._cond:
            self._ready.append(item)
            surplus = len(self._ready) > 1
        if surplus:
            self._wake_idle_peer()

    def _pop_ready(self) -> Optional[Tuple[Fiber, Any]]:
        if not self._steal:
            return self._ready.popleft() if self._ready else None
        with self._cond:
            item = self._ready.popleft() if self._ready else None
        if item is not None:
            h = instrument.hooks
            if h is not None:
                h.queue_take(self)
        return item

    def _try_steal(self) -> bool:
        """Pull ready fibers from the most loaded sibling.  Takes up to half
        of the victim's deque (at least 1, at most 4) from the back; returns
        True if anything was stolen."""
        victim = None
        depth = 0
        for s in self._group.members:   # racy peek: just a victim heuristic
            if s is not self and len(s._ready) > depth:
                victim, depth = s, len(s._ready)
        if victim is None:
            return False
        with victim._cond:
            n = len(victim._ready)
            take = min(max(n // 2, 1), 4) if n else 0
            grabbed = [victim._ready.pop() for _ in range(take)]
        if not grabbed:
            return False
        grabbed.reverse()               # preserve the victim's FIFO order
        h = instrument.hooks
        if h is not None:
            h.fiber_steal(victim, self, len(grabbed))
            h.queue_take(victim)
        with self._cond:
            self._ready.extend(grabbed)
        self.steals += len(grabbed)
        return True

    def _wake_idle_peer(self) -> None:
        if self._group is None:
            return
        peer = self._group.pick_idle(self)
        if peer is not None:
            with peer._cond:
                peer._cond.notify()

    # ------------------------------------------------------- fiber driving
    def _run_fiber(self, fib: Fiber, send_value: Any) -> None:
        """Drive ``fib`` until it parks (Wait/Sleep) or finishes.

        Non-blocking effects (AsyncRpc spawn, Compute, Offload, SpawnLocal)
        are interpreted inline, matching boost::fibers where the caller keeps
        running until it actually blocks.
        """
        while True:
            try:
                if isinstance(send_value, tuple) and len(send_value) == 2 \
                        and send_value[0] is _RAISE:
                    eff = fib.gen.throw(send_value[1])
                else:
                    eff = fib.gen.send(send_value)
            except StopIteration as stop:
                fib.future.set_result(stop.value)
                self._classify(fib.future)
                return
            except BaseException as exc:  # handler error -> propagate
                fib.future.set_exception(exc)
                self._classify(fib.future)
                return

            send_value, parked = self._interpret(fib, eff)
            if parked:
                return

    def _rpc_ctx(self, fib: Optional[Fiber],
                 eff: AsyncRpc) -> Optional[RequestContext]:
        """Context for one nested async call: the calling request's
        inherited context (inline callees have no Fiber yet; their ambient
        context is ``_inline_ctx``) hopped with the effect's own deadline —
        session/trace inherited, deadline tightened, depth bumped.  None
        when there is nothing to carry (the zero-alloc plain path)."""
        parent = fib.ctx if fib is not None else self._inline_ctx
        return RequestContext.hop(parent, eff.deadline)

    def _interpret(self, fib: Fiber, eff: Effect) -> Tuple[Any, bool]:
        """Returns (send_value, parked)."""
        if isinstance(eff, AsyncRpc):
            app = self.app
            hop = self._rpc_ctx(fib, eff)
            dl = hop.deadline if hop is not None else None
            if dl is not None and time.monotonic() >= dl:
                # hop check: an expired request spawns no further fan-out
                self._count_timeout()
                return (_RAISE, DeadlineExceeded(
                    f"rpc {eff.dest}.{eff.method}: deadline expired")), False
            if app is not None and app.net_latency == 0 \
                    and app.inline_budget > 0:
                # Zero-handoff fast path.  Tier 1: run the callee handler
                # inline (no mailbox, no carrier, no handoff at all).
                # Breaker/retry/bulkhead policies inline with per-edge
                # accounting (App._inline_resilient); only a mailbox-bound
                # policy forces the hop through App.send (tier 2 below),
                # because inlining would bypass the bounded queue itself.
                fut = (self._try_inline(eff, app, hop)
                       if app._inline_rpc_ok else None)
                if fut is not None:
                    return fut, False
                # Tier 2, carrier elision: with no client-side hop to
                # simulate, the carrier body is just send + Wait(reply) —
                # the reply future *is* the carrier's result, so hand it to
                # the caller directly instead of spawning a fiber whose only
                # job is to forward it.
                return app.send(eff.dest, eff.method, eff.payload,
                                ctx=hop), False
            # THE paper's operation: async call spawns a *fiber*, not a thread.
            carrier = Fiber(self.app.rpc_carrier(eff.dest, eff.method,
                                                 eff.payload, hop),
                            name=f"carrier->{eff.dest}", ctx=hop)
            self.fibers_spawned += 1
            h = instrument.hooks
            if h is not None:
                h.fiber_spawn(self, carrier)
            self._push_ready((carrier, None))
            return carrier.future, False

        if isinstance(eff, Wait):
            fut: Future = eff.future
            if fut.done:
                try:
                    return fut.result(), False
                except BaseException as exc:
                    return (_RAISE, exc), False
            claim = self._arm_deadline(fib)
            h = instrument.hooks
            if h is not None:
                h.fiber_park(self, fib)
                h.future_join(fut)
            fut.add_done_callback(
                lambda f, fib=fib, claim=claim: self._resume_on(f, fib, claim))
            return None, True

        if isinstance(eff, WaitAll):
            futs = list(eff.futures)
            if all(f.done for f in futs):
                try:
                    return [f.result() for f in futs], False
                except BaseException as exc:
                    return (_RAISE, exc), False
            latch = _CountdownLatch(len(futs))
            claim = self._arm_deadline(fib)
            h = instrument.hooks
            if h is not None:
                h.fiber_park(self, fib)
                for f in futs:
                    h.future_join(f)
            for f in futs:
                f.add_done_callback(
                    lambda _f, fib=fib, futs=futs, latch=latch, claim=claim:
                        self._resume_all_on(latch, futs, fib, claim))
            return None, True

        if isinstance(eff, Sleep):
            h = instrument.hooks
            if h is not None:
                h.fiber_park(self, fib)
            wake = time.monotonic() + max(eff.seconds, 0.0)
            if fib.deadline is not None and fib.deadline <= wake:
                # the sleep outlives the request: park the expiry instead of
                # the wake-up (timer-wheel-armed, claimed on fire so the
                # timeout counter ticks exactly once)
                self._timers.push(fib.deadline, (_DEADLINE, Once(), fib))
            else:
                self._timers.push(wake, (fib, None))
            return None, True

        if isinstance(eff, Compute):
            burn(eff.seconds)  # occupies this hardware thread, as in the paper
            return None, False

        if isinstance(eff, Offload):
            fut = self.app.offload(eff.fn, *eff.args)
            return fut, False

        if isinstance(eff, SpawnLocal):
            sub = Fiber(eff.genfn(*eff.args), name="local")
            self.fibers_spawned += 1
            h = instrument.hooks
            if h is not None:
                h.fiber_spawn(self, sub)
            self._push_ready((sub, None))
            return sub.future, False

        if isinstance(eff, CurrentContext):
            # ambient context of the running request (inlined callees have
            # no Fiber; theirs is the scheduler's _inline_ctx)
            return (fib.ctx if fib is not None else self._inline_ctx), False

        raise TypeError(f"Unknown effect: {eff!r}")

    def _arm_deadline(self, fib: Optional[Fiber]) -> Optional[Once]:
        """Park-time deadline arming: push a claimed expiry entry on the
        wheel for a deadline-carrying fiber about to suspend.  Returns the
        claim the resume callback must win before injecting (first writer
        wins; the loser — late completion or stale timer — is a no-op).
        The wheel is owner-thread-only and we *are* the driving thread."""
        if fib is None or fib.deadline is None:
            return None
        claim = Once()
        self._timers.push(fib.deadline, (_DEADLINE, claim, fib))
        return claim

    def _classify(self, fut: Future) -> None:
        """fast = resolved without a kernel Condition ever materializing."""
        if fut.blocking_waited():
            self.slow_futures += 1
        else:
            self.fast_futures += 1

    # ------------------------------------------------ zero-handoff fast path
    def _try_inline(self, eff: AsyncRpc, app: "Any",
                    ctx: Optional[RequestContext] = None) -> Optional[Future]:
        """Same-carrier call inlining: if the callee service's executor is
        cooperative and co-scheduled (same process, no simulated network
        hop), run its handler right here as a direct continuation of the
        calling fiber — skipping the reply-future handoff, the mailbox, the
        carrier spawn and the park/wake round trip.  Returns the call's
        future, or None when the call must take the slow path (budget
        exhausted, unknown service/method, thread-family callee).  Policy
        admission — breaker ``allow()``, bulkhead slots, outcome recording
        — is the App's job (``App._inline_call``); this scheduler only
        gates its own depth budget and drives the admitted generator."""
        if self._inline_depth >= app.inline_budget:
            return None
        return app._inline_call(eff.dest, eff.method, eff.payload, ctx,
                                self._inline_drive)

    def _inline_drive(self, gen: Generator,
                      ctx: Optional[RequestContext]) -> Future:
        """Scheduler-side bookkeeping around :meth:`_drive_inline`: inline
        counters, depth high-water, and the ambient-context save/restore
        that lets nested inlined hops tighten against the caller's bound.
        Owner-thread-only (``App._inline_call`` invokes it synchronously on
        the driving scheduler thread)."""
        self.inline_calls += 1
        self._inline_depth += 1
        if self._inline_depth > self.inline_depth_hwm:
            self.inline_depth_hwm = self._inline_depth
        prev_ctx = self._inline_ctx
        self._inline_ctx = ctx
        try:
            return self._drive_inline(gen, ctx)
        finally:
            self._inline_ctx = prev_ctx
            self._inline_depth -= 1

    def _drive_inline(self, gen: Generator,
                      ctx: Optional[RequestContext] = None) -> Future:
        """Run an inlined callee handler up to its first suspension point.

        Completion without suspending returns a pre-resolved
        :class:`CompletedFuture` — the zero-object, zero-handoff case.  A
        genuine suspension (unresolved join, timed wait) falls back to
        wrapping the remainder in a :class:`Fiber` parked on *this*
        scheduler, indistinguishable from a carrier that suspended."""
        send_value: Any = None
        while True:
            try:
                if isinstance(send_value, tuple) and len(send_value) == 2 \
                        and send_value[0] is _RAISE:
                    eff = gen.throw(send_value[1])
                else:
                    eff = gen.send(send_value)
            except StopIteration as stop:
                self.fast_futures += 1
                return CompletedFuture(stop.value)
            except BaseException as exc:
                self.fast_futures += 1
                return CompletedFuture(exc=exc)

            if isinstance(eff, Wait):
                # the hot sync_rpc sequence: the nested AsyncRpc just
                # returned a CompletedFuture, so the join is already done —
                # no Fiber, no callback, no park
                fut: Future = eff.future
                if fut.done:
                    try:
                        send_value = fut.result()
                    except BaseException as exc:
                        send_value = (_RAISE, exc)
                    continue
            elif isinstance(eff, WaitAll):
                futs = list(eff.futures)
                if all(f.done for f in futs):
                    try:
                        send_value = [f.result() for f in futs]
                    except BaseException as exc:
                        send_value = (_RAISE, exc)
                    continue
            if isinstance(eff, (Wait, WaitAll, Sleep)):
                # first real suspension point: from here on the remainder is
                # an ordinary fiber of this scheduler (inheriting the inline
                # call's context, so parked deadline expiry still arms)
                fib = Fiber(gen, ctx=ctx)
                self.fibers_spawned += 1
                h = instrument.hooks
                if h is not None:
                    h.fiber_spawn(self, fib)
                send_value, parked = self._interpret(fib, eff)
                if parked:
                    return fib.future
                # resolved in the race window between our done-check and
                # _interpret's — keep driving as a normal fiber
                self._run_fiber(fib, send_value)
                return fib.future
            # non-parking effects (AsyncRpc, Compute, Offload, SpawnLocal)
            # never touch the fiber argument
            send_value, _ = self._interpret(None, eff)  # type: ignore[arg-type]

    def _resume_on(self, fut: Future, fib: Fiber,
                   claim: Optional[Once] = None) -> None:
        if claim is not None and not claim.claim():
            return  # the deadline expiry beat us; the fiber already resumed
        try:
            value: Any = fut.result()
        except BaseException as exc:
            value = (_RAISE, exc)
        self._inject(fib, value)

    def _resume_all_on(self, latch: "_CountdownLatch", futs: List[Future],
                       fib: Fiber, claim: Optional[Once] = None) -> None:
        if not latch.count_down():
            return
        if claim is not None and not claim.claim():
            return  # the deadline expiry beat us; the fiber already resumed
        try:
            value: Any = [f.result() for f in futs]
        except BaseException as exc:
            value = (_RAISE, exc)
        self._inject(fib, value)


class _CountdownLatch:
    __slots__ = ("_n", "_lock")

    def __init__(self, n: int) -> None:
        self._n = n
        self._lock = threading.Lock()

    def count_down(self) -> bool:
        """Returns True exactly once, when the count reaches zero."""
        with self._lock:
            self._n -= 1
            return self._n == 0


def _chain_reply(reply: Future, fut: Future) -> None:
    """Copy a resolved transport reply onto the future handed to the
    submitting fiber at AsyncRpc time (the batch backend decouples the two)."""
    try:
        fut.set_result(reply.result())
    except BaseException as exc:
        fut.set_exception(exc)


class BatchFiberScheduler(FiberScheduler):
    """Fiber scheduler with io_uring-style batched async-call submission.

    A plain :class:`FiberScheduler` spawns one carrier fiber per ``AsyncRpc``
    — cheap, but still a ready-queue push, a context switch and a transport
    send *per call*.  This subclass gives each scheduler a **submission
    ring**: ``AsyncRpc`` effects buffer ``(dest, method, payload, future)``
    entries and resume the caller immediately; the ring is flushed as **one
    batch carrier fiber** that performs every transport send back-to-back
    (and pays any simulated network latency once per batch, the io_uring
    amortization).  Completions flow back through per-call reply futures —
    the completion ring — so callers observe identical semantics.

    Flush triggers, mirroring io_uring's submit conditions:

    * **size** — the ring reached ``batch_size`` entries;
    * **join** — the running fiber is about to wait (``Wait``/``WaitAll``);
      buffered submissions must reach the wire first, both for correctness
      (the awaited future may *be* a buffered call's reply) and because a
      blocking caller is exactly when io_uring applications submit;
    * **timeout** — ``flush_after`` seconds elapsed since the ring became
      non-empty (bounds the latency of fire-and-forget calls), tracked on
      the shared :class:`~repro.core.timers.TimerWheel`.

    Ring state is owner-thread-only, so this scheduler never joins a
    :class:`StealGroup` (a thief cannot see the victim's unflushed ring).
    """

    def __init__(self, app: "Any", name: str = "sched", *,
                 batch_size: int = 32, flush_after: float = 0.0005) -> None:
        super().__init__(app, name)
        self.batch_size = batch_size
        self.flush_after = flush_after
        self._ring: List[Tuple[str, str, Any, Future,
                               Optional[RequestContext]]] = []
        # Each flush advances the ring generation; flush deadlines are
        # tagged with the generation that armed them so a stale timer from
        # a size/join-flushed ring cannot truncate its successor (which
        # would systematically shrink batches under sustained load).
        self._ring_gen = 0
        # --- instrumentation (see metrics.BackendStats) ------------------
        self.batched_calls = 0      # submissions that went through the ring
        self.flushes_size = 0
        self.flushes_join = 0
        self.flushes_timeout = 0
        self.ring_hwm = 0           # ring occupancy high-water

    # ----------------------------------------------------------- submission
    def _interpret(self, fib: Fiber, eff: Effect) -> Tuple[Any, bool]:
        if isinstance(eff, AsyncRpc):
            hop = self._rpc_ctx(fib, eff)
            dl = hop.deadline if hop is not None else None
            if dl is not None and time.monotonic() >= dl:
                # hop check before buffering: dead calls never hit the ring
                self._count_timeout()
                return (_RAISE, DeadlineExceeded(
                    f"rpc {eff.dest}.{eff.method}: deadline expired")), False
            fut = Future()
            if not self._ring:
                # arm the flush deadline when the ring goes non-empty
                self._timers.push(time.monotonic() + self.flush_after,
                                  (_FLUSH, self._ring_gen))
            h = instrument.hooks
            if h is not None:
                h.ring_submit(self)
            self._ring.append((eff.dest, eff.method, eff.payload, fut, hop))
            if len(self._ring) > self.ring_hwm:
                self.ring_hwm = len(self._ring)
            if len(self._ring) >= self.batch_size:
                self._flush("size")
            return fut, False

        if isinstance(eff, (Wait, WaitAll)) and self._ring:
            self._flush("join")
        return super()._interpret(fib, eff)

    # ---------------------------------------------------------------- flush
    def _on_timer(self, item: Any) -> None:
        if isinstance(item, tuple) and item and item[0] is _FLUSH:
            if item[1] == self._ring_gen:
                self._flush("timeout")
            return  # stale generation: its ring already flushed
        super()._on_timer(item)

    def _flush(self, reason: str) -> None:
        if not self._ring:
            return  # already flushed by a tighter trigger
        batch, self._ring = self._ring, []
        self._ring_gen += 1  # invalidates this ring's pending flush timer
        self.batched_calls += len(batch)
        if reason == "size":
            self.flushes_size += 1
        elif reason == "join":
            self.flushes_join += 1
        else:
            self.flushes_timeout += 1
        carrier = Fiber(self._batch_carrier(batch),
                        name=f"batch-carrier[{len(batch)}]")
        self.fibers_spawned += 1  # one carrier per *batch*, not per call
        h = instrument.hooks
        if h is not None:
            h.ring_drain(self, len(batch), reason)
            h.fiber_spawn(self, carrier)
        self._push_ready((carrier, None))

    def _batch_carrier(self, batch: List[Tuple[str, str, Any, Future,
                                               Optional[RequestContext]]]
                       ) -> Generator:
        """One fiber submits the whole ring: the per-call dispatch cost the
        plain fiber backend pays N times is paid once here."""
        if self.app.net_latency > 0:
            yield Sleep(self.app.net_latency)  # client-side hop, amortized
        for dest, method, payload, fut, ctx in batch:
            reply = self.app.send(dest, method, payload, ctx=ctx)
            reply.add_done_callback(
                lambda r, fut=fut: _chain_reply(r, fut))
        return len(batch)


class CompletionRing:
    """MPSC buffer of resolved-completion resumptions bound for ONE scheduler.

    The reply-side mirror of :class:`BatchFiberScheduler`'s submission ring
    (the io_uring CQ to its SQ): resolution callbacks running on *other*
    executors' threads append ``(fiber, send_value)`` resumptions here
    instead of each paying a condition-variable injection into the owning
    scheduler — appends synchronize on the ring's own lock, which only
    resolver threads contend, and the whole ring reaches the scheduler as
    **one** batch.  Flush triggers, mirroring CQ-reaping conditions:

    * **size** — the ring reached ``size`` entries; the appender that filled
      it injects the batch itself (one lock acquire + one notify for the
      whole batch);
    * **timeout** — the owner was busy running fibers for ``cq_flush_after``
      seconds since it first saw the ring non-empty (deadline parked on the
      scheduler's :class:`~repro.core.timers.TimerWheel`), bounding reply
      latency under sustained load;
    * **idle** — the owner ran out of ready fibers; pending completions
      become the next batch instead of a park/wake round trip.

    Counters (surfaced as ``BackendStats``): ``completions_batched`` — total
    resumptions that travelled through the ring; ``flushes_size`` /
    ``flushes_timeout`` / ``flushes_idle`` — drains by trigger; ``hwm`` —
    ring-occupancy high-water (gauge).
    """

    __slots__ = ("size", "_lock", "_entries", "_gen", "completions_batched",
                 "flushes_size", "flushes_timeout", "flushes_idle", "hwm")

    def __init__(self, size: int = 32) -> None:
        self.size = size
        self._lock = threading.Lock()
        self._entries: List[Tuple[Fiber, Any]] = []
        self._gen = 0  # bumps per drain: stale-deadline guard (cf. _FLUSH)
        self.completions_batched = 0
        self.flushes_size = 0
        self.flushes_timeout = 0
        self.flushes_idle = 0
        self.hwm = 0

    def append(self, fib: Fiber, value: Any
               ) -> Tuple[Optional[List[Tuple[Fiber, Any]]], bool]:
        """Thread-safe append.  Returns ``(batch, first)``: ``batch`` is the
        whole ring when this append filled it to ``size`` (the appender
        must deliver it), ``first`` is True when the ring just went
        non-empty (the appender sends the single arming wakeup)."""
        h = instrument.hooks
        if h is not None:
            h.ring_submit(self)
            h.queue_put(self)
        with self._lock:
            self._entries.append((fib, value))
            n = len(self._entries)
            if n > self.hwm:
                self.hwm = n
            if n >= self.size:
                batch, self._entries = self._entries, []
                self._gen += 1
                self.flushes_size += 1
                self.completions_batched += n
                return batch, False
            return None, n == 1

    def drain(self, reason: str) -> List[Tuple[Fiber, Any]]:
        """Owner-side flush ("timeout" or "idle"); empty list when there is
        nothing pending."""
        with self._lock:
            if not self._entries:
                return []
            batch, self._entries = self._entries, []
            self._gen += 1
            self.completions_batched += len(batch)
            if reason == "timeout":
                self.flushes_timeout += 1
            else:
                self.flushes_idle += 1
        h = instrument.hooks
        if h is not None:
            h.ring_drain(self, len(batch), reason)
            h.queue_take(self)
        return batch

    @property
    def gen(self) -> int:
        """Flush generation (bumps per drain; timeout entries check it)."""
        return self._gen

    def __len__(self) -> int:
        return len(self._entries)

    def __bool__(self) -> bool:
        return bool(self._entries)


class CQBatchFiberScheduler(BatchFiberScheduler):
    """Submission rings *and* a completion ring (the ``fiber-batch-cq``
    backend).

    :class:`BatchFiberScheduler` amortizes the submission side but still
    pays one injected wakeup per *reply*: every resolution callback fired on
    a callee's thread acquires this scheduler's condition variable, appends
    one resumption and notifies — under a wide fan-out the caller's cond
    becomes the hottest lock in the app.  This subclass routes every
    cross-thread event — reply resumptions *and* new-fiber deliveries
    (``spawn_external``) — through a :class:`CompletionRing` instead: the
    ring is the scheduler's only cross-thread doorbell.  Appender threads
    contend only the ring's lock, the owner drains the ring as one batch
    (size / timeout / idle — see :class:`CompletionRing`), and a ten-wide
    burst of replies costs one scheduler wakeup instead of ten; while the
    owner is demonstrably awake (``_parked`` False) an append costs no
    condition-variable traffic at all.

    Ring drains by the owner go straight onto the ready deque (no lock: the
    batch family excludes stealing, so the deque is owner-thread-only); a
    size-triggered flush is injected by the appender as one locked batch.
    """

    def __init__(self, app: "Any", name: str = "sched", *,
                 batch_size: int = 32, flush_after: float = 0.0005,
                 cq_size: int = 32, cq_flush_after: float = 0.0005) -> None:
        super().__init__(app, name, batch_size=batch_size,
                         flush_after=flush_after)
        self.cq_flush_after = cq_flush_after
        self._cq = CompletionRing(cq_size)
        self._cq_armed = False  # owner-thread-only: drain deadline on wheel?

    # ------------------------------------------------- callee-side: append
    # The base class injects per cross-thread event; here ALL of them —
    # reply resumptions fired on resolver threads AND new-fiber deliveries
    # (spawn_external from dispatchers / batch carriers) — batch through
    # the completion ring: it is this scheduler's only cross-thread
    # doorbell, so a burst of replies or deliveries costs one wakeup.
    def spawn_external(self, gen: Generator, future: Optional[Future] = None,
                       name: str = "",
                       ctx: Optional[RequestContext] = None) -> Future:
        """Cross-thread delivery via the completion ring (one doorbell)."""
        fib = Fiber(gen, future, name, ctx)
        self._complete(fib, None)
        return fib.future

    def _inject(self, fib: Fiber, value: Any) -> None:
        # the base resume callbacks (_resume_on/_resume_all_on) funnel every
        # cross-thread resumption through here; rerouting this one seam puts
        # them all on the ring
        self._complete(fib, value)

    def _complete(self, fib: Fiber, value: Any) -> None:
        if threading.get_ident() == self._ident:
            # already on the owner thread (a resolution fired while this
            # scheduler drives a fiber, or a co-scheduled delivery): the
            # ready deque is ours to touch — no ring, no lock, no wakeup,
            # and no flush latency for a same-thread continuation
            self._ready.append((fib, value))
            return
        batch, first = self._cq.append(fib, value)
        if batch is not None:
            # size flush: the whole batch crosses in ONE injection
            h = instrument.hooks
            if h is not None:
                h.ring_drain(self._cq, len(batch), "size")
                h.queue_put(self)
            with self._cond:
                self._injected.extend(batch)
                self._cond.notify()
        elif first and self._parked:
            # empty -> non-empty while the owner sleeps: the single arming
            # wakeup.  A busy owner needs none — it re-checks the ring every
            # loop pass — and the pre-park _has_pending_completions re-check
            # (made after _parked is published, under the cond lock that
            # this notify must also take) closes the race either way: the
            # owner sees our entry, or we see _parked and wake it.
            with self._cond:
                self._cond.notify()

    # --------------------------------------- owner-side: drain + deadlines
    def _harvest_completions(self) -> bool:
        batch = self._cq.drain("idle")
        if not batch:
            return False
        self._ready.extend(batch)
        return True

    def _has_pending_completions(self) -> bool:
        return bool(self._cq)

    def _arm_completion_timer(self) -> None:
        if self._cq_armed or not self._cq:
            return
        self._cq_armed = True
        self._timers.push(time.monotonic() + self.cq_flush_after,
                          (_CQ_FLUSH, self._cq.gen))

    def _on_timer(self, item: Any) -> None:
        if isinstance(item, tuple) and item and item[0] is _CQ_FLUSH:
            self._cq_armed = False  # re-armed next loop pass if refilled
            if item[1] == self._cq.gen:
                self._ready.extend(self._cq.drain("timeout"))
            return  # stale generation: its ring already drained
        super()._on_timer(item)

    # ------------------------------------------------------ stats plumbing
    @property
    def completions_batched(self) -> int:
        """Cross-thread events that rode the completion ring."""
        return self._cq.completions_batched

    @property
    def cq_flushes_size(self) -> int:
        """Ring drains triggered by the ring filling."""
        return self._cq.flushes_size

    @property
    def cq_flushes_timeout(self) -> int:
        """Ring drains triggered by the flush deadline."""
        return self._cq.flushes_timeout

    @property
    def cq_flushes_idle(self) -> int:
        """Ring drains triggered by the owner running out of work."""
        return self._cq.flushes_idle

    @property
    def cq_hwm(self) -> int:
        """Completion-ring occupancy high-water mark."""
        return self._cq.hwm
