"""Cooperative user-space fiber scheduler (the paper's technique).

``boost::fiber`` semantics, adapted to Python:

* many **fibers** (resumable handler generators) multiplexed on **one OS
  thread** per scheduler;
* spawning a fiber is a heap allocation + deque push — no ``clone``/``exit``
  syscalls, no kernel run-queue contention;
* a fiber that *waits* (future join, timed I/O) is parked and the scheduler
  immediately runs another ready fiber, overlapping waiting times exactly as
  the paper's Figure 2 illustrates for ComposePost;
* only one fiber runs at a time per scheduler — fibers trade parallelism for
  scheduling cost, the trade the paper shows wins at high request rates.

External events (future resolutions from other schedulers/threads, new
requests, timer expiries) are *injected* through a mutex-protected queue and
wake the scheduler via its condition variable.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from collections import deque
from typing import Any, Callable, Generator, List, Optional, Tuple

from .calibrate import burn
from .effects import AsyncRpc, Compute, Effect, Offload, Sleep, SpawnLocal, Wait, WaitAll
from .future import Future

_RAISE = object()  # sentinel: send value is an exception to throw into the fiber


class Fiber:
    """A resumable handler: generator + completion future."""

    __slots__ = ("gen", "future", "name")
    _count = itertools.count()

    def __init__(self, gen: Generator, future: Optional[Future] = None,
                 name: str = "") -> None:
        self.gen = gen
        self.future = future if future is not None else Future()
        self.name = name or f"fiber-{next(Fiber._count)}"


class FiberScheduler:
    """One OS thread running many fibers cooperatively."""

    def __init__(self, app: "Any", name: str = "sched") -> None:
        self.app = app
        self.name = name
        self._ready: deque[Tuple[Fiber, Any]] = deque()
        self._timers: List[Tuple[float, int, Fiber, Any]] = []
        self._timer_seq = itertools.count()
        self._cond = threading.Condition()
        self._injected: deque[Tuple[Fiber, Any]] = deque()
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        # --- instrumentation (read by benchmarks) -----------------------
        self.fibers_spawned = 0
        self.switches = 0

    # ------------------------------------------------------------ external
    def spawn_external(self, gen: Generator, future: Optional[Future] = None,
                       name: str = "") -> Future:
        """Thread-safe: create a fiber from outside the scheduler thread."""
        fib = Fiber(gen, future, name)
        with self._cond:
            self._injected.append((fib, None))
            self._cond.notify()
        return fib.future

    def _inject(self, fib: Fiber, value: Any) -> None:
        with self._cond:
            self._injected.append((fib, value))
            self._cond.notify()

    def start(self) -> None:
        self._thread = threading.Thread(target=self.run, name=self.name,
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        with self._cond:
            self._stop = True
            self._cond.notify()
        if self._thread is not None:
            self._thread.join(timeout=5.0)

    # ----------------------------------------------------------- main loop
    def run(self) -> None:
        while True:
            # 1. pull external events / decide idle sleep under the lock
            with self._cond:
                while self._injected:
                    self._ready.append(self._injected.popleft())
                if not self._ready:
                    if self._stop:
                        return
                    timeout = None
                    if self._timers:
                        timeout = max(self._timers[0][0] - time.monotonic(), 0.0)
                    if timeout is None or timeout > 0:
                        self._cond.wait(timeout=timeout)
                    while self._injected:
                        self._ready.append(self._injected.popleft())
            # 2. fire due timers (owner thread only — no lock needed)
            now = time.monotonic()
            while self._timers and self._timers[0][0] <= now:
                _, _, fib, value = heapq.heappop(self._timers)
                self._ready.append((fib, value))
            # 3. run one ready fiber to its next suspension point
            if self._ready:
                fib, value = self._ready.popleft()
                self.switches += 1
                self._run_fiber(fib, value)

    # ------------------------------------------------------- fiber driving
    def _run_fiber(self, fib: Fiber, send_value: Any) -> None:
        """Drive ``fib`` until it parks (Wait/Sleep) or finishes.

        Non-blocking effects (AsyncRpc spawn, Compute, Offload, SpawnLocal)
        are interpreted inline, matching boost::fibers where the caller keeps
        running until it actually blocks.
        """
        while True:
            try:
                if isinstance(send_value, tuple) and len(send_value) == 2 \
                        and send_value[0] is _RAISE:
                    eff = fib.gen.throw(send_value[1])
                else:
                    eff = fib.gen.send(send_value)
            except StopIteration as stop:
                fib.future.set_result(stop.value)
                return
            except BaseException as exc:  # handler error -> propagate
                fib.future.set_exception(exc)
                return

            send_value, parked = self._interpret(fib, eff)
            if parked:
                return

    def _interpret(self, fib: Fiber, eff: Effect) -> Tuple[Any, bool]:
        """Returns (send_value, parked)."""
        if isinstance(eff, AsyncRpc):
            # THE paper's operation: async call spawns a *fiber*, not a thread.
            carrier = Fiber(self.app.rpc_carrier(eff.dest, eff.method,
                                                 eff.payload),
                            name=f"carrier->{eff.dest}")
            self.fibers_spawned += 1
            self._ready.append((carrier, None))
            return carrier.future, False

        if isinstance(eff, Wait):
            fut: Future = eff.future
            if fut.done:
                try:
                    return fut.result(), False
                except BaseException as exc:
                    return (_RAISE, exc), False
            fut.add_done_callback(lambda f, fib=fib: self._resume_on(f, fib))
            return None, True

        if isinstance(eff, WaitAll):
            futs = list(eff.futures)
            if all(f.done for f in futs):
                try:
                    return [f.result() for f in futs], False
                except BaseException as exc:
                    return (_RAISE, exc), False
            latch = _CountdownLatch(len(futs))
            for f in futs:
                f.add_done_callback(
                    lambda _f, fib=fib, futs=futs, latch=latch:
                        self._resume_all_on(latch, futs, fib))
            return None, True

        if isinstance(eff, Sleep):
            deadline = time.monotonic() + max(eff.seconds, 0.0)
            heapq.heappush(self._timers,
                           (deadline, next(self._timer_seq), fib, None))
            return None, True

        if isinstance(eff, Compute):
            burn(eff.seconds)  # occupies this hardware thread, as in the paper
            return None, False

        if isinstance(eff, Offload):
            fut = self.app.offload(eff.fn, *eff.args)
            return fut, False

        if isinstance(eff, SpawnLocal):
            sub = Fiber(eff.genfn(*eff.args), name="local")
            self.fibers_spawned += 1
            self._ready.append((sub, None))
            return sub.future, False

        raise TypeError(f"Unknown effect: {eff!r}")

    def _resume_on(self, fut: Future, fib: Fiber) -> None:
        try:
            value: Any = fut.result()
        except BaseException as exc:
            value = (_RAISE, exc)
        self._inject(fib, value)

    def _resume_all_on(self, latch: "_CountdownLatch", futs: List[Future],
                       fib: Fiber) -> None:
        if not latch.count_down():
            return
        try:
            value: Any = [f.result() for f in futs]
        except BaseException as exc:
            value = (_RAISE, exc)
        self._inject(fib, value)


class _CountdownLatch:
    __slots__ = ("_n", "_lock")

    def __init__(self, n: int) -> None:
        self._n = n
        self._lock = threading.Lock()

    def count_down(self) -> bool:
        """Returns True exactly once, when the count reaches zero."""
        with self._lock:
            self._n -= 1
            return self._n == 0
