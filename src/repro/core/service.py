"""Microservice graph: services, in-process transport, offload pool.

Each :class:`Service` owns a mailbox and an executor (thread- or fiber-
backed, chosen *per service* — the paper's incremental migration).  An RPC is
an enqueue into the destination mailbox plus a reply :class:`Future`; the
client side of the RPC (serialize / send / wait) runs inside a **carrier**
spawned by the calling service's backend, which is exactly where the paper's
thread-vs-fiber difference lives.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Generator, List, Optional

from .effects import Sleep, Wait
from .executor import Executor, make_executor
from .future import Future
from .resilience import (CircuitBreaker, CircuitOpenError, DeadlineExceeded,
                         Rejected, ResiliencePolicy, ResilienceStats,
                         RetryBudget)
from .timers import TimerThread

# Default inline-depth budget for the zero-handoff fast path: how many
# levels of same-process cooperative callees may run as a direct
# continuation of one caller step before the scheduler falls back to the
# carrier path.  Bounds both the Python stack and how long one fiber can
# monopolize its scheduler on a deep call chain (socialnetwork's
# compose -> text -> url_shorten is depth 2).  0 disables the fast path
# entirely (carrier elision included), restoring the PR 3 dispatch path.
INLINE_BUDGET_DEFAULT = 4


@dataclass
class ServiceSpec:
    name: str
    handlers: Dict[str, Callable[..., Generator]]
    n_workers: int = 2
    backend: Optional[str] = None  # None -> App default
    state: Dict[str, Any] = field(default_factory=dict)


class Service:
    def __init__(self, app: "App", spec: ServiceSpec, backend: str) -> None:
        self.app = app
        self.name = spec.name
        self.handlers = spec.handlers
        self.state = dict(spec.state)
        self.lock = threading.Lock()  # protects self.state across workers
        self.backend = backend
        self.executor: Executor = make_executor(backend, app, spec.name,
                                                spec.n_workers)
        # Lock-free request accounting: each request consumes one ticket
        # from an atomic counter (the same lost-update fix as
        # FiberExecutor._rr) and performs *no* Python-level write at all —
        # `requests` reads the counter's next value back out of its repr
        # (documented itertools.count behaviour), so the count is exact
        # with no lock acquire and no last-writer-wins race.
        self._req_ticket = itertools.count(1)
        # Queue-based load leveling: when the app's resilience policy caps
        # mailbox depth, admissions beyond the bound are rejected outright
        # instead of building unbounded backlog.  The in-flight count is a
        # plain int under its own small lock (one acquire per request at
        # admission, one in the reply's done-callback).
        pol = getattr(app, "resilience", None)
        self._mailbox_bound: Optional[int] = (
            pol.mailbox_bound if pol is not None else None)
        self._adm_lock = threading.Lock()
        self._inflight = 0

    @property
    def requests(self) -> int:
        r = repr(self._req_ticket)          # e.g. "count(42)"
        return int(r[r.index("(") + 1:-1]) - 1

    def count_request(self) -> None:
        next(self._req_ticket)

    def _admission_release(self, _fut: Future) -> None:
        with self._adm_lock:
            self._inflight -= 1

    def deliver(self, method: str, payload: Any, reply: Future,
                deadline: Optional[float] = None) -> None:
        handler = self.handlers.get(method)
        if handler is None:
            reply.set_exception(KeyError(f"{self.name}: no method {method!r}"))
            return
        if deadline is not None and time.monotonic() >= deadline:
            # hop-level admission check: an already-expired request must not
            # enter the mailbox — fail the reply, spawn nothing.
            self.app._res_stats.timeout()
            reply.set_exception(DeadlineExceeded(
                f"{self.name}.{method}: deadline expired before dispatch"))
            return
        bound = self._mailbox_bound
        if bound is not None:
            with self._adm_lock:
                admitted = self._inflight < bound
                if admitted:
                    self._inflight += 1
            if not admitted:
                self.app._res_stats.rejection()
                reply.set_exception(Rejected(
                    f"{self.name}: mailbox full ({bound} in flight)"))
                return
            reply.add_done_callback(self._admission_release)
        self.count_request()
        self.executor.deliver(handler(self, payload), reply, deadline)

    def inline_handler(self, method: str) -> Optional[Callable[..., Generator]]:
        """Zero-handoff fast path: return the handler iff this service's
        executor accepts having it run inline on a co-scheduled cooperative
        caller (skipping the mailbox and the carrier spawn entirely).
        Thread-family executors decline — their kernel-level dispatch cost
        is the design point being measured.  An inlined handler runs on the
        *caller's* thread, possibly concurrently with this service's own
        executor; that is already the contract handlers live under (every
        backend with ``n_workers > 1`` runs them on several threads), and
        ``self.lock`` remains the mechanism protecting shared state."""
        if not getattr(self.executor, "cooperative", False):
            return None
        return self.handlers.get(method)


class OffloadPool:
    """Fixed thread pool for genuinely-blocking work (jitted JAX steps,
    checkpoint file writes).  Shared app-wide so fiber schedulers never block.

    ``start()``/``stop()`` are idempotent and the pool is **restartable**: a
    stopped pool's worker threads have exited (kernel threads cannot be
    resurrected), so each ``start()`` spawns a fresh set.  It also drains
    any shutdown sentinels still sitting in the queue — a worker that missed
    its sentinel (join timeout) or a ``stop()`` issued before any start
    would otherwise leave poison that kills the new workers on their first
    ``get()``, silently orphaning every subsequent ``offload()`` future.
    """

    def __init__(self, n_threads: int = 2) -> None:
        import queue as _q
        self._queue_mod = _q
        self._n_threads = n_threads
        self._q: "_q.SimpleQueue" = _q.SimpleQueue()
        self._threads: list = []
        self._started = False

    def start(self) -> None:
        if self._started:
            return
        # drain stale shutdown sentinels, preserving queued work in order:
        # submissions made while stopped are served by the new workers.
        pending = []
        while True:
            try:
                item = self._q.get_nowait()
            except self._queue_mod.Empty:
                break
            if item is not None:
                pending.append(item)
        for item in pending:
            self._q.put(item)
        self._threads = [
            threading.Thread(target=self._loop, name=f"offload{i}", daemon=True)
            for i in range(self._n_threads)
        ]
        for t in self._threads:
            t.start()
        self._started = True

    def stop(self) -> None:
        if not self._started:
            return  # idempotent; a never-started pool must not be poisoned
        for _ in self._threads:
            self._q.put(None)
        # join with the executors' 5 s budget: App.stop() must not
        # return while offload work is still mid-flight
        for t in self._threads:
            t.join(timeout=5.0)
        self._threads = []
        self._started = False

    def submit(self, fn: Callable[..., Any], *args: Any) -> Future:
        fut = Future()
        self._q.put((fn, args, fut))
        return fut

    def _loop(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            fn, args, fut = item
            try:
                fut.set_result(fn(*args))
            except BaseException as exc:
                fut.set_exception(exc)


class App:
    """A wired microservice application.

    Parameters
    ----------
    backend:
        Default async-call backend for every service — any name in
        ``executor.BACKEND_NAMES``: ``"thread"`` (paper baseline, std::async
        semantics), ``"thread-pool"`` (bounded pre-spawned carrier pool),
        ``"fiber"`` (paper technique, work-sharing placement),
        ``"fiber-steal"`` (work-stealing placement), ``"fiber-batch"``
        (io_uring-style batched submission rings), ``"fiber-batch-cq"``
        (submission rings plus reply-batching completion rings),
        ``"event-loop"`` (single-carrier cooperative loop) or
        ``"event-loop-shard"`` (N loops, requests hashed by id).
        Individual :class:`ServiceSpec`s may override.
    net_latency:
        Simulated one-way network latency the carrier pays before the send
        (the container has one host; spawn/scheduling costs are real).
    inline_budget:
        Zero-handoff fast-path depth budget: when a cooperative backend's
        ``AsyncRpc`` targets a co-scheduled cooperative service and
        ``net_latency == 0``, the callee handler runs as a direct
        continuation of the caller up to its first suspension point, up to
        this many nested levels; beyond it (or for thread-family callees)
        the call falls back to carrier elision or the full carrier path.
        ``0`` disables the fast path entirely (the PR 3 dispatch path).
    resilience:
        Optional :class:`~repro.core.resilience.ResiliencePolicy` enabling
        the overload-survival layer: default per-request deadlines, budgeted
        retry-with-backoff, per-destination circuit breakers and bounded
        service mailboxes.  ``None`` (the default) keeps the pre-resilience
        send path bit-for-bit.
    """

    def __init__(self, backend: str = "fiber", net_latency: float = 0.0,
                 offload_threads: int = 2,
                 inline_budget: int = INLINE_BUDGET_DEFAULT,
                 resilience: Optional[ResiliencePolicy] = None) -> None:
        self.default_backend = backend
        self.net_latency = net_latency
        self.inline_budget = inline_budget
        self.resilience = resilience
        # Tier-1 call inlining runs the callee handler without touching the
        # send path, which would bypass per-edge breakers, retries and
        # mailbox bounds — only sound when the policy carries none of those
        # (a bare default-deadline policy still inlines: deadlines ride the
        # ambient propagation the interpreters already do).
        self._inline_rpc_ok = resilience is None or (
            not resilience.breakers and resilience.retry is None
            and resilience.mailbox_bound is None)
        self.services: Dict[str, Service] = {}
        self.offload_pool = OffloadPool(offload_threads)
        self._started = False
        # resilience machinery: app-wide counters, per-destination breakers,
        # a retry token bucket, and one kernel-timer thread for backoff
        # firings and pool-suspend deadline expiries (lazily started).
        self._res_stats = ResilienceStats()
        self._breakers: Dict[str, CircuitBreaker] = {}
        self._breaker_lock = threading.Lock()
        self._retry_budget: Optional[RetryBudget] = (
            RetryBudget(resilience.retry)
            if resilience is not None and resilience.retry is not None
            else None)
        self._timer = TimerThread()
        # futures of requests a load-generation trial abandoned at sever
        # time; the next trial settles on them before snapshotting stats
        # (see loadgen.run_trial).
        self._loadgen_leftovers: List[Future] = []

    # ------------------------------------------------------------- wiring
    def add_service(self, spec: ServiceSpec) -> Service:
        if spec.name in self.services:
            raise ValueError(f"duplicate service {spec.name!r}")
        svc = Service(self, spec, spec.backend or self.default_backend)
        self.services[spec.name] = svc
        return svc

    def start(self) -> None:
        """Idempotent; a stopped app can be started again (the benchmark
        harnesses re-enter one App as a context manager between sweeps)."""
        if self._started:
            return
        from .calibrate import iters_per_second
        iters_per_second()  # calibrate the Compute burn before serving
        self.offload_pool.start()
        for svc in self.services.values():
            svc.executor.start()
        self._started = True

    def stop(self) -> None:
        """Idempotent: a double stop() must not re-join executors or poison
        the offload pool with extra shutdown sentinels."""
        if not self._started:
            return
        self._started = False  # send() fails fast while teardown runs
        for svc in self.services.values():
            svc.executor.stop()
        self.offload_pool.stop()
        self._timer.stop()

    def __enter__(self) -> "App":
        self.start()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.stop()

    # ---------------------------------------------------------- transport
    def send(self, dest: str, method: str, payload: Any = None, *,
             deadline: Optional[float] = None) -> Future:
        """Enqueue an RPC at ``dest``; returns the reply future.
        Thread-safe; callable from any thread (incl. the load generator).

        ``deadline`` is an absolute ``time.monotonic()`` bound propagated
        to every downstream hop.  With no deadline and no resilience
        policy this is the original zero-overhead path."""
        if self.resilience is None and deadline is None:
            reply = Future()
            if not self._started:
                # fail fast: a delivery into a stopped app would sit in a
                # dead executor's mailbox and hang any blocking waiter
                reply.set_exception(RuntimeError(
                    f"App is not started; cannot send {dest}.{method} "
                    f"(start() it, or use it as a context manager)"))
                return reply
            svc = self.services.get(dest)
            if svc is None:
                reply.set_exception(KeyError(f"no service {dest!r}"))
                return reply
            svc.deliver(method, payload, reply)
            return reply
        return self._send_resilient(dest, method, payload, deadline)

    def _breaker(self, dest: str) -> CircuitBreaker:
        br = self._breakers.get(dest)
        if br is None:
            with self._breaker_lock:
                br = self._breakers.get(dest)
                if br is None:
                    br = self.resilience.make_breaker()
                    self._breakers[dest] = br
        return br

    def _send_resilient(self, dest: str, method: str, payload: Any,
                        deadline: Optional[float]) -> Future:
        """Policy-wrapped send: default deadline stamping, per-destination
        circuit breaker, and budgeted retry-with-jittered-backoff.

        The outer ``reply`` future is resolved exactly once, by whichever
        attempt concludes the call; each attempt uses its own inner future,
        so a late reply from a superseded attempt can never double-resolve
        the caller's join (single-writer discipline preserved)."""
        pol = self.resilience
        reply = Future()
        if not self._started:
            reply.set_exception(RuntimeError(
                f"App is not started; cannot send {dest}.{method} "
                f"(start() it, or use it as a context manager)"))
            return reply
        svc = self.services.get(dest)
        if svc is None:
            reply.set_exception(KeyError(f"no service {dest!r}"))
            return reply
        if (deadline is None and pol is not None
                and pol.deadline is not None):
            deadline = time.monotonic() + pol.deadline
        if deadline is not None and time.monotonic() >= deadline:
            self._res_stats.timeout()
            reply.set_exception(DeadlineExceeded(
                f"{dest}.{method}: deadline already expired at send"))
            return reply
        breaker = (self._breaker(dest)
                   if pol is not None and pol.breakers else None)
        retry = pol.retry if pol is not None else None
        if breaker is not None and not breaker.allow():
            reply.set_exception(CircuitOpenError(
                f"{dest}: circuit open, failing fast"))
            return reply

        attempts = [0]

        def launch() -> None:
            attempts[0] += 1
            inner = Future()
            inner.add_done_callback(on_done)
            svc.deliver(method, payload, inner, deadline)

        def on_done(f: Future) -> None:
            try:
                value = f.result()
            except CircuitOpenError as exc:
                # a *downstream* edge failed fast; propagate without
                # recording a failure here (don't cascade trips) and
                # without retrying into a known-open circuit.  If this
                # attempt was a half-open probe, release the slot — the
                # edge itself was never exercised (see abort_probe).
                if breaker is not None:
                    breaker.abort_probe()
                reply.set_exception(exc)
                return
            except BaseException as exc:
                if breaker is not None:
                    breaker.record(False)
                delay = _retry_delay(exc)
                if delay is None:
                    reply.set_exception(exc)
                    return
                self._res_stats.retry()
                self._timer.push(time.monotonic() + delay, retry_fire)
                return
            if breaker is not None:
                breaker.record(True)
            if self._retry_budget is not None:
                self._retry_budget.credit()
            reply.set_result(value)

        def _retry_delay(exc: BaseException) -> Optional[float]:
            """Backoff before the next attempt, or None for no retry.
            Deadline expiry is never retried (the attempt consumed the
            whole budget); the token bucket caps amplification."""
            if retry is None or isinstance(exc, DeadlineExceeded):
                return None
            if attempts[0] >= retry.max_attempts:
                return None
            delay = retry.backoff_for(attempts[0])
            if (deadline is not None
                    and time.monotonic() + delay >= deadline):
                return None
            if not self._retry_budget.try_spend():
                return None
            return delay

        def retry_fire() -> None:
            if not self._started:
                reply.set_exception(RuntimeError(
                    f"App stopped while retrying {dest}.{method}"))
                return
            if breaker is not None and not breaker.allow():
                reply.set_exception(CircuitOpenError(
                    f"{dest}: circuit opened during backoff, failing fast"))
                return
            launch()

        launch()
        return reply

    def rpc_carrier(self, dest: str, method: str, payload: Any,
                    deadline: Optional[float] = None) -> Generator:
        """The generator every async-call carrier runs: client-side network
        latency, send, block on reply.  Interpreted by a kernel thread
        (thread backend) or a fiber (fiber backend)."""
        if self.net_latency > 0:
            yield Sleep(self.net_latency)
        reply = self.send(dest, method, payload, deadline=deadline)
        value = yield Wait(reply)
        return value

    def offload(self, fn: Callable[..., Any], *args: Any) -> Future:
        return self.offload_pool.submit(fn, *args)

    # ------------------------------------------------------ instrumentation
    def total_spawns(self) -> int:
        return sum(s.executor.spawns for s in self.services.values())

    def backend_stats(self) -> "BackendStats":
        """App-wide executor counters: sums across services, except gauges
        (queue-depth high-water) which take the max."""
        from .metrics import BackendStats
        agg = BackendStats()
        for s in self.services.values():
            agg.add(s.executor.stats())
        agg.timeouts = self._res_stats.timeouts
        agg.retries = self._res_stats.retries
        agg.rejections = self._res_stats.rejections
        agg.breaker_opens = sum(b.opens for b in self._breakers.values())
        return agg
