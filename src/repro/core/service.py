"""Microservice graph: services, in-process transport, offload pool.

Each :class:`Service` owns a mailbox and an executor (thread- or fiber-
backed, chosen *per service* — the paper's incremental migration).  An RPC is
an enqueue into the destination mailbox plus a reply :class:`Future`; the
client side of the RPC (serialize / send / wait) runs inside a **carrier**
spawned by the calling service's backend, which is exactly where the paper's
thread-vs-fiber difference lives.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Generator, List, Optional, Tuple

from . import instrument
from .context import RequestContext
from .effects import Sleep, Wait
from .executor import Executor, make_executor
from .faults import FaultPlan, faulted_handler
from .future import CompletedFuture, Future
from .resilience import (Bulkhead, CircuitBreaker, CircuitOpenError,
                         DeadlineExceeded, Rejected, ResiliencePolicy,
                         ResilienceStats, RetryBudget, min_deadline)
from .timers import TimerThread

# Default inline-depth budget for the zero-handoff fast path: how many
# levels of same-process cooperative callees may run as a direct
# continuation of one caller step before the scheduler falls back to the
# carrier path.  Bounds both the Python stack and how long one fiber can
# monopolize its scheduler on a deep call chain (socialnetwork's
# compose -> text -> url_shorten is depth 2).  0 disables the fast path
# entirely (carrier elision included), restoring the PR 3 dispatch path.
INLINE_BUDGET_DEFAULT = 4


def _ctx_with_deadline(ctx: Optional[RequestContext],
                       deadline: Optional[float]
                       ) -> Optional[RequestContext]:
    """Context carrying exactly ``deadline`` (session/depth/trace kept).
    Returns ``ctx`` unchanged when nothing would change, and ``None`` when
    there is nothing to carry — the zero-alloc plain path."""
    if ctx is None:
        return RequestContext(deadline=deadline) if deadline is not None \
            else None
    if ctx.deadline == deadline:
        return ctx
    return RequestContext(session=ctx.session, deadline=deadline,
                          depth=ctx.depth, trace_id=ctx.trace_id)


@dataclass
class ServiceSpec:
    """Declarative service definition: handlers + sizing + backend pick."""

    name: str
    handlers: Dict[str, Callable[..., Generator]]
    n_workers: int = 2
    backend: Optional[str] = None  # None -> App default
    state: Dict[str, Any] = field(default_factory=dict)


class Service:
    """One microservice: a ServiceSpec bound to an executor instance."""

    def __init__(self, app: "App", spec: ServiceSpec, backend: str) -> None:
        self.app = app
        self.name = spec.name
        self.handlers = spec.handlers
        self.state = dict(spec.state)
        self.lock = threading.Lock()  # protects self.state across workers
        self.backend = backend
        self.executor: Executor = make_executor(backend, app, spec.name,
                                                spec.n_workers)
        # Lock-free request accounting: each request consumes one ticket
        # from an atomic counter (the same lost-update fix as
        # FiberExecutor._rr) and performs *no* Python-level write at all —
        # `requests` reads the counter's next value back out of its repr
        # (documented itertools.count behaviour), so the count is exact
        # with no lock acquire and no last-writer-wins race.
        self._req_ticket = itertools.count(1)
        # Queue-based load leveling: when the app's resilience policy caps
        # mailbox depth, admissions beyond the bound are rejected outright
        # instead of building unbounded backlog.  The in-flight count is a
        # plain int under its own small lock (one acquire per request at
        # admission, one in the reply's done-callback).
        pol = getattr(app, "resilience", None)
        self._mailbox_bound: Optional[int] = (
            pol.mailbox_bound if pol is not None else None)
        self._adm_lock = threading.Lock()
        self._inflight = 0

    @property
    def requests(self) -> int:
        """Requests handled so far (exact, lock-free ticket-counter read)."""
        r = repr(self._req_ticket)          # e.g. "count(42)"
        return int(r[r.index("(") + 1:-1]) - 1

    def count_request(self) -> None:
        """Count one handled request (called by every delivery/inline path)."""
        next(self._req_ticket)

    def _admission_release(self, _fut: Future) -> None:
        with self._adm_lock:
            self._inflight -= 1

    def deliver(self, method: str, payload: Any, reply: Future,
                ctx: Optional[RequestContext] = None) -> None:
        """Transport hop: admit (deadline/mailbox-bound checks), simulate
        the network, and hand the handler generator to the executor.
        ``ctx`` is the request's :class:`RequestContext` (or None on the
        plain path); its deadline gates admission and the whole context is
        handed to the executor so session pinning and nested hops see it."""
        handler = self.handlers.get(method)
        if handler is None:
            reply.set_exception(KeyError(f"{self.name}: no method {method!r}"))
            return
        deadline = ctx.deadline if ctx is not None else None
        if deadline is not None and time.monotonic() >= deadline:
            # hop-level admission check: an already-expired request must not
            # enter the mailbox — fail the reply, spawn nothing.
            self.app._res_stats.timeout()
            reply.set_exception(DeadlineExceeded(
                f"{self.name}.{method}: deadline expired before dispatch"))
            return
        bound = self._mailbox_bound
        if bound is not None:
            with self._adm_lock:
                admitted = self._inflight < bound
                if admitted:
                    self._inflight += 1
            if not admitted:
                self.app._res_stats.rejection()
                reply.set_exception(Rejected(
                    f"{self.name}: mailbox full ({bound} in flight)"))
                return
            reply.add_done_callback(self._admission_release)
        plan = self.app.fault_plan
        if plan is not None:
            action = plan.intercept(self.name, method)
            if action is not None:
                # injected fault, applied *after* the admission checks so a
                # faulted request flows through the same accounting as a
                # real failure (see repro.core.faults: injection points)
                if action[0] == "wrap":
                    self.count_request()
                    self.executor.deliver(
                        faulted_handler(handler(self, payload),
                                        action[1], action[2]), reply, ctx)
                    return
                if action[0] == "hang":
                    plan.blackhole(reply)
                    return
                reply.set_exception(action[1])      # "error" / crash
                return
        self.count_request()
        self.executor.deliver(handler(self, payload), reply, ctx)

    def inline_handler(self, method: str) -> Optional[Callable[..., Generator]]:
        """Zero-handoff fast path: return the handler iff this service's
        executor accepts having it run inline on a co-scheduled cooperative
        caller (skipping the mailbox and the carrier spawn entirely).
        Thread-family executors decline — their kernel-level dispatch cost
        is the design point being measured.  An inlined handler runs on the
        *caller's* thread, possibly concurrently with this service's own
        executor; that is already the contract handlers live under (every
        backend with ``n_workers > 1`` runs them on several threads), and
        ``self.lock`` remains the mechanism protecting shared state."""
        if not getattr(self.executor, "cooperative", False):
            return None
        return self.handlers.get(method)


class OffloadPool:
    """Fixed thread pool for genuinely-blocking work (jitted JAX steps,
    checkpoint file writes).  Shared app-wide so fiber schedulers never block.

    ``start()``/``stop()`` are idempotent and the pool is **restartable**: a
    stopped pool's worker threads have exited (kernel threads cannot be
    resurrected), so each ``start()`` spawns a fresh set.  It also drains
    any shutdown sentinels still sitting in the queue — a worker that missed
    its sentinel (join timeout) or a ``stop()`` issued before any start
    would otherwise leave poison that kills the new workers on their first
    ``get()``, silently orphaning every subsequent ``offload()`` future.
    """

    def __init__(self, n_threads: int = 2) -> None:
        import queue as _q
        self._queue_mod = _q
        self._n_threads = n_threads
        self._q: "_q.SimpleQueue" = _q.SimpleQueue()
        self._threads: list = []
        self._started = False

    def start(self) -> None:
        """Spawn the worker threads (idempotent; replays queued work)."""
        if self._started:
            return
        # drain stale shutdown sentinels, preserving queued work in order:
        # submissions made while stopped are served by the new workers.
        pending = []
        while True:
            try:
                item = self._q.get_nowait()
            except self._queue_mod.Empty:
                break
            if item is not None:
                pending.append(item)
        for item in pending:
            self._q.put(item)
        self._threads = [
            threading.Thread(target=self._loop, name=f"offload{i}", daemon=True)
            for i in range(self._n_threads)
        ]
        for t in self._threads:
            t.start()
        self._started = True

    def stop(self) -> None:
        """Stop the workers (idempotent; queued work survives a restart)."""
        if not self._started:
            return  # idempotent; a never-started pool must not be poisoned
        for _ in self._threads:
            self._q.put(None)
        # join with the executors' 5 s budget: App.stop() must not
        # return while offload work is still mid-flight
        for t in self._threads:
            t.join(timeout=5.0)
        self._threads = []
        self._started = False

    def submit(self, fn: Callable[..., Any], *args: Any) -> Future:
        """Queue ``fn(*args)`` for a worker; returns its reply Future."""
        fut = Future()
        self._q.put((fn, args, fut))
        return fut

    def _loop(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            fn, args, fut = item
            try:
                fut.set_result(fn(*args))
            except BaseException as exc:
                fut.set_exception(exc)


class App:
    """A wired microservice application.

    Parameters
    ----------
    backend:
        Default async-call backend for every service — any name in
        ``executor.BACKEND_NAMES``: ``"thread"`` (paper baseline, std::async
        semantics), ``"thread-pool"`` (bounded pre-spawned carrier pool),
        ``"fiber"`` (paper technique, work-sharing placement),
        ``"fiber-steal"`` (work-stealing placement), ``"fiber-batch"``
        (io_uring-style batched submission rings), ``"fiber-batch-cq"``
        (submission rings plus reply-batching completion rings),
        ``"event-loop"`` (single-carrier cooperative loop) or
        ``"event-loop-shard"`` (N loops, requests hashed by id).
        Individual :class:`ServiceSpec`s may override.
    net_latency:
        Simulated one-way network latency the carrier pays before the send
        (the container has one host; spawn/scheduling costs are real).
    inline_budget:
        Zero-handoff fast-path depth budget: when a cooperative backend's
        ``AsyncRpc`` targets a co-scheduled cooperative service and
        ``net_latency == 0``, the callee handler runs as a direct
        continuation of the caller up to its first suspension point, up to
        this many nested levels; beyond it (or for thread-family callees)
        the call falls back to carrier elision or the full carrier path.
        ``0`` disables the fast path entirely (the PR 3 dispatch path).
    resilience:
        Optional :class:`~repro.core.resilience.ResiliencePolicy` enabling
        the overload-survival layer: default per-request deadlines, budgeted
        retry-with-backoff, per-destination circuit breakers, per-edge
        bulkheads and bounded service mailboxes.  ``None`` (the default)
        keeps the pre-resilience send path bit-for-bit.  Breaker / retry /
        bulkhead policies keep the zero-handoff inline fast path (the
        inlined attempt feeds the same per-edge accounting — see
        ``_inline_resilient``); only ``mailbox_bound`` disables inlining.
    """

    def __init__(self, backend: str = "fiber", net_latency: float = 0.0,
                 offload_threads: int = 2,
                 inline_budget: int = INLINE_BUDGET_DEFAULT,
                 resilience: Optional[ResiliencePolicy] = None) -> None:
        self.default_backend = backend
        self.net_latency = net_latency
        self.inline_budget = inline_budget
        self.resilience = resilience
        # Tier-1 call inlining admission (see _inline_call).  Breaker,
        # retry and bulkhead policies inline with full per-edge accounting
        # (_inline_resilient feeds the same breaker windows and budgets as
        # the carrier path — the PR 7 breaker-aware fast path); only a
        # mailbox bound makes inlining step aside entirely, because an
        # inlined call bypasses the destination queue that bound is
        # leveling.  A policy-free app (or a bare default-deadline policy)
        # takes the zero-bookkeeping plain path: deadlines ride the ambient
        # propagation the interpreters already do.
        self._inline_rpc_ok = (resilience is None
                               or resilience.mailbox_bound is None)
        self._inline_plain = resilience is None or (
            not resilience.breakers and resilience.retry is None
            and resilience.bulkhead is None)
        self.services: Dict[str, Service] = {}
        self.offload_pool = OffloadPool(offload_threads)
        self._started = False
        # resilience machinery: app-wide counters, per-destination breakers
        # and bulkheads, a retry token bucket, and one kernel-timer thread
        # for backoff firings and pool-suspend deadline expiries (lazily
        # started).
        self._res_stats = ResilienceStats()
        # per-EDGE resilience state, keyed (dest, method): a sick write
        # path must not take the healthy read path of the same service
        # down with it (PR 8 — previously keyed by bare dest).
        self._breakers: Dict[Tuple[str, str], CircuitBreaker] = {}
        self._breaker_lock = threading.Lock()
        self._bulkheads: Dict[Tuple[str, str], Bulkhead] = {}
        self._retry_budget: Optional[RetryBudget] = (
            RetryBudget(resilience.retry)
            if resilience is not None and resilience.retry is not None
            else None)
        self._timer = TimerThread()
        # Sharded-backend routing policy: True pins requests to shards by
        # their RequestContext session (deterministic across trials and
        # restarts); False falls back to the synthetic per-executor ticket.
        # Read at deliver time so an A/B probe can flip it between trials.
        self.shard_by_session = True
        # App-wide cache-tier counters (fed by the apps' cache service via
        # svc.app.cache_stats; surfaced in backend_stats as cache_hits /
        # cache_misses).  Created unconditionally — two itertools.counts.
        from .metrics import CacheStats
        self.cache_stats = CacheStats()
        # futures of requests a load-generation trial abandoned at sever
        # time; the next trial settles on them before snapshotting stats
        # (see loadgen.run_trial).
        self._loadgen_leftovers: List[Future] = []
        # optional deterministic fault-injection plan (repro.core.faults);
        # consulted by Service.deliver and the inline fast path, armed by
        # loadgen.run_trial on the trial clock.
        self.fault_plan: Optional[FaultPlan] = None

    def set_faults(self, plan: Optional[FaultPlan]) -> None:
        """Install a :class:`~repro.core.faults.FaultPlan` (or clear it with
        ``None``).  A replaced plan is disarmed first, settling any replies
        it blackholed so their waiters are never orphaned."""
        old = self.fault_plan
        if old is not None and old is not plan:
            old.disarm()
        self.fault_plan = plan
        if plan is not None:
            plan.bind(self)

    # ------------------------------------------------------------- wiring
    def add_service(self, spec: ServiceSpec) -> Service:
        """Register and build one service from its spec (before start())."""
        if spec.name in self.services:
            raise ValueError(f"duplicate service {spec.name!r}")
        svc = Service(self, spec, spec.backend or self.default_backend)
        self.services[spec.name] = svc
        return svc

    def start(self) -> None:
        """Idempotent; a stopped app can be started again (the benchmark
        harnesses re-enter one App as a context manager between sweeps)."""
        if self._started:
            return
        from .calibrate import iters_per_second
        iters_per_second()  # calibrate the Compute burn before serving
        self.offload_pool.start()
        for svc in self.services.values():
            svc.executor.start()
        self._started = True

    def stop(self) -> None:
        """Idempotent: a double stop() must not re-join executors or poison
        the offload pool with extra shutdown sentinels.

        Shutdown-ordering contract (audited by the PR 10 sanitizer's
        lock-order / future-leak rules):

        1. ``_started = False`` — new sends fail fast;
        2. settle blackholed replies while schedulers still run (their
           done-callbacks may resume parked waiters);
        3. stop executors, then the offload pool;
        4. drain the kernel timer with ``fire_pending=True`` — a pending
           retry backoff fires early, observes the stopped app and fails
           the reply it owes.  Dropping it (the pre-PR-10 behaviour)
           orphaned the caller: a leaked, waited-but-never-set future.
        """
        if not self._started:
            return
        h = instrument.hooks
        self._started = False  # send() fails fast while teardown runs
        if self.fault_plan is not None:
            # settle blackholed replies *before* the executors stop: their
            # done-callbacks may resume parked waiters, which needs live
            # schedulers.  No orphaned waiters survive teardown (same
            # discipline as the loadgen leftovers).
            if h is not None:
                h.stop_phase(self, "settle_blackholed")
            self.fault_plan.settle_blackholed()
        if h is not None:
            h.stop_phase(self, "executor_stop")
        for svc in self.services.values():
            svc.executor.stop()
        if h is not None:
            h.stop_phase(self, "offload_stop")
        self.offload_pool.stop()
        if h is not None:
            h.stop_phase(self, "timer_stop")
        self._timer.stop(fire_pending=True)

    def __enter__(self) -> "App":
        self.start()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.stop()

    # ---------------------------------------------------------- transport
    def send(self, dest: str, method: str, payload: Any = None, *,
             ctx: Optional[RequestContext] = None,
             deadline: Optional[float] = None) -> Future:
        """Enqueue an RPC at ``dest``; returns the reply future.
        Thread-safe; callable from any thread (incl. the load generator).

        ``ctx`` is the request's :class:`~repro.core.context.
        RequestContext` — session identity (shard pinning), absolute
        deadline, hop depth, trace id — threaded to every downstream hop.
        ``deadline`` is the legacy kwarg, kept as a back-compat shim: it
        is folded into the context (tightening any deadline already
        there).  With no context, no deadline and no resilience policy
        this is the original zero-overhead path — nothing is allocated
        beyond the reply future."""
        if deadline is not None:
            ctx = _ctx_with_deadline(
                ctx, min_deadline(ctx.deadline, deadline)
                if ctx is not None else deadline)
        if self.resilience is None and (ctx is None or ctx.deadline is None):
            reply = Future()
            if not self._started:
                # fail fast: a delivery into a stopped app would sit in a
                # dead executor's mailbox and hang any blocking waiter
                reply.set_exception(RuntimeError(
                    f"App is not started; cannot send {dest}.{method} "
                    f"(start() it, or use it as a context manager)"))
                return reply
            svc = self.services.get(dest)
            if svc is None:
                reply.set_exception(KeyError(f"no service {dest!r}"))
                return reply
            svc.deliver(method, payload, reply, ctx)
            return reply
        return self._send_resilient(dest, method, payload, ctx)

    def _breaker(self, dest: str, method: str) -> CircuitBreaker:
        """Per-edge circuit breaker, keyed ``(dest, method)`` and created
        on first use (shared by the carrier send path and the inline fast
        path — one window per edge, whichever mechanism exercised it)."""
        key = (dest, method)
        br = self._breakers.get(key)
        if br is None:
            with self._breaker_lock:
                br = self._breakers.get(key)
                if br is None:
                    br = self.resilience.make_breaker()
                    self._breakers[key] = br
        return br

    def _bulkhead(self, dest: str, method: str) -> Bulkhead:
        """Per-edge bulkhead, keyed ``(dest, method)``, created on first
        use (same sharing contract as :meth:`_breaker`: inlined and
        carrier attempts draw from one slot pool)."""
        key = (dest, method)
        bh = self._bulkheads.get(key)
        if bh is None:
            with self._breaker_lock:
                bh = self._bulkheads.get(key)
                if bh is None:
                    bh = Bulkhead(self.resilience.bulkhead)
                    self._bulkheads[key] = bh
        return bh

    def resilience_by_edge(self) -> Dict[Tuple[str, str], Dict[str, int]]:
        """Per-edge resilience report: ``{(dest, method): {"opens": ...,
        "bulkhead_inflight": ...}}`` for every edge that has seen policy
        traffic (breaker window or bulkhead slot pool created)."""
        report: Dict[Tuple[str, str], Dict[str, int]] = {}
        for key, br in self._breakers.items():
            report.setdefault(key, {})["opens"] = br.opens
        for key, bh in self._bulkheads.items():
            report.setdefault(key, {})["bulkhead_inflight"] = bh.inflight
        return report

    def _send_resilient(self, dest: str, method: str, payload: Any,
                        ctx: Optional[RequestContext]) -> Future:
        """Policy-wrapped send: default deadline stamping, per-destination
        circuit breaker + bulkhead, budgeted retry-with-jittered-backoff.

        The outer ``reply`` future is resolved exactly once, by whichever
        attempt concludes the call; each attempt uses its own inner future,
        so a late reply from a superseded attempt can never double-resolve
        the caller's join (single-writer discipline preserved)."""
        pol = self.resilience
        reply = Future()
        if not self._started:
            reply.set_exception(RuntimeError(
                f"App is not started; cannot send {dest}.{method} "
                f"(start() it, or use it as a context manager)"))
            return reply
        svc = self.services.get(dest)
        if svc is None:
            reply.set_exception(KeyError(f"no service {dest!r}"))
            return reply
        deadline = ctx.deadline if ctx is not None else None
        if (deadline is None and pol is not None
                and pol.deadline is not None):
            deadline = time.monotonic() + pol.deadline
        if deadline is not None and time.monotonic() >= deadline:
            self._res_stats.timeout()
            reply.set_exception(DeadlineExceeded(
                f"{dest}.{method}: deadline already expired at send"))
            return reply
        ctx = _ctx_with_deadline(ctx, deadline)
        breaker = (self._breaker(dest, method)
                   if pol is not None and pol.breakers else None)
        if breaker is not None and not breaker.allow():
            reply.set_exception(CircuitOpenError(
                f"{dest}.{method}: circuit open, failing fast"))
            return reply
        bulkhead = (self._bulkhead(dest, method)
                    if pol is not None and pol.bulkhead is not None else None)
        self._drive_attempts(svc, method, payload, ctx, breaker,
                             bulkhead, reply, [0])
        return reply

    def _drive_attempts(self, svc: Service, method: str, payload: Any,
                        ctx: Optional[RequestContext],
                        breaker: Optional[CircuitBreaker],
                        bulkhead: Optional[Bulkhead], reply: Future,
                        attempts: List[int],
                        first: Optional[Future] = None,
                        prefail: Optional[BaseException] = None) -> None:
        """Attempt loop shared by the carrier send path and the inline fast
        path: launch (or adopt) attempts against ``svc`` until one
        concludes the outer ``reply``.

        ``attempts`` is the launched-attempt count (a one-element list so
        closures can bump it); ``first`` is an already-launched attempt to
        adopt — the inline fast path hands over its in-flight (or failed)
        first attempt here with ``attempts == [1]``, so retry accounting is
        identical whether attempt #1 was inlined or mailbox-delivered.
        ``prefail`` seeds the loop with a first-attempt failure that must
        NOT be recorded as breaker evidence (a bulkhead rejection: the edge
        was never exercised) but may still be retried.
        Retries always go through ``svc.deliver`` (never re-inline): the
        backoff timer fires on the kernel :class:`TimerThread`, which is
        not a scheduler thread, and the mailbox path is valid from any
        thread.  Breaker/budget outcomes are recorded per *attempt*, so
        the breaker window sees the same sequence either way."""
        pol = self.resilience
        retry = pol.retry if pol is not None else None
        dest = svc.name
        deadline = ctx.deadline if ctx is not None else None

        def launch() -> None:
            attempts[0] += 1
            if bulkhead is not None and not bulkhead.try_acquire():
                # caller-side admission: the edge was never exercised, so
                # this is neither breaker evidence nor a mailbox rejection
                # — release any half-open probe slot and retry-or-fail.
                self._res_stats.bulkhead_rejection()
                if breaker is not None:
                    breaker.abort_probe()
                fail(Rejected(f"{dest}.{method}: bulkhead full "
                              f"({bulkhead.limit} attempts in flight)"))
                return
            inner = Future()
            if bulkhead is not None:
                # registered before on_done so a retry scheduled from
                # on_done always sees this attempt's slot already freed
                inner.add_done_callback(bulkhead.release)
            inner.add_done_callback(on_done)
            svc.deliver(method, payload, inner, ctx)

        def on_done(f: Future) -> None:
            try:
                value = f.result()
            except CircuitOpenError as exc:
                # a *downstream* edge failed fast; propagate without
                # recording a failure here (don't cascade trips) and
                # without retrying into a known-open circuit.  If this
                # attempt was a half-open probe, release the slot — the
                # edge itself was never exercised (see abort_probe).
                if breaker is not None:
                    breaker.abort_probe()
                reply.set_exception(exc)
                return
            except BaseException as exc:
                if breaker is not None:
                    breaker.record(False)
                fail(exc)
                return
            if breaker is not None:
                breaker.record(True)
            if self._retry_budget is not None:
                self._retry_budget.credit()
            reply.set_result(value)

        def fail(exc: BaseException) -> None:
            """Conclude a failed attempt: schedule a backoff retry when the
            policy and budget allow, else resolve ``reply`` with ``exc``."""
            delay = _retry_delay(exc)
            if delay is None:
                reply.set_exception(exc)
                return
            self._res_stats.retry()
            self._timer.push(time.monotonic() + delay, retry_fire)

        def _retry_delay(exc: BaseException) -> Optional[float]:
            """Backoff before the next attempt, or None for no retry.
            Deadline expiry is never retried (the attempt consumed the
            whole budget); the token bucket caps amplification."""
            if retry is None or isinstance(exc, DeadlineExceeded):
                return None
            if attempts[0] >= retry.max_attempts:
                return None
            delay = retry.backoff_for(attempts[0])
            if (deadline is not None
                    and time.monotonic() + delay >= deadline):
                return None
            if not self._retry_budget.try_spend():
                return None
            return delay

        def retry_fire() -> None:
            if not self._started:
                reply.set_exception(RuntimeError(
                    f"App stopped while retrying {dest}.{method}"))
                return
            if breaker is not None and not breaker.allow():
                reply.set_exception(CircuitOpenError(
                    f"{dest}: circuit opened during backoff, failing fast"))
                return
            launch()

        if prefail is not None:
            fail(prefail)
        elif first is not None:
            first.add_done_callback(on_done)
        else:
            launch()

    # ------------------------------------------------ zero-handoff admission
    def _inline_call(self, dest: str, method: str, payload: Any,
                     ctx: Optional[RequestContext],
                     drive: Callable[[Generator, Optional[RequestContext]],
                                     Future]
                     ) -> Optional[Future]:
        """Tier-1 fast-path admission: run ``dest.method`` as a direct
        continuation of the calling scheduler, with full policy accounting.

        ``drive`` is the calling interpreter's ``_inline_drive`` — it owns
        the scheduler-side bookkeeping (inline counters, ambient deadline)
        and runs the handler generator up to its first suspension point.
        Returns None when the call cannot inline (unknown service, thread-
        family callee, or no inlineable handler); the interpreter then
        falls back to carrier elision via :meth:`send`.  The depth budget
        is the interpreter's to check — it is per-scheduler state."""
        svc = self.services.get(dest)
        if svc is None:
            return None
        handler = svc.inline_handler(method)
        if handler is None:
            return None
        if self._inline_plain:
            plan = self.fault_plan
            if plan is not None:
                action = plan.intercept(dest, method)
                if action is not None:
                    if action[0] == "wrap":
                        svc.count_request()
                        return drive(faulted_handler(handler(svc, payload),
                                                     action[1], action[2]),
                                     ctx)
                    if action[0] == "hang":
                        fut = Future()
                        plan.blackhole(fut)
                        return fut
                    return CompletedFuture(exc=action[1])
            # no per-edge policy bookkeeping: the pre-PR-6 path, bit-for-bit
            svc.count_request()
            return drive(handler(svc, payload), ctx)
        return self._inline_resilient(svc, handler, method, payload,
                                      ctx, drive)

    def _inline_resilient(self, svc: Service,
                          handler: Callable[..., Generator], method: str,
                          payload: Any, ctx: Optional[RequestContext],
                          drive: Callable[[Generator,
                                           Optional[RequestContext]],
                                          Future]) -> Future:
        """Breaker-aware inlining: the zero-handoff fast path under a
        breakers/retry/bulkhead policy (PR 7).

        The policy checks mirror :meth:`_send_resilient` *before* the
        handler runs — default-deadline stamping, ``CircuitBreaker.allow``
        (an open edge fails fast without running anything), bulkhead slot
        acquisition — and the attempt's outcome is recorded into the same
        per-edge breaker window and retry budget the carrier path feeds,
        so inline-on vs inline-off produces identical breaker decisions
        for the same fault script (tests/test_inline_resilience.py).

        The hot path — attempt completes synchronously and succeeds —
        returns the callee's :class:`~repro.core.future.CompletedFuture`
        as-is after a ``record(True)``/``credit()``: no reply future, no
        closures, no timer.  Failures and suspended attempts hand off to
        :meth:`_drive_attempts` with ``attempts=[1]``; retries go through
        the mailbox (never re-inline — see ``_drive_attempts``)."""
        pol = self.resilience
        deadline = ctx.deadline if ctx is not None else None
        if deadline is None and pol.deadline is not None:
            deadline = time.monotonic() + pol.deadline
            ctx = _ctx_with_deadline(ctx, deadline)
        breaker = self._breaker(svc.name, method) if pol.breakers else None
        if breaker is not None and not breaker.allow():
            return CompletedFuture(exc=CircuitOpenError(
                f"{svc.name}.{method}: circuit open, failing fast"))
        bulkhead = self._bulkhead(svc.name, method) \
            if pol.bulkhead is not None else None
        if bulkhead is not None and not bulkhead.try_acquire():
            # the edge was never exercised: no breaker evidence (but free a
            # half-open probe slot), count it, and let the shared attempt
            # loop decide retry-or-fail exactly like a carrier-path attempt
            self._res_stats.bulkhead_rejection()
            if breaker is not None:
                breaker.abort_probe()
            exc = Rejected(f"{svc.name}.{method}: bulkhead full "
                           f"({bulkhead.limit} attempts in flight)")
            if pol.retry is None:
                return CompletedFuture(exc=exc)
            reply = Future()
            self._drive_attempts(svc, method, payload, ctx, breaker,
                                 bulkhead, reply, [1], prefail=exc)
            return reply
        attempt: Optional[Future] = None
        plan = self.fault_plan
        if plan is not None:
            action = plan.intercept(svc.name, method)
            if action is not None:
                # mirror the carrier path: the faulted attempt is adopted by
                # _drive_attempts below, so it feeds the same breaker window
                # and retry budget as a mailbox-delivered fault would
                if action[0] == "wrap":
                    svc.count_request()
                    attempt = drive(faulted_handler(handler(svc, payload),
                                                    action[1], action[2]),
                                    ctx)
                elif action[0] == "hang":
                    attempt = Future()
                    plan.blackhole(attempt)
                else:
                    attempt = CompletedFuture(exc=action[1])
        if attempt is None:
            svc.count_request()
            attempt = drive(handler(svc, payload), ctx)
        if bulkhead is not None:
            attempt.add_done_callback(bulkhead.release)
        if attempt.done and attempt.exception() is None:
            # hot path: the inlined callee completed without suspending
            if breaker is not None:
                breaker.record(True)
            if self._retry_budget is not None:
                self._retry_budget.credit()
            return attempt
        # slow path: the attempt suspended (resolve later) or failed —
        # adopt it into the shared attempt loop for breaker recording and
        # possible mailbox-path retries
        reply = Future()
        self._drive_attempts(svc, method, payload, ctx, breaker,
                             bulkhead, reply, [1], first=attempt)
        return reply

    def rpc_carrier(self, dest: str, method: str, payload: Any,
                    ctx: Optional[RequestContext] = None) -> Generator:
        """The generator every async-call carrier runs: client-side network
        latency, send, block on reply.  Interpreted by a kernel thread
        (thread backend) or a fiber (fiber backend).  ``ctx`` is the hop's
        already-derived :class:`RequestContext` (deadline tightened by the
        interpreter via ``RequestContext.hop``)."""
        if self.net_latency > 0:
            yield Sleep(self.net_latency)
        reply = self.send(dest, method, payload, ctx=ctx)
        value = yield Wait(reply)
        return value

    def offload(self, fn: Callable[..., Any], *args: Any) -> Future:
        """Run a blocking callable on the shared offload pool."""
        return self.offload_pool.submit(fn, *args)

    # ------------------------------------------------------ instrumentation
    def total_spawns(self) -> int:
        """Carrier spawns across all services (the paper's cost driver)."""
        return sum(s.executor.spawns for s in self.services.values())

    def backend_stats(self) -> "BackendStats":
        """App-wide executor counters: sums across services, except gauges
        (queue-depth high-water) which take the max."""
        from .metrics import BackendStats
        agg = BackendStats()
        for s in self.services.values():
            agg.add(s.executor.stats())
        agg.timeouts = self._res_stats.timeouts
        agg.retries = self._res_stats.retries
        agg.rejections = self._res_stats.rejections
        agg.bulkhead_rejections = self._res_stats.bulkhead_rejections
        agg.breaker_opens = sum(b.opens for b in self._breakers.values())
        agg.cache_hits = self.cache_stats.hits
        agg.cache_misses = self.cache_stats.misses
        if self.fault_plan is not None:
            fs = self.fault_plan.stats
            agg.faults_injected = fs.injected
            agg.faults_latency = fs.get("latency")
            agg.faults_error = fs.get("error")
            agg.faults_hang = fs.get("hang")
            agg.faults_brownout = fs.get("brownout")
            agg.faults_crash = fs.get("crash")
        return agg
