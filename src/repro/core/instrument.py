"""Instrumentation seam for the concurrency sanitizer (``repro.analysis``).

``hooks`` is a **module-level hook table**: ``None`` in production, an
object with event methods when a sanitizer is attached.  Every call site in
``repro.core`` guards with a single branch::

    h = instrument.hooks
    if h is not None:
        h.future_set(fut)

so the disabled cost is one module-attribute load plus one ``is not None``
test — no indirection, no allocation, no lock.  The rpc_path micro bench
carries a paired probe (:func:`benchmarks.bench_rpc_path.measure_rpc_cost`
with ``hooks`` on/off) proving the seam stays inside the noise band when
off.

The event vocabulary is the :class:`Hooks` base class below; all methods
are no-ops so a subscriber overrides only what it consumes.  Events are
emitted **on the thread where the action happens** — subscribers derive
carrier identity from ``threading.get_ident()`` and must be thread-safe.

Design rules for call sites (keep the fast path honest):

* never emit from the zero-handoff inline path's per-call loop — inline
  calls synchronize nothing, so there is no edge to record;
* blocking/parking sites may emit freely (they already pay kernel sync);
* per-event payloads are existing objects (no tuples built when disabled).

This module is a leaf: it imports nothing from ``repro.core`` so every
core module can import it without cycles, and it keeps ``repro.core``
importable without ``repro.analysis`` (the analysis package depends on
core, never the reverse).
"""
from __future__ import annotations

from typing import Any, Optional

#: The hook table.  ``None`` (the overwhelmingly common case) disables the
#: seam; :func:`install` swaps in a :class:`Hooks` subclass.
hooks: Optional["Hooks"] = None


class Hooks:
    """No-op event sink; subclass and override the events you consume.

    One method per seam event.  Grouped by emitting module:

    ``repro.core.future``
        :meth:`future_set`, :meth:`future_block`, :meth:`future_unblock`
    ``repro.core.fiber``
        :meth:`fiber_spawn`, :meth:`fiber_park`, :meth:`fiber_resume`,
        :meth:`fiber_steal`, :meth:`sched_loop`, :meth:`queue_put`,
        :meth:`queue_take`, :meth:`ring_submit`, :meth:`ring_drain`
    ``repro.core.eventloop``
        :meth:`loop_spawn`, :meth:`queue_put`, :meth:`queue_take`,
        :meth:`sched_loop`, :meth:`shard_handoff`
    ``repro.core.timers``
        :meth:`timer_arm`, :meth:`timer_fire`, :meth:`timer_cancel`
    ``repro.core.executor``
        :meth:`carrier_start`, :meth:`carrier_stop`, :meth:`ring_submit`,
        :meth:`ring_drain`
    ``repro.core.service`` / ``repro.core.loadgen`` / ``repro.core.metrics``
        :meth:`stop_phase`, :meth:`trial_sever`, :meth:`recorder_write`,
        :meth:`recorder_summary`
    anyone (self-tests, lock proxies)
        :meth:`lock_acquire`, :meth:`lock_release`, :meth:`access`
    """

    # ------------------------------------------------------------- futures
    def future_set(self, fut: Any) -> None:
        """``fut`` just resolved (value/exception published)."""

    def future_block(self, fut: Any, timeout: Optional[float]) -> None:
        """A thread is about to *block* on ``fut`` (kernel wait)."""

    def future_unblock(self, fut: Any, done: bool) -> None:
        """A blocking wait on ``fut`` returned (``done=False`` = timeout)."""

    def future_join(self, fut: Any) -> None:
        """A cooperative carrier parked a continuation on ``fut``."""

    # -------------------------------------------------------------- fibers
    def fiber_spawn(self, sched: Any, fib: Any) -> None:
        """``fib`` (with its carrier ``fib.future``) queued on ``sched``."""

    def fiber_park(self, sched: Any, fib: Any) -> None:
        """``fib`` suspended awaiting futures/timers."""

    def fiber_resume(self, sched: Any, fib: Any) -> None:
        """``fib`` re-enqueued for execution."""

    def fiber_steal(self, victim: Any, thief: Any, n: int) -> None:
        """``thief`` stole ``n`` ready fibers from ``victim``."""

    def sched_loop(self, sched: Any) -> None:
        """A scheduler run loop claimed the current thread as its carrier."""

    # --------------------------------------------- run/injection queues
    def queue_put(self, obj: Any) -> None:
        """Work posted to ``obj``'s cross-thread queue (release edge)."""

    def queue_take(self, obj: Any) -> None:
        """``obj``'s owner drained its cross-thread queue (acquire edge)."""

    # ---------------------------------------------------------- event loop
    def loop_spawn(self, loop: Any, fut: Any) -> None:
        """A continuation producing ``fut`` was created on ``loop``."""

    def shard_handoff(self, loop: Any, shard: int) -> None:
        """A request was routed to shard ``shard`` of ``loop``."""

    # -------------------------------------------------------------- timers
    def timer_arm(self, owner: Any, deadline: float) -> None:
        """A timer entry became pending on ``owner``."""

    def timer_fire(self, owner: Any, n: int) -> None:
        """``owner`` popped ``n`` due entries."""

    def timer_cancel(self, owner: Any, n: int) -> None:
        """``owner`` dropped ``n`` pending entries without firing them."""

    # ------------------------------------------------------ carriers/rings
    def carrier_start(self, owner: Any, name: str) -> None:
        """``owner`` spawned carrier thread ``name``."""

    def carrier_stop(self, owner: Any) -> None:
        """``owner`` finished joining its carrier threads."""

    def ring_submit(self, ring: Any) -> None:
        """An entry was appended to a submission/completion ring."""

    def ring_drain(self, ring: Any, n: int, reason: str) -> None:
        """``n`` entries left ``ring`` (``reason``: size/timeout/idle/...)."""

    # -------------------------------------------------- app/trial protocol
    def stop_phase(self, app: Any, phase: str) -> None:
        """``App.stop`` entered the named shutdown phase."""

    def trial_sever(self, recorder: Any) -> None:
        """A load-gen trial severed late completions from ``recorder``."""

    def recorder_write(self, recorder: Any) -> None:
        """A latency sample/error landed in ``recorder``."""

    def recorder_summary(self, recorder: Any) -> None:
        """``recorder``'s summary statistics were read."""

    # ------------------------------------------- generic sanitizer surface
    def lock_acquire(self, key: Any) -> None:
        """The current thread acquired the lock identified by ``key``."""

    def lock_release(self, key: Any) -> None:
        """The current thread released the lock identified by ``key``."""

    def access(self, key: Any, write: bool) -> None:
        """The current thread touched shared state ``key`` (race check)."""


def install(h: Hooks) -> None:
    """Attach a hook table (replacing any previous one)."""
    global hooks
    hooks = h


def uninstall() -> None:
    """Detach the hook table; the seam reverts to the single dead branch."""
    global hooks
    hooks = None
