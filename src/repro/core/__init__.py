"""repro.core — async-RPC substrate with thread and fiber backends.

The paper's contribution (fiber-based asynchronous RPC) as a composable
library: write service handlers once as effect generators, choose the
execution backend per service.
"""
from .context import RequestContext, session_key
from .effects import (AsyncRpc, Compute, CurrentContext, Offload, Sleep,
                      SpawnLocal, Wait, WaitAll, sync_rpc)
from .executor import BACKEND_FACTORIES, BACKEND_NAMES, make_executor
from .faults import (FaultPlan, FaultRule, FaultStats, InjectedFault,
                     ServiceCrashed)
from .future import CompletedFuture, Future, Once
from .loadgen import (OverloadResult, RequestFactory, find_peak_throughput,
                      latency_sweep, run_overload, run_trial, warmup)
from .metrics import BackendStats, LatencyRecorder, PeakResult, TrialResult
from .resilience import (Bulkhead, CircuitBreaker, CircuitOpenError,
                         DeadlineExceeded, Rejected, ResiliencePolicy,
                         RetryBudget, RetryPolicy)
from .service import App, Service, ServiceSpec

__all__ = [
    "App", "Service", "ServiceSpec", "Future", "CompletedFuture", "Once",
    "AsyncRpc", "Wait", "WaitAll", "Sleep", "Compute", "Offload",
    "SpawnLocal", "CurrentContext", "sync_rpc",
    "RequestContext", "session_key",
    "BACKEND_FACTORIES", "BACKEND_NAMES", "make_executor",
    "run_trial", "find_peak_throughput", "latency_sweep", "warmup",
    "run_overload", "OverloadResult", "RequestFactory",
    "LatencyRecorder", "TrialResult", "PeakResult",
    "DeadlineExceeded", "CircuitOpenError", "Rejected",
    "RetryPolicy", "RetryBudget", "CircuitBreaker", "Bulkhead",
    "ResiliencePolicy",
    "FaultPlan", "FaultRule", "FaultStats", "InjectedFault",
    "ServiceCrashed",
]
