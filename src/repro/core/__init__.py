"""repro.core — async-RPC substrate with thread and fiber backends.

The paper's contribution (fiber-based asynchronous RPC) as a composable
library: write service handlers once as effect generators, choose the
execution backend per service.
"""
from .effects import (AsyncRpc, Compute, Offload, Sleep, SpawnLocal, Wait,
                      WaitAll, sync_rpc)
from .executor import BACKEND_FACTORIES, BACKEND_NAMES, make_executor
from .future import CompletedFuture, Future
from .loadgen import (RequestFactory, find_peak_throughput, latency_sweep,
                      run_trial, warmup)
from .metrics import BackendStats, LatencyRecorder, PeakResult, TrialResult
from .service import App, Service, ServiceSpec

__all__ = [
    "App", "Service", "ServiceSpec", "Future", "CompletedFuture",
    "AsyncRpc", "Wait", "WaitAll", "Sleep", "Compute", "Offload",
    "SpawnLocal", "sync_rpc",
    "BACKEND_FACTORIES", "BACKEND_NAMES", "make_executor",
    "run_trial", "find_peak_throughput", "latency_sweep", "warmup",
    "RequestFactory",
    "LatencyRecorder", "TrialResult", "PeakResult",
]
