"""Backend-agnostic futures with a lock-free fast path.

A :class:`Future` is the join point between the execution backends the
paper compares:

* **threads** (DeathStarBench ``std::async`` default policy): a kernel thread
  blocks on :meth:`Future.wait` via a condition variable;
* **fibers / event loops** (``boost::fiber::async``): a cooperative carrier
  registers a *callback* that re-enqueues it on its scheduler's ready queue —
  no kernel involvement.

The same object supports both, so a request can traverse services running on
different backends (the paper's "replace the affected services one by one"
migration story).

The zero-handoff fast path (PR 4) makes the cooperative side genuinely
kernel-free: the ``threading.Condition`` is **lazy**, materialized only when
the first *blocking* waiter shows up (:meth:`wait` / :meth:`wait_done`).
Resolution publishes value-then-``_done``-flag — single attribute stores,
atomic and ordered under the GIL — so ``set_result`` on the happy path is a
couple of attribute writes and a callback drain, with no lock acquire and no
kernel synchronization object ever allocated.  Futures follow a
**single-writer** discipline (each is resolved by exactly one completion
site); the double-resolve check is exact for a sequential double-set and
best-effort under a racing one.

:class:`CompletedFuture` is the degenerate case for inline calls: born
resolved, it never allocates even the callback list.
"""
from __future__ import annotations

import itertools
import threading
from typing import Any, Callable, List, Optional

from . import instrument

# Guards only the one-time materialization of a future's Condition (two
# blocking waiters racing to create it).  Shared module-wide because the
# blocking-wait path is already paying a kernel sync; the cooperative fast
# path never touches it.
_COND_LOCK = threading.Lock()


class FutureError(RuntimeError):
    """Misuse of a Future (e.g. resolving an already-resolved one)."""


class Future:
    """A write-once result slot with thread-safe blocking *and* callback waits."""

    __slots__ = ("_done", "_value", "_exc", "_exc_tb", "_callbacks", "_cond")

    def __init__(self) -> None:
        self._done = False
        self._value: Any = None
        self._exc: Optional[BaseException] = None
        self._exc_tb = None  # traceback snapshot taken at set_exception time
        self._callbacks: List[Callable[["Future"], None]] = []
        self._cond: Optional[threading.Condition] = None

    # ---------------------------------------------------------------- write
    def set_result(self, value: Any) -> None:
        """Resolve with ``value``; fires callbacks and wakes blocked waiters."""
        if self._done:
            raise FutureError("Future already resolved")
        self._value = value
        self._done = True  # publish: GIL orders the value store before this
        h = instrument.hooks
        if h is not None:
            h.future_set(self)
        self._on_resolved()

    def set_exception(self, exc: BaseException) -> None:
        """Resolve with ``exc``; every waiter re-raises it."""
        if self._done:
            raise FutureError("Future already resolved")
        self._exc = exc
        # Snapshot the traceback as resolved.  Every re-raise (wait/result,
        # possibly one per waiter) restores this snapshot first: a bare
        # `raise exc` would instead *extend* the shared exc.__traceback__
        # with the raising frames each time it is caught, so concurrent
        # waiters would mutate each other's tracebacks and a wait->catch->
        # wait loop would grow the chain without bound.
        self._exc_tb = exc.__traceback__
        self._done = True
        h = instrument.hooks
        if h is not None:
            h.future_set(self)
        self._on_resolved()

    def _on_resolved(self) -> None:
        # `_done` was set *before* this read, so a waiter that materializes
        # the Condition after we read None here will see `_done` already
        # True in its wait_for predicate and never park — no lost wakeup.
        cond = self._cond
        if cond is not None:
            with cond:
                cond.notify_all()
        self._drain_callbacks()

    def _drain_callbacks(self) -> None:
        # list.pop(0) is atomic under the GIL, so the resolver and a
        # registrar that lost the append-vs-resolve race can both drain:
        # each callback is popped (and therefore fired) exactly once, in
        # registration order.
        cbs = self._callbacks
        while cbs:
            try:
                cb = cbs.pop(0)
            except IndexError:
                return
            cb(self)

    # ----------------------------------------------------------------- read
    @property
    def done(self) -> bool:
        """True once resolved (lock-free read; safe from any thread)."""
        return self._done

    def blocking_waited(self) -> bool:
        """True iff some waiter materialized the kernel Condition — the
        executors' ``fast_futures``/``slow_futures`` classification."""
        return self._cond is not None

    def _materialize_cond(self) -> threading.Condition:
        cond = self._cond
        if cond is None:
            with _COND_LOCK:
                cond = self._cond
                if cond is None:
                    cond = self._cond = threading.Condition()
        return cond

    def wait(self, timeout: Optional[float] = None) -> Any:
        """Blocking get — the *thread* backend's join. Re-raises exceptions."""
        if not self._done:
            h = instrument.hooks
            if h is not None:
                h.future_block(self, timeout)
            cond = self._materialize_cond()
            with cond:
                done = cond.wait_for(lambda: self._done, timeout=timeout)
            if h is not None:
                h.future_unblock(self, done)
            if not done:
                raise TimeoutError("Future.wait timed out")
        if self._exc is not None:
            # re-raise from the stored snapshot so multi-waiter re-raises
            # never compound each other's frames (see set_exception)
            raise self._exc.with_traceback(self._exc_tb)
        return self._value

    def wait_done(self, timeout: Optional[float] = None) -> bool:
        """Block until resolved or timeout; returns done-ness and never
        (re-)raises the stored exception — for waiters that only need the
        completion *event* (e.g. a pool thread deciding whether it can stop
        work-helping), not the value."""
        if self._done:
            return True
        h = instrument.hooks
        if h is not None:
            h.future_block(self, timeout)
        cond = self._materialize_cond()
        with cond:
            done = cond.wait_for(lambda: self._done, timeout=timeout)
        if h is not None:
            h.future_unblock(self, done)
        return done

    def exception(self) -> Optional[BaseException]:
        """Non-raising outcome peek: the stored exception of a *resolved*
        future, or None (success, or not yet resolved — check :attr:`done`
        first).  The resilience layer's inline fast path uses this to
        classify a completed attempt without paying a raise/except cycle
        on every successful call."""
        return self._exc

    def result(self) -> Any:
        """Non-blocking get; raises if not done."""
        if not self._done:
            raise FutureError("Future not resolved yet")
        if self._exc is not None:
            raise self._exc.with_traceback(self._exc_tb)
        return self._value

    def add_done_callback(self, cb: Callable[["Future"], None]) -> None:
        """The cooperative backends' join: cb fires immediately if already
        done, else exactly once on resolution (possibly from another
        thread)."""
        if self._done:
            cb(self)
            return
        self._callbacks.append(cb)
        if self._done:
            # lost the append-vs-resolve race: the resolver may have drained
            # before our append landed, so drain whatever is left ourselves
            self._drain_callbacks()


class CompletedFuture(Future):
    """A future born resolved — the zero-handoff inline-call result.

    Allocates neither a Condition nor a callback list; every accessor takes
    the already-done fast path, so handing one to a caller costs a single
    tiny object construction."""

    __slots__ = ()

    def __init__(self, value: Any = None,
                 exc: Optional[BaseException] = None) -> None:
        self._done = True
        self._value = value
        self._exc = exc
        self._exc_tb = exc.__traceback__ if exc is not None else None
        self._callbacks = ()  # type: ignore[assignment]  # never appended to
        self._cond = None


class Once:
    """First-writer-wins claim ticket for completion-vs-deadline races.

    When a parked continuation can be resumed by *either* a future's done
    callback or a timer-armed deadline expiry, both sides call ``claim()``
    and only the winner acts; the loser's wheel entry or callback becomes a
    no-op.  The future itself keeps its single-writer discipline — the
    resumed generator remains the only thing that resolves the reply.
    ``itertools.count`` makes the claim a single C-level operation under
    the GIL (the same lost-update-free idiom as the executors' tickets).
    """

    __slots__ = ("_ticket",)

    def __init__(self) -> None:
        self._ticket = itertools.count()

    def claim(self) -> bool:
        """True exactly once, across any number of racing callers."""
        return next(self._ticket) == 0


def all_done(futures: List[Future]) -> bool:
    """True when every future in the list has resolved."""
    return all(f.done for f in futures)
