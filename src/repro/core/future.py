"""Backend-agnostic futures.

A :class:`Future` is the join point between the two execution backends the
paper compares:

* **threads** (DeathStarBench ``std::async`` default policy): a kernel thread
  blocks on :meth:`Future.wait` via a condition variable;
* **fibers** (``boost::fiber::async``): a fiber registers a *callback* that
  re-enqueues it on its scheduler's ready queue — no kernel involvement.

The same object supports both, so a request can traverse services running on
different backends (the paper's "replace the affected services one by one"
migration story).
"""
from __future__ import annotations

import threading
from typing import Any, Callable, List, Optional


class FutureError(RuntimeError):
    pass


class Future:
    """A write-once result slot with thread-safe blocking *and* callback waits."""

    __slots__ = ("_cond", "_done", "_value", "_exc", "_callbacks")

    def __init__(self) -> None:
        self._cond = threading.Condition()
        self._done = False
        self._value: Any = None
        self._exc: Optional[BaseException] = None
        self._callbacks: List[Callable[["Future"], None]] = []

    # ---------------------------------------------------------------- write
    def set_result(self, value: Any) -> None:
        with self._cond:
            if self._done:
                raise FutureError("Future already resolved")
            self._value = value
            self._done = True
            callbacks, self._callbacks = self._callbacks, []
            self._cond.notify_all()
        for cb in callbacks:
            cb(self)

    def set_exception(self, exc: BaseException) -> None:
        with self._cond:
            if self._done:
                raise FutureError("Future already resolved")
            self._exc = exc
            self._done = True
            callbacks, self._callbacks = self._callbacks, []
            self._cond.notify_all()
        for cb in callbacks:
            cb(self)

    # ----------------------------------------------------------------- read
    @property
    def done(self) -> bool:
        with self._cond:
            return self._done

    def wait(self, timeout: Optional[float] = None) -> Any:
        """Blocking get — the *thread* backend's join. Re-raises exceptions."""
        with self._cond:
            if not self._done:
                ok = self._cond.wait_for(lambda: self._done, timeout=timeout)
                if not ok:
                    raise TimeoutError("Future.wait timed out")
            if self._exc is not None:
                raise self._exc
            return self._value

    def wait_done(self, timeout: Optional[float] = None) -> bool:
        """Block until resolved or timeout; returns done-ness and never
        (re-)raises the stored exception — for waiters that only need the
        completion *event* (e.g. a pool thread deciding whether it can stop
        work-helping), not the value."""
        with self._cond:
            return self._cond.wait_for(lambda: self._done, timeout=timeout)

    def result(self) -> Any:
        """Non-blocking get; raises if not done."""
        with self._cond:
            if not self._done:
                raise FutureError("Future not resolved yet")
            if self._exc is not None:
                raise self._exc
            return self._value

    def add_done_callback(self, cb: Callable[["Future"], None]) -> None:
        """The *fiber* backend's join: cb fires immediately if already done,
        else exactly once on resolution (possibly from another thread)."""
        run_now = False
        with self._cond:
            if self._done:
                run_now = True
            else:
                self._callbacks.append(cb)
        if run_now:
            cb(self)


def all_done(futures: List[Future]) -> bool:
    return all(f.done for f in futures)
