"""Service executors: the two async-call backends the paper compares.

``ThreadExecutor``
    Faithful to DeathStarBench's ``std::async`` default launch policy: every
    asynchronous RPC spawns a **fresh kernel thread** whose body performs the
    call and is joined on ``get()``.  Dispatcher threads pull requests from
    the service mailbox.  Thread create/exit + kernel scheduling is the
    bottleneck the paper measures (23% of ComposePost time in clone/exit).

``FiberExecutor``
    The paper's fix: each dispatcher is a :class:`FiberScheduler`; requests
    and async-RPC carriers are **fibers** on that scheduler.  Spawn cost is a
    function call; waits are overlapped cooperatively.

Both interpret the *same* handler generators (see ``effects.py``) — switching
a service between backends is a one-word config change, mirroring the paper's
``std::async`` → ``boost::fiber::async`` search-and-replace.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from typing import Any, Generator, List, Optional

from .calibrate import burn
from .effects import AsyncRpc, Compute, Offload, Sleep, SpawnLocal, Wait, WaitAll
from .fiber import FiberScheduler
from .future import Future

_SHUTDOWN = object()


class Executor:
    """Common interface: deliver(gen, reply_future) + lifecycle."""

    def deliver(self, gen: Generator, reply: Future) -> None:
        raise NotImplementedError

    def start(self) -> None:
        raise NotImplementedError

    def stop(self) -> None:
        raise NotImplementedError

    # instrumentation
    spawns: int = 0


class ThreadExecutor(Executor):
    """Thread-per-async-call backend (the paper's baseline)."""

    def __init__(self, app: Any, name: str, n_workers: int = 4) -> None:
        self.app = app
        self.name = name
        self.n_workers = n_workers
        self._mailbox: "queue.SimpleQueue" = queue.SimpleQueue()
        self._threads: List[threading.Thread] = []
        self.spawns = 0           # kernel threads created for async calls
        self.spawn_seconds = 0.0  # wall time spent creating threads
        self._lock = threading.Lock()

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        for i in range(self.n_workers):
            t = threading.Thread(target=self._dispatch_loop,
                                 name=f"{self.name}-disp{i}", daemon=True)
            t.start()
            self._threads.append(t)

    def stop(self) -> None:
        for _ in self._threads:
            self._mailbox.put(_SHUTDOWN)
        for t in self._threads:
            t.join(timeout=5.0)
        self._threads.clear()

    def deliver(self, gen: Generator, reply: Future) -> None:
        self._mailbox.put((gen, reply))

    # ------------------------------------------------------------- dispatch
    def _dispatch_loop(self) -> None:
        while True:
            item = self._mailbox.get()
            if item is _SHUTDOWN:
                return
            gen, reply = item
            self._drive(gen, reply)

    def _drive(self, gen: Generator, reply: Future) -> None:
        """Run a handler generator to completion *in this kernel thread*."""
        send_value: Any = None
        throw_exc: Optional[BaseException] = None
        while True:
            try:
                if throw_exc is not None:
                    exc, throw_exc = throw_exc, None
                    eff = gen.throw(exc)
                else:
                    eff = gen.send(send_value)
            except StopIteration as stop:
                reply.set_result(stop.value)
                return
            except BaseException as exc:
                reply.set_exception(exc)
                return

            try:
                send_value = self._interpret(eff)
                throw_exc = None
            except BaseException as exc:
                throw_exc = exc

    def _interpret(self, eff: Any) -> Any:
        if isinstance(eff, AsyncRpc):
            # THE paper's baseline operation: a fresh kernel thread per call.
            fut = Future()
            t0 = time.perf_counter()
            t = threading.Thread(
                target=self._carrier_body,
                args=(eff.dest, eff.method, eff.payload, fut),
                daemon=True)
            t.start()
            with self._lock:
                self.spawns += 1
                self.spawn_seconds += time.perf_counter() - t0
            return fut

        if isinstance(eff, Wait):
            return eff.future.wait()

        if isinstance(eff, WaitAll):
            return [f.wait() for f in eff.futures]

        if isinstance(eff, Sleep):
            time.sleep(max(eff.seconds, 0.0))
            return None

        if isinstance(eff, Compute):
            burn(eff.seconds)
            return None

        if isinstance(eff, Offload):
            return self.app.offload(eff.fn, *eff.args)

        if isinstance(eff, SpawnLocal):
            fut = Future()
            t0 = time.perf_counter()
            t = threading.Thread(target=self._drive,
                                 args=(eff.genfn(*eff.args), fut),
                                 daemon=True)
            t.start()
            with self._lock:
                self.spawns += 1
                self.spawn_seconds += time.perf_counter() - t0
            return fut

        raise TypeError(f"Unknown effect: {eff!r}")

    def _carrier_body(self, dest: str, method: str, payload: Any,
                      fut: Future) -> None:
        """Body of the per-call thread: perform the RPC, block on the reply."""
        try:
            self._drive(self.app.rpc_carrier(dest, method, payload), fut)
        except BaseException as exc:  # pragma: no cover - _drive catches
            if not fut.done:
                fut.set_exception(exc)


class FiberExecutor(Executor):
    """Fiber-per-async-call backend (the paper's technique)."""

    def __init__(self, app: Any, name: str, n_workers: int = 1) -> None:
        self.app = app
        self.name = name
        self._scheds: List[FiberScheduler] = [
            FiberScheduler(app, name=f"{name}-fib{i}") for i in range(n_workers)
        ]
        # atomic round-robin ticket; a plain `self._rr += 1` is a lost-update
        # race when many dispatcher threads deliver concurrently, which
        # silently unbalances the schedulers.
        self._rr = itertools.count()

    @property
    def spawns(self) -> int:  # type: ignore[override]
        return sum(s.fibers_spawned for s in self._scheds)

    @property
    def switches(self) -> int:
        return sum(s.switches for s in self._scheds)

    def start(self) -> None:
        for s in self._scheds:
            s.start()

    def stop(self) -> None:
        for s in self._scheds:
            s.stop()

    def deliver(self, gen: Generator, reply: Future) -> None:
        # round-robin across schedulers (boost work-sharing analogue);
        # each fiber stays pinned to its scheduler thereafter.
        s = self._scheds[next(self._rr) % len(self._scheds)]
        s.spawn_external(gen, reply)


def make_executor(backend: str, app: Any, name: str,
                  n_workers: int) -> Executor:
    if backend == "thread":
        return ThreadExecutor(app, name, n_workers)
    if backend == "fiber":
        return FiberExecutor(app, name, n_workers)
    raise ValueError(f"unknown backend {backend!r} (want 'thread'|'fiber')")
