"""Service executors: the async-call backends under study.

The paper compares two; this repo grows the comparison into a backend
design-space study over eight (see ``BACKEND_NAMES``):

``thread``  (:class:`ThreadExecutor`)
    Faithful to DeathStarBench's ``std::async`` default launch policy: every
    asynchronous RPC spawns a **fresh kernel thread** whose body performs the
    call and is joined on ``get()``.  Dispatcher threads pull requests from
    the service mailbox.  Thread create/exit + kernel scheduling is the
    bottleneck the paper measures (23% of ComposePost time in clone/exit).

``thread-pool``  (:class:`PooledThreadExecutor`)
    The obvious production alternative to raw ``std::async``: a **bounded,
    pre-spawned carrier pool** with a shared work queue.  An async call costs
    a queue push instead of a ``clone()``; saturation shows up as queue depth
    and pool-full stalls instead of spawn latency.

``fiber``  (:class:`FiberExecutor`)
    The paper's fix: each dispatcher is a :class:`FiberScheduler`; requests
    and async-RPC carriers are **fibers** on that scheduler.  Spawn cost is a
    function call; waits are overlapped cooperatively.  New work is placed
    round-robin (boost's work-*sharing* analogue) and stays pinned.

``fiber-steal``  (:class:`FiberExecutor` with ``steal=True``)
    Same fibers, boost's work-*stealing* algorithm analogue: idle schedulers
    pull parked-ready fibers from loaded siblings instead of sleeping.

``fiber-batch``  (:class:`FiberExecutor` with ``batch=True``)
    Fibers with **io_uring-style batched submission**: same-tick async calls
    buffer in a per-scheduler submission ring and flush (on size, join or
    timeout) as *one* batch carrier fiber, amortizing per-call dispatch
    across a whole fan-out (see :class:`fiber.BatchFiberScheduler`).

``fiber-batch-cq``  (:class:`FiberExecutor` with ``batch=True, cq=True``)
    Submission rings plus the **completion-ring** mirror: reply resolutions
    fired on callee threads append to the caller scheduler's
    :class:`fiber.CompletionRing` instead of each paying an injected wakeup;
    the ring drains as one batch on size / timeout / idle, so a wide burst
    of replies costs one scheduler wakeup instead of one per reply (see
    :class:`fiber.CQBatchFiberScheduler`).

``event-loop``  (:class:`eventloop.EventLoopExecutor`)
    The asyncio/libuv design point: a **single-carrier** cooperative loop
    where async calls are continuations on a run queue — no clone, no
    carrier pool, no handoff; ``Compute`` serializes on the loop.

``event-loop-shard``  (:class:`eventloop.ShardedEventLoopExecutor`)
    N independent event loops with requests hashed by request id onto one
    shard (the nginx-worker/SO_REUSEPORT design point): the loop's zero
    dispatch cost and per-request locality survive, but a CPU-heavy handler
    stalls only 1/N-th of the service instead of all of it.

All eight interpret the *same* handler generators (see ``effects.py``) —
switching a service between backends is a one-word config change, mirroring
the paper's ``std::async`` → ``boost::fiber::async`` search-and-replace.
New backends register in ``BACKEND_FACTORIES`` and every harness (benchmarks,
CI smoke matrix, parity tests) picks them up from there.

On top of the carrier designs, the cooperative backends share a
**zero-handoff fast path** (PR 4): when ``net_latency == 0`` an ``AsyncRpc``
to a co-scheduled cooperative service runs the callee handler *inline* as a
direct continuation of the caller up to its first suspension point (bounded
by ``App.inline_budget``), returning a pre-resolved ``CompletedFuture`` when
it never suspends; calls that cannot inline still skip the carrier spawn by
returning the transport reply future directly (carrier elision).  Thread
backends keep the full carrier path — their kernel dispatch cost is the
baseline under study.  The fast path is **breaker-aware** (PR 7):
interpreters only gate on the inline depth budget and then delegate
admission to ``service.App._inline_call``, which applies the same
deadline-stamping, circuit-breaker and bulkhead checks as the carrier path
and records inline outcomes into the same per-edge windows — only a bounded
service mailbox (``ResiliencePolicy.mailbox_bound``) forces the carrier
path, because an inlined call never occupies a mailbox slot.  See
``fiber.FiberScheduler._try_inline`` and ``docs/ARCHITECTURE.md``.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, Generator, List, Optional

from . import instrument
from .calibrate import burn
from .context import RequestContext
from .effects import (AsyncRpc, Compute, CurrentContext, Offload, Sleep,
                      SpawnLocal, Wait, WaitAll)
from .eventloop import EventLoopExecutor, ShardedEventLoopExecutor
from .fiber import (BatchFiberScheduler, CQBatchFiberScheduler,
                    FiberScheduler, StealGroup)
from .metrics import BackendStats
from .future import Future, Once
from .resilience import DeadlineExceeded

_SHUTDOWN = object()


class Executor:
    """Common interface: deliver(gen, reply_future[, ctx]) + lifecycle.

    ``ctx`` is the request's :class:`~repro.core.context.RequestContext`
    (session id, absolute ``time.monotonic()`` deadline, hop depth) — or
    ``None`` on the plain path, which stays allocation-free.  Thread-family
    executors enforce the deadline with kernel-timed waits
    (``Future.wait(timeout)``, truncated sleeps); the pool's suspended
    continuations arm the app's ``TimerThread``; cooperative executors arm
    their own timer wheel — no backend ever polls for expiry.
    """

    # Whether this executor's handlers may run inline on a co-scheduled
    # cooperative caller (the zero-handoff fast path).  Thread-family
    # executors keep False: their kernel-level dispatch cost is the design
    # point under study, so bypassing it would falsify the baseline.
    cooperative = False

    def deliver(self, gen: Generator, reply: Future,
                ctx: Optional[RequestContext] = None) -> None:
        """Accept one handler generator; resolve ``reply`` when it finishes."""
        raise NotImplementedError

    def _count_timeout(self) -> None:
        app = getattr(self, "app", None)
        if app is not None:
            app._res_stats.timeout()

    def start(self) -> None:
        """Bring up dispatcher threads/schedulers."""
        raise NotImplementedError

    def stop(self) -> None:
        """Tear down (bounded joins; pending work is abandoned)."""
        raise NotImplementedError

    # instrumentation
    spawns: int = 0

    def stats(self) -> BackendStats:
        """Cumulative-since-start execution counters (see BackendStats)."""
        return BackendStats(spawns=self.spawns)


class ThreadExecutor(Executor):
    """Thread-per-async-call backend (the paper's baseline)."""

    def __init__(self, app: Any, name: str, n_workers: int = 4) -> None:
        self.app = app
        self.name = name
        self.n_workers = n_workers
        self._mailbox: "queue.SimpleQueue" = queue.SimpleQueue()
        self._threads: List[threading.Thread] = []
        self.spawns = 0           # kernel threads created for async calls
        self.spawn_seconds = 0.0  # wall time spent creating threads
        self.fast_futures = 0     # completions resolved with no Condition
        self.slow_futures = 0     # completions some waiter blocked on
        self._lock = threading.Lock()

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        """Spawn the dispatcher threads that drain the mailbox."""
        h = instrument.hooks
        for i in range(self.n_workers):
            t = threading.Thread(target=self._dispatch_loop,
                                 name=f"{self.name}-disp{i}", daemon=True)
            t.start()
            self._threads.append(t)
            if h is not None:
                h.carrier_start(self, t.name)

    def stop(self) -> None:
        """Poison and join every dispatcher."""
        for _ in self._threads:
            self._mailbox.put(_SHUTDOWN)
        for t in self._threads:
            t.join(timeout=5.0)
        self._threads.clear()
        h = instrument.hooks
        if h is not None:
            h.carrier_stop(self)

    def deliver(self, gen: Generator, reply: Future,
                ctx: Optional[RequestContext] = None) -> None:
        """Queue the request on the shared dispatcher mailbox."""
        h = instrument.hooks
        if h is not None:
            h.queue_put(self)
        self._mailbox.put((gen, reply, ctx))

    # ------------------------------------------------------------- dispatch
    def _dispatch_loop(self) -> None:
        while True:
            item = self._mailbox.get()
            if item is _SHUTDOWN:
                return
            gen, reply, ctx = item
            self._drive(gen, reply, ctx)  # _drive emits the queue_take edge

    def _drive(self, gen: Generator, reply: Future,
               ctx: Optional[RequestContext] = None) -> None:
        """Run a handler generator to completion *in this kernel thread*."""
        h = instrument.hooks
        if h is not None:
            h.queue_take(self)      # join the spawner's release edge
        deadline = ctx.deadline if ctx is not None else None
        if deadline is not None and time.monotonic() >= deadline:
            # the request expired while queued in the mailbox: fail it
            # without running the handler (dequeue-side hop check)
            self._count_timeout()
            reply.set_exception(DeadlineExceeded(
                f"{self.name}: deadline expired in mailbox"))
            self._classify(reply)
            gen.close()
            return
        send_value: Any = None
        throw_exc: Optional[BaseException] = None
        while True:
            try:
                if throw_exc is not None:
                    exc, throw_exc = throw_exc, None
                    eff = gen.throw(exc)
                else:
                    eff = gen.send(send_value)
            except StopIteration as stop:
                reply.set_result(stop.value)
                self._classify(reply)
                return
            except BaseException as exc:
                reply.set_exception(exc)
                self._classify(reply)
                return

            try:
                send_value = self._interpret(eff, ctx)
                throw_exc = None
            except BaseException as exc:
                throw_exc = exc

    def _classify(self, fut: Future) -> None:
        """fast/slow future accounting (see BackendStats): on the thread
        backends nearly every join is a blocking ``wait``, which is exactly
        the kernel-object contrast the fast-path counters exist to show."""
        with self._lock:
            if fut.blocking_waited():
                self.slow_futures += 1
            else:
                self.fast_futures += 1

    def _interpret(self, eff: Any, ctx: Optional[RequestContext] = None) -> Any:
        deadline = ctx.deadline if ctx is not None else None
        if isinstance(eff, AsyncRpc):
            # THE paper's baseline operation: spawn a carrier per async call
            # (a fresh kernel thread here; a pool submission in the
            # PooledThreadExecutor subclass).  The nested hop derives its
            # own RequestContext — deadline tightened, depth bumped,
            # session/trace inherited (None when nothing to carry).
            hop = RequestContext.hop(ctx, eff.deadline)
            dl = hop.deadline if hop is not None else None
            if dl is not None and time.monotonic() >= dl:
                self._count_timeout()
                raise DeadlineExceeded(
                    f"rpc {eff.dest}.{eff.method}: deadline expired")
            fut = Future()
            self._spawn_carrier(
                self.app.rpc_carrier(eff.dest, eff.method, eff.payload, hop),
                fut, hop)
            return fut

        if isinstance(eff, Wait):
            if deadline is None:
                return eff.future.wait()
            return self._timed_wait(eff.future, deadline)

        if isinstance(eff, WaitAll):
            if deadline is None:
                return [f.wait() for f in eff.futures]
            return [self._timed_wait(f, deadline) for f in eff.futures]

        if isinstance(eff, Sleep):
            seconds = max(eff.seconds, 0.0)
            if deadline is not None:
                now = time.monotonic()
                if now + seconds >= deadline:
                    # kernel-timed truncation: sleep only to the deadline,
                    # then fail the request instead of finishing dead work
                    time.sleep(max(deadline - now, 0.0))
                    self._count_timeout()
                    raise DeadlineExceeded("deadline expired during sleep")
            time.sleep(seconds)
            return None

        if isinstance(eff, Compute):
            burn(eff.seconds)
            return None

        if isinstance(eff, Offload):
            return self.app.offload(eff.fn, *eff.args)

        if isinstance(eff, SpawnLocal):
            fut = Future()
            self._spawn_carrier(eff.genfn(*eff.args), fut, ctx)
            return fut

        if isinstance(eff, CurrentContext):
            return ctx

        raise TypeError(f"Unknown effect: {eff!r}")

    def _timed_wait(self, fut: Future, deadline: float) -> Any:
        """Kernel-timed join: block at most until the deadline, then fail
        the *waiter* with DeadlineExceeded (the awaited future stays
        pending and keeps its own single writer)."""
        remaining = deadline - time.monotonic()
        try:
            return fut.wait(timeout=max(remaining, 0.0))
        except TimeoutError:
            self._count_timeout()
            raise DeadlineExceeded("deadline expired while waiting") from None

    def _spawn_carrier(self, gen: Generator, fut: Future,
                       ctx: Optional[RequestContext] = None) -> None:
        """std::async semantics: one fresh kernel thread per async call."""
        t0 = time.perf_counter()
        h = instrument.hooks
        if h is not None:
            h.queue_put(self)       # thread start is a release edge
            h.carrier_start(self, "async-carrier")
        t = threading.Thread(target=self._drive, args=(gen, fut, ctx),
                             daemon=True)
        t.start()
        with self._lock:
            self.spawns += 1
            self.spawn_seconds += time.perf_counter() - t0

    def stats(self) -> BackendStats:
        """Snapshot this executor's counters."""
        with self._lock:
            return BackendStats(spawns=self.spawns,
                                spawn_seconds=self.spawn_seconds,
                                fast_futures=self.fast_futures,
                                slow_futures=self.slow_futures)


class PooledThreadExecutor(ThreadExecutor):
    """Bounded pre-spawned carrier pool with a shared work queue.

    Dispatchers behave exactly like :class:`ThreadExecutor`'s; only the
    async-call spawn path differs: carriers are queued to a fixed set of
    pre-spawned pool threads, so ``AsyncRpc``/``SpawnLocal`` cost a queue
    push, never a ``clone()``.  The pool is deliberately *bounded* so that
    saturation is observable: ``pool_stalls`` counts submissions that found
    the queue full, ``stall_seconds`` the wall time dispatchers spent blocked
    on it, and ``queue_depth_hwm`` the queue-depth high-water mark.

    Saturation policy, in order of pressure:

    * a **dispatcher** that finds the queue full blocks with backpressure
      accounting up to ``stall_timeout``, then degrades to caller-runs;
    * a **pool thread** about to block on a join instead *work-helps*:
      it drains queued carriers until its futures resolve.  Helped carriers
      are run in suspendable mode — a helped carrier that would block is
      parked on a done-callback and its continuation re-queued — so helping
      is iterative (flat stack), and a saturated pool can neither deadlock
      on itself nor recurse without bound;
    * a **pool thread** that submits while the queue is full runs the new
      carrier inline, also in suspendable mode.

    Fresh submissions executed by the pool loop block their pool thread on
    joins (classic bounded-pool semantics — that occupancy *is* the
    saturation being measured); suspendable mode exists only on the
    pressure paths above.
    """

    def __init__(self, app: Any, name: str, n_workers: int = 4, *,
                 pool_size: Optional[int] = None,
                 queue_bound: Optional[int] = None,
                 stall_timeout: float = 0.25) -> None:
        super().__init__(app, name, n_workers)
        self.pool_size = pool_size if pool_size is not None \
            else max(4 * n_workers, 8)
        self.queue_bound = queue_bound if queue_bound is not None \
            else 8 * self.pool_size
        self.stall_timeout = stall_timeout
        # one lock, two wait-sets: pool threads wait for work, stalled
        # dispatchers wait for queue space
        self._qlock = threading.Lock()
        self._work_cv = threading.Condition(self._qlock)
        self._space_cv = threading.Condition(self._qlock)
        self._carriers: "deque" = deque()   # fresh submissions (bounded)
        self._resumes: "deque" = deque()    # suspended-carrier continuations
        self._shutdown = False
        self._pool: List[threading.Thread] = []
        self._pool_ids: "set[int]" = set()
        self.pool_stalls = 0
        self.stall_seconds = 0.0
        self.queue_depth_hwm = 0

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        """Spawn dispatchers plus the bounded carrier pool."""
        super().start()  # dispatchers
        self._shutdown = False
        h = instrument.hooks
        for i in range(self.pool_size):
            t = threading.Thread(target=self._pool_loop,
                                 name=f"{self.name}-pool{i}", daemon=True)
            t.start()
            self._pool.append(t)
            self._pool_ids.add(t.ident)
            if h is not None:
                h.carrier_start(self, t.name)

    def stop(self) -> None:
        """Stop dispatchers, then drain and join the pool."""
        super().stop()  # dispatchers first: no new submissions
        with self._qlock:
            self._shutdown = True
            self._work_cv.notify_all()
            self._space_cv.notify_all()
        for t in self._pool:
            t.join(timeout=5.0)
        self._pool.clear()
        self._pool_ids.clear()

    def _pool_loop(self) -> None:
        while True:
            with self._qlock:
                while not self._resumes and not self._carriers:
                    if self._shutdown:
                        return
                    self._work_cv.wait()
                if self._resumes:
                    # continuations first: they unblock waiting carriers
                    gen, fut, resume, ctx = self._resumes.popleft()
                else:
                    (gen, fut, ctx), resume = \
                        self._carriers.popleft(), None
                    self._space_cv.notify()
            if resume is None:
                self._drive(gen, fut, ctx)  # classic blocking carrier
            else:
                h = instrument.hooks
                if h is not None:
                    h.queue_take(self)
                self._run_suspendable(gen, fut, resume, ctx)

    def _take_work_nowait(self):
        item = None
        with self._qlock:
            if self._resumes:
                item = self._resumes.popleft()
            elif self._carriers:
                gen, fut, ctx = self._carriers.popleft()
                self._space_cv.notify()
                item = (gen, fut, None, ctx)
        if item is not None:
            h = instrument.hooks
            if h is not None:
                h.queue_take(self)
        return item

    # ----------------------------------------------------------- wait path
    def _interpret(self, eff: Any, ctx: Optional[RequestContext] = None) -> Any:
        # Work-helping: a pool thread about to block on a join first drains
        # queued work until the awaited futures resolve.  Without this a
        # saturated pool deadlocks on itself — every pool thread parked on a
        # future whose carrier is still sitting in the queue.
        if isinstance(eff, (Wait, WaitAll)) \
                and threading.get_ident() in self._pool_ids:
            futs = [eff.future] if isinstance(eff, Wait) else list(eff.futures)
            self._help_until(futs, ctx.deadline if ctx is not None else None)
        return super()._interpret(eff, ctx)

    def _help_until(self, futs: List[Future],
                    deadline: Optional[float] = None) -> None:
        while not all(f.done for f in futs):
            if deadline is not None and time.monotonic() >= deadline:
                return  # the timed wait in super()._interpret fails the join
            item = self._take_work_nowait()
            if item is None:
                # nothing to help with; progress is on other threads.  The
                # short timeout also bounds the window in which a freshly
                # queued continuation (that may be what resolves our future)
                # waits for a helper to notice it.
                for f in futs:
                    if not f.done:
                        f.wait_done(timeout=0.005)
                        break
                continue
            gen, fut, resume, item_ctx = item
            self._run_suspendable(gen, fut, resume, item_ctx)

    def _run_suspendable(self, gen: Generator, fut: Future,
                         resume: Optional[Any] = None,
                         ctx: Optional[RequestContext] = None) -> None:
        """Drive a carrier without ever blocking this thread on a join: an
        unresolved Wait/WaitAll parks the generator on a done-callback that
        re-queues its continuation.  This is what keeps work-helping and
        saturated fan-out flat-stacked."""
        deadline = ctx.deadline if ctx is not None else None
        send_value: Any = None
        throw_exc: Optional[BaseException] = None
        if resume is not None:
            kind, payload = resume
            if kind == "throw":
                throw_exc = payload
            else:
                send_value = payload
        if (deadline is not None and throw_exc is None
                and time.monotonic() >= deadline):
            # expired while queued/suspended and no expiry was delivered
            # yet: fail the carrier now instead of resuming dead work
            self._count_timeout()
            throw_exc = DeadlineExceeded(
                f"{self.name}: deadline expired before resume")
        while True:
            try:
                if throw_exc is not None:
                    exc, throw_exc = throw_exc, None
                    eff = gen.throw(exc)
                else:
                    eff = gen.send(send_value)
            except StopIteration as stop:
                fut.set_result(stop.value)
                self._classify(fut)
                return
            except BaseException as exc:
                fut.set_exception(exc)
                self._classify(fut)
                return
            if isinstance(eff, (Wait, WaitAll)):
                waits = ([eff.future] if isinstance(eff, Wait)
                         else list(eff.futures))
                if all(w.done for w in waits):
                    try:
                        send_value = (waits[0].result()
                                      if isinstance(eff, Wait)
                                      else [w.result() for w in waits])
                        throw_exc = None
                    except BaseException as exc:
                        send_value, throw_exc = None, exc
                    continue
                self._suspend_on(gen, fut, eff, waits, ctx)
                return
            try:
                # non-join effects only; ThreadExecutor._interpret so the
                # timed-wait work-help hook above is not re-entered
                send_value = ThreadExecutor._interpret(self, eff, ctx)
                throw_exc = None
            except BaseException as exc:
                throw_exc = exc

    def _suspend_on(self, gen: Generator, fut: Future, eff: Any,
                    waits: List[Future],
                    ctx: Optional[RequestContext] = None) -> None:
        # With a deadline, the parked continuation races a TimerThread
        # expiry against the done-callback; a first-writer-wins claim
        # guarantees exactly one of them enqueues the resume.
        deadline = ctx.deadline if ctx is not None else None
        h = instrument.hooks
        if h is not None:
            for w in waits:
                h.future_join(w)
        claim = Once() if deadline is not None else None
        if claim is not None:
            def _expire() -> None:
                if claim.claim():
                    self._count_timeout()
                    self._enqueue_resume(gen, fut, ("throw", DeadlineExceeded(
                        f"{self.name}: deadline expired while suspended")),
                        ctx)
            self.app._timer.push(deadline, _expire)
        if isinstance(eff, Wait):
            def _resume_one(w: Future) -> None:
                if claim is not None and not claim.claim():
                    return  # the deadline expiry already resumed the carrier
                try:
                    resume = ("send", w.result())
                except BaseException as exc:
                    resume = ("throw", exc)
                self._enqueue_resume(gen, fut, resume, ctx)
            waits[0].add_done_callback(_resume_one)
            return
        remaining = [len(waits)]
        rlock = threading.Lock()

        def _resume_all(_w: Future) -> None:
            with rlock:
                remaining[0] -= 1
                if remaining[0]:
                    return
            if claim is not None and not claim.claim():
                return
            try:
                resume = ("send", [w.result() for w in waits])
            except BaseException as exc:
                resume = ("throw", exc)
            self._enqueue_resume(gen, fut, resume, ctx)
        for w in waits:
            w.add_done_callback(_resume_all)

    def _enqueue_resume(self, gen: Generator, fut: Future, resume: Any,
                        ctx: Optional[RequestContext] = None) -> None:
        # unbounded on purpose: continuations are not new admissions (the
        # carrier was counted and bounded at submission), and refusing them
        # could deadlock the very join they resolve
        h = instrument.hooks
        if h is not None:
            h.queue_put(self)
        with self._qlock:
            self._resumes.append((gen, fut, resume, ctx))
            self._work_cv.notify()

    # ----------------------------------------------------------- spawn path
    def _spawn_carrier(self, gen: Generator, fut: Future,
                       ctx: Optional[RequestContext] = None) -> None:
        on_pool = threading.get_ident() in self._pool_ids
        queued = False
        stalled = False
        t0 = time.perf_counter()
        h = instrument.hooks
        if h is not None:
            h.queue_put(self)
            h.carrier_start(self, "pooled-carrier")
        with self._qlock:
            if len(self._carriers) >= self.queue_bound:
                stalled = True
                if not on_pool:
                    # dispatcher: block with backpressure accounting, then —
                    # on pathological saturation — degrade to caller-runs so
                    # the service makes progress instead of wedging
                    stall_end = t0 + self.stall_timeout
                    while len(self._carriers) >= self.queue_bound \
                            and not self._shutdown:
                        left = stall_end - time.perf_counter()
                        if left <= 0:
                            break
                        self._space_cv.wait(timeout=left)
                # pool thread: fall through to caller-runs immediately — its
                # queue slot may only free when *it* helps, so waiting here
                # could deadlock
            if len(self._carriers) < self.queue_bound:
                self._carriers.append((gen, fut, ctx))
                queued = True
                self._work_cv.notify()
                depth = len(self._carriers) + len(self._resumes)
            else:
                depth = None
        with self._lock:
            self.spawns += 1  # every carrier counts, queued or caller-run
            if stalled:
                self.pool_stalls += 1
                if not on_pool:
                    self.stall_seconds += time.perf_counter() - t0
            if depth is not None and depth > self.queue_depth_hwm:
                self.queue_depth_hwm = depth
        if not queued:
            if on_pool:
                self._run_suspendable(gen, fut, None, ctx)
            else:
                self._drive(gen, fut, ctx)

    def stats(self) -> BackendStats:
        """Snapshot counters, including pool backpressure gauges."""
        with self._lock:
            return BackendStats(spawns=self.spawns,
                                spawn_seconds=self.spawn_seconds,
                                pool_stalls=self.pool_stalls,
                                stall_seconds=self.stall_seconds,
                                queue_depth_hwm=self.queue_depth_hwm,
                                fast_futures=self.fast_futures,
                                slow_futures=self.slow_futures)


class FiberExecutor(Executor):
    """Fiber-per-async-call backend (the paper's technique).

    ``steal=False``: round-robin placement, fibers pinned (work-sharing).
    ``steal=True``: same placement, but idle schedulers steal parked-ready
    fibers from loaded siblings (work-stealing; see ``fiber.py``).
    ``batch=True``: per-scheduler submission rings flush same-tick async
    calls as one batch carrier (io_uring-style; see ``fiber.py``).  Batch
    rings are owner-thread-only, so ``batch`` excludes ``steal``.
    ``cq=True`` (requires ``batch``): schedulers additionally batch
    cross-thread reply resumptions through a per-scheduler
    ``CompletionRing`` (see ``fiber.CQBatchFiberScheduler``).
    """

    cooperative = True  # handlers may be inlined by a cooperative caller

    def __init__(self, app: Any, name: str, n_workers: int = 1, *,
                 steal: bool = False, batch: bool = False,
                 batch_size: int = 32, flush_after: float = 0.0005,
                 cq: bool = False, cq_size: int = 32,
                 cq_flush_after: float = 0.0005) -> None:
        if steal and batch:
            raise ValueError("batch submission rings are owner-thread-only "
                             "state; steal=True cannot see them")
        if cq and not batch:
            raise ValueError("the completion ring is the batch family's "
                             "reply-side mirror; cq=True requires batch=True")
        self.app = app
        self.name = name
        self.steal = steal
        self.batch = batch
        self.cq = cq
        group = StealGroup() if steal and n_workers > 1 else None
        if cq:
            self._scheds: List[FiberScheduler] = [
                CQBatchFiberScheduler(app, name=f"{name}-fib{i}",
                                      batch_size=batch_size,
                                      flush_after=flush_after,
                                      cq_size=cq_size,
                                      cq_flush_after=cq_flush_after)
                for i in range(n_workers)
            ]
        elif batch:
            self._scheds = [
                BatchFiberScheduler(app, name=f"{name}-fib{i}",
                                    batch_size=batch_size,
                                    flush_after=flush_after)
                for i in range(n_workers)
            ]
        else:
            self._scheds = [
                FiberScheduler(app, name=f"{name}-fib{i}", steal_group=group)
                for i in range(n_workers)
            ]
        # atomic round-robin ticket; a plain `self._rr += 1` is a lost-update
        # race when many dispatcher threads deliver concurrently, which
        # silently unbalances the schedulers.
        self._rr = itertools.count()

    @property
    def spawns(self) -> int:  # type: ignore[override]
        """Fibers spawned across this executor's schedulers."""
        return sum(s.fibers_spawned for s in self._scheds)

    @property
    def switches(self) -> int:
        """Fiber context switches across schedulers."""
        return sum(s.switches for s in self._scheds)

    @property
    def steals(self) -> int:
        """Fibers stolen by idle schedulers (steal mode only)."""
        return sum(s.steals for s in self._scheds)

    def start(self) -> None:
        """Start every scheduler thread."""
        h = instrument.hooks
        for s in self._scheds:
            s.start()
            if h is not None:
                h.carrier_start(self, s.name)

    def stop(self) -> None:
        """Stop every scheduler thread (bounded joins)."""
        for s in self._scheds:
            s.stop()
        h = instrument.hooks
        if h is not None:
            h.carrier_stop(self)

    def deliver(self, gen: Generator, reply: Future,
                ctx: Optional[RequestContext] = None) -> None:
        """Place the request on a scheduler (round-robin)."""
        # Round-robin placement in both modes (as in boost, whose
        # work_stealing algorithm also keeps naive local placement and lets
        # the steal path fix imbalance).  A least-loaded placement variant
        # was measured and *lost* to rr+steal on the widest-fan-out app:
        # concurrent delivers all read the same stale queue lengths and herd
        # onto one scheduler, while rr spreads bursts by construction.
        s = self._scheds[next(self._rr) % len(self._scheds)]
        if ctx is None:  # common path keeps the pre-context signature
            s.spawn_external(gen, reply)
        else:
            s.spawn_external(gen, reply, ctx=ctx)

    def stats(self) -> BackendStats:
        """Aggregate counters across schedulers (rings included)."""
        # ring counters exist only on the batch/cq scheduler subclasses;
        # getattr keeps one aggregation path for all four fiber variants.
        def agg(field: str) -> int:
            return sum(getattr(s, field, 0) for s in self._scheds)

        def gauge(field: str) -> int:
            return max((getattr(s, field, 0) for s in self._scheds),
                       default=0)
        return BackendStats(spawns=self.spawns, switches=self.switches,
                            steals=self.steals,
                            batched_calls=agg("batched_calls"),
                            flushes_size=agg("flushes_size"),
                            flushes_join=agg("flushes_join"),
                            flushes_timeout=agg("flushes_timeout"),
                            ring_hwm=gauge("ring_hwm"),
                            completions_batched=agg("completions_batched"),
                            cq_flushes_size=agg("cq_flushes_size"),
                            cq_flushes_timeout=agg("cq_flushes_timeout"),
                            cq_flushes_idle=agg("cq_flushes_idle"),
                            cq_hwm=gauge("cq_hwm"),
                            inline_calls=agg("inline_calls"),
                            inline_depth_hwm=gauge("inline_depth_hwm"),
                            fast_futures=agg("fast_futures"),
                            slow_futures=agg("slow_futures"))


# --------------------------------------------------------------- registry
# The backend set is *data*: benchmarks, the CI smoke matrix, parity tests
# and the app builders all iterate BACKEND_NAMES, so a future backend is
# one entry here (plus a sizing rule in repro.apps.registry.build_bench_app
# if the default pool sizing does not fit it).
BACKEND_FACTORIES: Dict[str, Callable[[Any, str, int], Executor]] = {
    "thread": ThreadExecutor,
    "thread-pool": PooledThreadExecutor,
    "fiber": FiberExecutor,
    "fiber-steal": lambda app, name, n_workers: FiberExecutor(
        app, name, n_workers, steal=True),
    "fiber-batch": lambda app, name, n_workers: FiberExecutor(
        app, name, n_workers, batch=True),
    "fiber-batch-cq": lambda app, name, n_workers: FiberExecutor(
        app, name, n_workers, batch=True, cq=True),
    "event-loop": EventLoopExecutor,
    "event-loop-shard": ShardedEventLoopExecutor,
}

BACKEND_NAMES = tuple(BACKEND_FACTORIES)


def make_executor(backend: str, app: Any, name: str,
                  n_workers: int) -> Executor:
    """Build the executor registered under ``backend`` for one service."""
    try:
        factory = BACKEND_FACTORIES[backend]
    except KeyError:
        raise ValueError(f"unknown backend {backend!r} "
                         f"(want one of {BACKEND_NAMES})") from None
    return factory(app, name, n_workers)
