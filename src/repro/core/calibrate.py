"""CPU-burn calibration for the Compute(seconds) effect.

Service handlers model on-CPU work with a *real* busy loop so that scheduler
pressure, GIL contention and context-switch costs are physically exercised —
the quantities the paper attributes the thread-backend collapse to.
"""
from __future__ import annotations

import time

_ITERS_PER_SEC: float | None = None


def _burn_iters(n: int) -> int:
    acc = 0
    for i in range(n):
        acc += i ^ (acc >> 3)
    return acc


def iters_per_second() -> float:
    """Calibrate once per process: busy-loop iterations per wall second."""
    global _ITERS_PER_SEC
    if _ITERS_PER_SEC is None:
        n = 200_000
        t0 = time.perf_counter()
        _burn_iters(n)
        dt = time.perf_counter() - t0
        # refine with a second, longer shot for stability
        n2 = max(int(n / dt * 0.02), 10_000)  # ~20 ms
        t0 = time.perf_counter()
        _burn_iters(n2)
        dt2 = time.perf_counter() - t0
        _ITERS_PER_SEC = n2 / max(dt2, 1e-9)
    return _ITERS_PER_SEC


def burn(seconds: float) -> None:
    """Busy-spin for approximately ``seconds`` of CPU time."""
    if seconds <= 0:
        return
    _burn_iters(max(int(iters_per_second() * seconds), 1))
