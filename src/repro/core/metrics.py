"""Latency/throughput metrics used by the load generator and benchmarks."""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field, fields
from typing import Dict, List

import numpy as np

from . import instrument


class CacheStats:
    """Lock-free app-wide cache-tier counters (hits/misses).

    Same idiom as ``ResilienceStats``: each event consumes one ticket from
    an atomic ``itertools.count`` (single C-level op under the GIL) and
    reads parse the counter's repr, so handlers on every executor thread
    can count without a lock.  The apps' cache service reaches this via
    ``svc.app.cache_stats``; ``App.backend_stats`` copies the totals into
    ``BackendStats.cache_hits`` / ``cache_misses``.
    """

    __slots__ = ("_hits", "_misses")

    def __init__(self) -> None:
        self._hits = itertools.count(1)
        self._misses = itertools.count(1)

    @staticmethod
    def _read(counter: "itertools.count") -> int:
        r = repr(counter)                    # e.g. "count(42)"
        return int(r[r.index("(") + 1:-1]) - 1

    def hit(self) -> None:
        """Count one cache hit."""
        next(self._hits)

    def miss(self) -> None:
        """Count one cache miss."""
        next(self._misses)

    @property
    def hits(self) -> int:
        """Cache hits so far."""
        return self._read(self._hits)

    @property
    def misses(self) -> int:
        """Cache misses so far."""
        return self._read(self._misses)


@dataclass
class BackendStats:
    """Per-backend execution counters, aggregated app-wide.

    Monotonic counters (everything except ``queue_depth_hwm``) support
    per-trial deltas via :meth:`delta`; ``queue_depth_hwm`` is a gauge — a
    high-water mark since executor start — and a delta keeps the ``after``
    value.

    ``spawns``/``spawn_seconds``: async-call carriers created (thread clones,
    pool submissions, fibers, or event-loop continuations) and the wall time
    spent creating them.  ``switches``: fiber context switches / event-loop
    continuation resumptions.  ``steals``: ready fibers pulled by an idle
    scheduler from a loaded sibling (``fiber-steal`` only).
    ``pool_stalls``/``stall_seconds``: submissions that found the carrier
    queue full, and the wall time dispatchers spent blocked on it
    (``thread-pool`` only).  ``queue_depth_hwm``: carrier-queue (or event-loop
    run-queue) high water.  ``batched_calls``: async calls that went through
    a submission ring; ``flushes_size``/``flushes_join``/``flushes_timeout``:
    ring flushes by trigger; ``ring_hwm``: ring occupancy high-water
    (``fiber-batch``/``fiber-batch-cq`` — mean batch size is
    ``batched_calls / sum(flushes_*)``).

    Completion-ring counters (``fiber-batch-cq`` only):
    ``completions_batched``: cross-thread resumptions that travelled through
    a scheduler's completion ring instead of each paying an injected wakeup;
    ``cq_flushes_size``/``cq_flushes_timeout``/``cq_flushes_idle``: ring
    drains by trigger (mean reply-batch size is
    ``completions_batched / sum(cq_flushes_*)``); ``cq_hwm``: completion-ring
    occupancy high-water (gauge).  ``shards``: configured shard width of an
    ``event-loop-shard`` executor (gauge; app-wide aggregation takes the
    widest service).

    Zero-handoff fast-path counters (cooperative backends):
    ``inline_calls``: async RPCs whose callee handler ran as a direct
    continuation of the caller (mailbox and carrier spawn skipped);
    ``inline_depth_hwm``: deepest nesting of inlined calls observed — a
    gauge, bounded by ``App.inline_budget``.  ``fast_futures``/
    ``slow_futures``: handler/carrier completions whose reply future was
    resolved without / with a kernel ``Condition`` ever materializing (a
    blocking ``wait`` is what materializes one; cooperative joins never do).

    Resilience counters (app-level, see ``repro.core.resilience``):
    ``timeouts``: deadline-expiry events (admission checks, parked-wait
    expiries, truncated sleeps — a single request can tick several hops);
    ``retries``: re-sends issued by the budgeted retry policy;
    ``breaker_opens``: circuit-breaker closed/half-open -> open transitions;
    ``rejections``: arrivals refused by a bounded service mailbox;
    ``bulkhead_rejections``: attempts refused by a per-edge bulkhead on the
    caller side (the edge was never exercised — distinct from mailbox
    ``rejections``, which the destination refuses after transport).

    Cache-tier counters (app-level, fed by the apps' cache service through
    ``App.cache_stats``): ``cache_hits`` / ``cache_misses`` — cache-aside
    lookups that found / missed the key (a miss pays the backing-store
    read and populates the cache).

    Fault-injection counters (app-level, fed by an installed
    ``repro.core.faults.FaultPlan``): ``faults_injected`` — requests that
    had at least one fault injected; ``faults_latency`` / ``faults_error``
    / ``faults_hang`` / ``faults_brownout`` / ``faults_crash`` — per-kind
    rule applications (one request can tick several wrap-kind rules).
    """
    spawns: int = 0
    spawn_seconds: float = 0.0
    switches: int = 0
    steals: int = 0
    pool_stalls: int = 0
    stall_seconds: float = 0.0
    queue_depth_hwm: int = 0
    batched_calls: int = 0
    flushes_size: int = 0
    flushes_join: int = 0
    flushes_timeout: int = 0
    ring_hwm: int = 0
    completions_batched: int = 0
    cq_flushes_size: int = 0
    cq_flushes_timeout: int = 0
    cq_flushes_idle: int = 0
    cq_hwm: int = 0
    shards: int = 0
    inline_calls: int = 0
    inline_depth_hwm: int = 0
    fast_futures: int = 0
    slow_futures: int = 0
    timeouts: int = 0
    retries: int = 0
    breaker_opens: int = 0
    rejections: int = 0
    bulkhead_rejections: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    faults_injected: int = 0
    faults_latency: int = 0
    faults_error: int = 0
    faults_hang: int = 0
    faults_brownout: int = 0
    faults_crash: int = 0

    _GAUGES = ("queue_depth_hwm", "ring_hwm", "cq_hwm", "shards",
               "inline_depth_hwm")

    def add(self, other: "BackendStats") -> "BackendStats":
        """In-place aggregation across executors (gauges take the max)."""
        for f in fields(self):
            if f.name in self._GAUGES:
                setattr(self, f.name,
                        max(getattr(self, f.name), getattr(other, f.name)))
            else:
                setattr(self, f.name,
                        getattr(self, f.name) + getattr(other, f.name))
        return self

    @staticmethod
    def delta(before: "BackendStats", after: "BackendStats") -> "BackendStats":
        """Counters: after - before.  Gauges: after (high-water survives)."""
        out = BackendStats()
        for f in fields(out):
            a, b = getattr(after, f.name), getattr(before, f.name)
            setattr(out, f.name, a if f.name in out._GAUGES else a - b)
        return out

    def as_dict(self) -> Dict[str, float]:
        """All counters/gauges as a flat name -> value dict."""
        return {f.name: getattr(self, f.name) for f in fields(self)}


class LatencyRecorder:
    """Thread-safe reservoir of request latencies (seconds)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._samples: List[float] = []
        self.completed = 0
        self.errors = 0

    def record(self, seconds: float) -> None:
        """Record one completed request's latency."""
        h = instrument.hooks
        if h is not None:
            h.recorder_write(self)
        with self._lock:
            self._samples.append(seconds)
            self.completed += 1

    def record_error(self) -> None:
        """Count one errored request (no latency sample)."""
        h = instrument.hooks
        if h is not None:
            h.recorder_write(self)
        with self._lock:
            self.errors += 1

    def snapshot(self) -> List[float]:
        """Copy of the samples so far (safe to read while recording)."""
        with self._lock:
            return list(self._samples)

    def summary(self) -> Dict[str, float]:
        """n/mean/p50/p90/p99 over the current samples (NaNs when empty)."""
        h = instrument.hooks
        if h is not None:
            h.recorder_summary(self)
        xs = np.asarray(self.snapshot(), dtype=np.float64)
        if xs.size == 0:
            return {"n": 0, "mean": float("nan"), "p50": float("nan"),
                    "p90": float("nan"), "p99": float("nan")}
        return {
            "n": int(xs.size),
            "mean": float(xs.mean()),
            "p50": float(np.percentile(xs, 50)),
            "p90": float(np.percentile(xs, 90)),
            "p99": float(np.percentile(xs, 99)),
        }


@dataclass
class TrialResult:
    """One load-generation trial at a fixed offered rate."""
    offered_rps: float
    achieved_rps: float
    duration: float
    p50: float
    p99: float
    mean: float
    completed: int
    shed: int
    errors: int
    # per-trial executor-counter delta (see BackendStats), aggregated over
    # every service in the app; empty when the caller did not supply an app
    # snapshot.
    backend_stats: Dict[str, float] = field(default_factory=dict)
    # goodput accounting (overload mode): total arrivals the generator
    # produced (admitted + shed — sheds stay in the denominator so "peak"
    # can never be inflated by quietly dropping offered load), completions
    # that beat the per-request deadline, that count as a rate, and
    # admitted requests still unresolved when the trial was severed.
    offered: int = 0
    good: int = 0
    goodput_rps: float = 0.0
    abandoned: int = 0

    def row(self) -> str:
        """One-line human-readable trial summary (counters appended only
        when nonzero, e.g. ``to=/rtry=/brko=/rej=/bhrej=``)."""
        s = (f"offered={self.offered_rps:9.1f} achieved={self.achieved_rps:9.1f} "
             f"p50={self.p50 * 1e3:8.2f}ms p99={self.p99 * 1e3:8.2f}ms "
             f"n={self.completed} shed={self.shed}")
        if self.good != self.completed:
            s += f" good={self.good} goodput={self.goodput_rps:.0f}/s"
        if self.abandoned:
            s += f" abandoned={self.abandoned}"
        if self.errors:
            s += f" errors={self.errors}"
        bs = self.backend_stats
        if bs.get("steals"):
            s += f" steals={bs['steals']:.0f}"
        if bs.get("pool_stalls"):
            s += (f" stalls={bs['pool_stalls']:.0f}"
                  f" qhwm={bs.get('queue_depth_hwm', 0):.0f}")
        if bs.get("inline_calls"):
            s += (f" inline={bs['inline_calls']:.0f}"
                  f"@d{bs.get('inline_depth_hwm', 0):.0f}")
        if bs.get("batched_calls"):
            flushes = (bs.get("flushes_size", 0) + bs.get("flushes_join", 0)
                       + bs.get("flushes_timeout", 0))
            s += (f" batched={bs['batched_calls']:.0f}"
                  f"/{flushes:.0f}fl"
                  f" ringhwm={bs.get('ring_hwm', 0):.0f}")
        if bs.get("completions_batched"):
            cq_flushes = (bs.get("cq_flushes_size", 0)
                          + bs.get("cq_flushes_timeout", 0)
                          + bs.get("cq_flushes_idle", 0))
            s += (f" cq={bs['completions_batched']:.0f}"
                  f"/{cq_flushes:.0f}fl"
                  f" cqhwm={bs.get('cq_hwm', 0):.0f}")
        if bs.get("shards"):
            s += f" shards={bs['shards']:.0f}"
        if bs.get("timeouts"):
            s += f" to={bs['timeouts']:.0f}"
        if bs.get("retries"):
            s += f" rtry={bs['retries']:.0f}"
        if bs.get("breaker_opens"):
            s += f" brko={bs['breaker_opens']:.0f}"
        if bs.get("rejections"):
            s += f" rej={bs['rejections']:.0f}"
        if bs.get("bulkhead_rejections"):
            s += f" bhrej={bs['bulkhead_rejections']:.0f}"
        if bs.get("cache_hits") or bs.get("cache_misses"):
            s += (f" ch={bs.get('cache_hits', 0):.0f}"
                  f" cm={bs.get('cache_misses', 0):.0f}")
        if bs.get("faults_injected"):
            kinds = "".join(
                f" {tag}={bs[k]:.0f}" for tag, k in
                (("lat", "faults_latency"), ("err", "faults_error"),
                 ("hang", "faults_hang"), ("brn", "faults_brownout"),
                 ("crsh", "faults_crash")) if bs.get(k))
            s += f" flt={bs['faults_injected']:.0f}({kinds.strip()})"
        return s


@dataclass
class PeakResult:
    """Outcome of the geometric peak-throughput ramp."""

    peak_rps: float
    trials: List[TrialResult] = field(default_factory=list)
