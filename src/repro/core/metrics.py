"""Latency/throughput metrics used by the load generator and benchmarks."""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np


class LatencyRecorder:
    """Thread-safe reservoir of request latencies (seconds)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._samples: List[float] = []
        self.completed = 0
        self.errors = 0

    def record(self, seconds: float) -> None:
        with self._lock:
            self._samples.append(seconds)
            self.completed += 1

    def record_error(self) -> None:
        with self._lock:
            self.errors += 1

    def snapshot(self) -> List[float]:
        with self._lock:
            return list(self._samples)

    def summary(self) -> Dict[str, float]:
        xs = np.asarray(self.snapshot(), dtype=np.float64)
        if xs.size == 0:
            return {"n": 0, "mean": float("nan"), "p50": float("nan"),
                    "p90": float("nan"), "p99": float("nan")}
        return {
            "n": int(xs.size),
            "mean": float(xs.mean()),
            "p50": float(np.percentile(xs, 50)),
            "p90": float(np.percentile(xs, 90)),
            "p99": float(np.percentile(xs, 99)),
        }


@dataclass
class TrialResult:
    """One load-generation trial at a fixed offered rate."""
    offered_rps: float
    achieved_rps: float
    duration: float
    p50: float
    p99: float
    mean: float
    completed: int
    shed: int
    errors: int

    def row(self) -> str:
        return (f"offered={self.offered_rps:9.1f} achieved={self.achieved_rps:9.1f} "
                f"p50={self.p50 * 1e3:8.2f}ms p99={self.p99 * 1e3:8.2f}ms "
                f"n={self.completed} shed={self.shed}")


@dataclass
class PeakResult:
    peak_rps: float
    trials: List[TrialResult] = field(default_factory=list)
