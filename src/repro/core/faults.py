"""Deterministic, seeded fault injection: make dependencies sick on purpose.

PR 6 proved the resilience layer survives *uniform* overload and recorded an
honestly bimodal breaker result — breakers pay off against a **sick
dependency**, not uniform pressure, and the repo had no way to make a
dependency sick.  This module is that missing instrument: a
:class:`FaultPlan` of per-``(dest, method)``-edge :class:`FaultRule`\\ s with
explicit schedules on the trial clock and a seeded RNG, so every run of a
scenario is bit-reproducible.

Fault taxonomy
--------------
``latency``
    Add ``latency`` seconds of service time before the handler runs, plus —
    with probability ``spike_prob`` per request (seeded RNG) — an extra
    ``spike_latency`` spike.  Injected as a leading ``Sleep`` effect, so the
    executor's normal deadline machinery truncates it and fails the attempt
    with ``DeadlineExceeded`` when the spike blows the budget.
``error``
    Fail the request with :class:`InjectedFault` before the handler runs,
    with probability ``error_rate`` per request (seeded RNG).  Retryable,
    breaker evidence — the deterministic stand-in for a flaky dependency.
``hang``
    Blackhole: the handler never runs and the reply future is **never
    resolved** by the destination.  The caller's parked join expires via the
    normal deadline machinery; the blackholed reply itself is parked on the
    plan and settled by ``App.stop()`` / :meth:`FaultPlan.disarm` so no
    waiter is orphaned past teardown.
``brownout``
    Inflate the handler's service time: every ``Sleep`` and ``Compute`` the
    handler yields is scaled by ``factor`` for the rule's window.  The
    degraded handler *runs* (burning real CPU for scaled ``Compute``), and
    fails with ``DeadlineExceeded`` only if the inflated time exceeds the
    request's budget — the "sick but not dead" dependency breakers exist for.
``crash``
    Crash the whole destination service for the window: its executor is
    stopped at ``start`` and restarted at ``stop`` (riding the idempotent,
    restartable executor contract ``App.start``/``App.stop`` already rely
    on), and every delivery during the window fails fast with
    :class:`ServiceCrashed` — the moral equivalent of connection-refused.

Injection points (backend invariance)
-------------------------------------
Both RPC paths instantiate the handler generator at exactly one spot —
``Service.deliver`` (mailbox/carrier path) and ``App._inline_call`` /
``App._inline_resilient`` (zero-handoff fast path) — and both consult
:meth:`FaultPlan.intercept` there, *after* the resilience admission checks
(deadline, breaker, bulkhead, mailbox bound).  A fault therefore flows
through each path's existing accounting identically: an injected error is
breaker evidence and retry fuel on either path, injected latency is subject
to the same deadline truncation, and a blackholed reply holds its bulkhead
slot and mailbox-admission token exactly like a genuinely hung request —
which is what makes fault semantics invariant across all 8 executors.

Determinism
-----------
All probabilistic draws (``error_rate``, ``spike_prob``) come from one
``random.Random(seed)`` re-seeded on every :meth:`FaultPlan.arm`, and every
injection appends a ``(kind, dest, method, param)`` entry to
:attr:`FaultPlan.trace`.  Same plan + same seed + same request sequence ⇒
identical trace, bit for bit (``tests/test_faults.py``).
"""
from __future__ import annotations

import itertools
import random
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, Generator, List, Optional, Tuple

from .effects import Compute, Sleep
from .future import Future

KINDS = ("latency", "error", "hang", "brownout", "crash")


class InjectedFault(RuntimeError):
    """A deterministic fault injected by a :class:`FaultPlan`.

    Deliberately a plain ``RuntimeError`` subclass (not ``DeadlineExceeded``):
    injected errors are retryable and count as circuit-breaker evidence,
    exactly like a real dependency failure would."""


class ServiceCrashed(InjectedFault):
    """Delivery refused because the destination service is crashed (its
    executor is stopped for the rule's window) — connection-refused
    semantics: fail fast, retryable, breaker evidence."""


@dataclass(frozen=True)
class FaultRule:
    """One scheduled fault on one ``(dest, method)`` edge.

    ``method=None`` matches every method of ``dest``.  ``start``/``stop``
    are seconds on the trial clock — relative to the instant the plan was
    :meth:`FaultPlan.arm`\\ ed — and the rule is active for
    ``start <= t < stop``.  Kind-specific knobs: ``latency`` +
    ``spike_prob``/``spike_latency`` (kind ``latency``), ``error_rate``
    (kind ``error``), ``factor`` (kind ``brownout``)."""

    dest: str
    kind: str
    method: Optional[str] = None
    start: float = 0.0
    stop: float = float("inf")
    latency: float = 0.0
    spike_prob: float = 0.0
    spike_latency: float = 0.0
    error_rate: float = 1.0
    factor: float = 1.0

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {KINDS})")
        if self.stop <= self.start:
            raise ValueError(f"empty fault window [{self.start}, {self.stop})")


class FaultStats:
    """Lock-free per-kind injection counters (``CacheStats`` idiom: one
    atomic ``itertools.count`` ticket per event, reads parse the repr), so
    every executor thread can count without a lock.  Monotonic for the
    plan's lifetime — per-trial views come from ``BackendStats.delta``."""

    __slots__ = ("_counters",)

    def __init__(self) -> None:
        self._counters = {k: itertools.count(1) for k in ("injected",) + KINDS}

    def tick(self, kind: str) -> None:
        """Count one injection of ``kind`` (``injected`` is ticked by the
        plan once per intercepted request, on top of the per-kind tick)."""
        next(self._counters[kind])

    def get(self, kind: str) -> int:
        """Injections of ``kind`` so far (exact, lock-free)."""
        r = repr(self._counters[kind])        # e.g. "count(42)"
        return int(r[r.index("(") + 1:-1]) - 1

    @property
    def injected(self) -> int:
        """Total requests that had at least one fault injected."""
        return self.get("injected")

    def as_dict(self) -> Dict[str, int]:
        """``{"faults_injected": n, "faults_<kind>": n, ...}``."""
        out = {"faults_injected": self.injected}
        for k in KINDS:
            out[f"faults_{k}"] = self.get(k)
        return out


def faulted_handler(gen: Generator, pre: float, scale: float) -> Generator:
    """Wrap a handler generator with injected service time.

    ``pre`` seconds of added latency are yielded as a leading ``Sleep`` (so
    the executor's deadline machinery can truncate it); ``scale != 1``
    turns the wrapper into a manual pump loop that multiplies every
    ``Sleep``/``Compute`` the handler yields — forwarding sent values *and*
    thrown exceptions, because the interpreters drive handlers with a
    ``send``/``throw`` protocol (a plain ``yield from`` could forward but
    not transform the effects)."""
    if pre > 0.0:
        yield Sleep(pre)
    if scale == 1.0:
        result = yield from gen
        return result
    try:
        eff = gen.send(None)
    except StopIteration as si:
        return si.value
    while True:
        kind = type(eff)
        if kind is Sleep:
            eff = Sleep(eff.seconds * scale)
        elif kind is Compute:
            eff = Compute(eff.seconds * scale)
        try:
            value = yield eff
        except BaseException as exc:  # deadline expiry thrown at the yield
            try:
                eff = gen.throw(exc)
            except StopIteration as si:
                return si.value
            continue
        try:
            eff = gen.send(value)
        except StopIteration as si:
            return si.value


class FaultPlan:
    """A seeded, scheduled set of :class:`FaultRule`\\ s for one app.

    Install with ``App.set_faults(plan)``; :meth:`arm` starts the schedule
    clock (``loadgen.run_trial`` arms an installed plan at trial start, so
    rule windows read as "seconds into the trial").  Each ``arm`` re-seeds
    the RNG and clears the trace, making every armed run bit-reproducible.
    """

    def __init__(self, rules: List[FaultRule], *, seed: int = 0) -> None:
        self.rules: Tuple[FaultRule, ...] = tuple(rules)
        self.seed = seed
        self.stats = FaultStats()
        self.trace: List[Tuple[Any, ...]] = []
        self._lock = threading.Lock()
        self._rng = random.Random(seed)
        self._epoch: Optional[float] = None
        self._gen = 0                       # arm generation: stale-timer guard
        self._app: Any = None
        self._blackholed: List[Future] = []
        self._crashed: set = set()          # dests with a stopped executor
        self._by_dest: Dict[str, List[FaultRule]] = {}
        for r in self.rules:
            self._by_dest.setdefault(r.dest, []).append(r)

    # ------------------------------------------------------------ lifecycle
    def bind(self, app: Any) -> None:
        """Attach to an :class:`~repro.core.service.App` (done by
        ``App.set_faults``); the app's ``TimerThread`` drives crash/restart
        schedules and ``App.stop`` settles blackholed replies."""
        self._app = app

    @property
    def armed(self) -> bool:
        """True between :meth:`arm` and :meth:`disarm`."""
        return self._epoch is not None

    def arm(self, at: Optional[float] = None) -> None:
        """Start (or restart) the schedule clock at ``at`` (default: now,
        ``time.monotonic``).  Re-seeds the RNG and clears the trace so every
        armed run of the same plan is bit-identical; schedules any ``crash``
        rules' stop/restart instants on the app's timer thread."""
        now = time.monotonic() if at is None else at
        with self._lock:
            self._gen += 1
            gen = self._gen
            self._epoch = now
            self._rng = random.Random(self.seed)
            self.trace = []
        app = self._app
        if app is None:
            return
        for rule in self.rules:
            if rule.kind != "crash":
                continue
            app._timer.push(now + rule.start,
                            lambda d=rule.dest, g=gen: self._crash(d, g))
            if rule.stop != float("inf"):
                app._timer.push(now + rule.stop,
                                lambda d=rule.dest, g=gen: self._restart(d, g))

    def disarm(self) -> None:
        """Stop injecting: clears the schedule clock, cancels pending
        crash/restart actions (generation bump), restarts any still-crashed
        service, and settles blackholed replies."""
        with self._lock:
            self._gen += 1
            self._epoch = None
        for dest in list(self._crashed):
            self._restart(dest, self._gen)
        self.settle_blackholed()

    # ------------------------------------------------------- crash schedule
    def _crash(self, dest: str, gen: int) -> None:
        app = self._app
        with self._lock:
            if gen != self._gen:
                return                      # re-armed/disarmed since scheduled
        if app is None or not getattr(app, "_started", False):
            return
        svc = app.services.get(dest)
        if svc is None:
            return
        self._crashed.add(dest)             # fail-fast flag set *before* stop
        svc.executor.stop()

    def _restart(self, dest: str, gen: int) -> None:
        app = self._app
        with self._lock:
            if gen != self._gen:
                return
        self._crashed.discard(dest)
        if app is None or not getattr(app, "_started", False):
            return                          # App.stop owns a stopped app
        svc = app.services.get(dest)
        if svc is not None:
            svc.executor.start()

    # ----------------------------------------------------------- blackholes
    def blackhole(self, reply: Future) -> None:
        """Park a blackholed reply: never resolved by the destination,
        settled with :class:`InjectedFault` at ``App.stop``/:meth:`disarm`
        (the no-orphaned-waiters discipline, same as loadgen leftovers)."""
        with self._lock:
            self._blackholed.append(reply)

    def settle_blackholed(self) -> None:
        """Resolve every parked blackholed reply with ``InjectedFault`` —
        waiters (and their bulkhead slots / mailbox-admission tokens) are
        released instead of being orphaned past teardown."""
        with self._lock:
            parked, self._blackholed = self._blackholed, []
        for fut in parked:
            if not fut.done:
                fut.set_exception(InjectedFault(
                    "blackholed reply settled at stop"))

    # ------------------------------------------------------------ intercept
    def intercept(self, dest: str, method: str) -> Optional[Tuple]:
        """Per-request fault decision for one delivery on ``(dest, method)``.

        Returns ``None`` (no fault) or an action tuple the call sites in
        ``Service.deliver`` / ``App._inline_call`` apply:
        ``("error", exc)`` fail the reply now; ``("hang",)`` blackhole it;
        ``("wrap", pre, scale)`` run the handler through
        :func:`faulted_handler`.  Terminal kinds (crash > hang > error, in
        rule order) win outright; latency and brownout rules *accumulate*
        (added latencies sum, brownout factors multiply)."""
        if self._epoch is None:
            return None
        rules = self._by_dest.get(dest)
        if rules is None:
            return None
        rel = time.monotonic() - self._epoch
        pre = 0.0
        scale = 1.0
        stats = self.stats
        with self._lock:
            if dest in self._crashed:
                # executor is down (covers the gap between a crash window
                # ending and the restart timer firing): never let a delivery
                # sit in a stopped executor's mailbox
                stats.tick("crash")
                stats.tick("injected")
                self.trace.append(("crash", dest, method))
                return ("error", ServiceCrashed(
                    f"{dest}: service crashed (injected fault)"))
            for r in rules:
                if r.method is not None and r.method != method:
                    continue
                if rel < r.start or rel >= r.stop:
                    continue
                if r.kind == "crash":
                    stats.tick("crash")
                    stats.tick("injected")
                    self.trace.append(("crash", dest, method))
                    return ("error", ServiceCrashed(
                        f"{dest}: service crashed (injected fault)"))
                if r.kind == "hang":
                    stats.tick("hang")
                    stats.tick("injected")
                    self.trace.append(("hang", dest, method))
                    return ("hang",)
                if r.kind == "error":
                    if r.error_rate >= 1.0 or self._rng.random() < r.error_rate:
                        stats.tick("error")
                        stats.tick("injected")
                        self.trace.append(("error", dest, method))
                        return ("error", InjectedFault(
                            f"{dest}.{method}: injected error"))
                    continue
                if r.kind == "latency":
                    add = r.latency
                    if r.spike_prob > 0.0 and \
                            self._rng.random() < r.spike_prob:
                        add += r.spike_latency
                    if add > 0.0:
                        stats.tick("latency")
                        self.trace.append(("latency", dest, method, add))
                        pre += add
                else:                       # brownout
                    stats.tick("brownout")
                    self.trace.append(("brownout", dest, method, r.factor))
                    scale *= r.factor
        if pre == 0.0 and scale == 1.0:
            return None
        stats.tick("injected")
        return ("wrap", pre, scale)
