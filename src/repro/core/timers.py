"""Shared timer wheel for the cooperative schedulers.

Both single-threaded scheduler families — :class:`~repro.core.fiber.
FiberScheduler` (fibers on a ready deque) and :class:`~repro.core.eventloop.
EventLoopExecutor` (continuations on a run queue) — park timed waits
(``Sleep`` effects, batched-submission flush deadlines) on the same
structure: a monotonic-deadline min-heap with FIFO tie-breaking.  It was
originally embedded in ``fiber.py``; it lives here so every cooperative
backend shares one implementation and one set of ordering guarantees:

* entries pop in deadline order;
* entries with *identical* deadlines pop in push order (without the
  sequence field, ``heapq`` would fall through to comparing payloads,
  which are unorderable scheduler internals);
* the wheel is **owner-thread-only** — exactly one scheduler thread pushes
  and pops; cross-thread wakeups go through the scheduler's own injection
  queue, never through the wheel.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import Any, Callable, List, Optional, Tuple

from . import instrument


class TimerWheel:
    """Deadline-ordered queue of opaque payloads (min-heap + FIFO ties)."""

    __slots__ = ("_heap", "_seq")

    def __init__(self) -> None:
        self._heap: List[Tuple[float, int, Any]] = []
        self._seq = itertools.count()

    def push(self, deadline: float, item: Any) -> None:
        """Schedule ``item`` to become due at monotonic time ``deadline``."""
        heapq.heappush(self._heap, (deadline, next(self._seq), item))
        h = instrument.hooks
        if h is not None:
            h.timer_arm(self, deadline)

    def pop_due(self, now: float) -> List[Any]:
        """Remove and return every item whose deadline has passed, in
        deadline order (FIFO among equal deadlines)."""
        due: List[Any] = []
        while self._heap and self._heap[0][0] <= now:
            due.append(heapq.heappop(self._heap)[2])
        if due:
            h = instrument.hooks
            if h is not None:
                h.timer_fire(self, len(due))
        return due

    def next_deadline(self) -> Optional[float]:
        """Earliest pending deadline; None when the wheel is empty."""
        return self._heap[0][0] if self._heap else None

    def seconds_until_next(self, now: float) -> Optional[float]:
        """Non-negative sleep budget until the next deadline; None if empty."""
        if not self._heap:
            return None
        return max(self._heap[0][0] - now, 0.0)

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


class TimerThread:
    """App-wide kernel-timed callback scheduler for the *non*-cooperative
    paths: retry backoff firings and deadline expiry for pool-suspended
    continuations, neither of which has a scheduler thread of its own to
    park a :class:`TimerWheel` entry on.

    One daemon thread sleeps on a condition variable until the earliest
    deadline (no polling); callbacks run on that thread with the lock
    released, so they may push further timers.  ``push`` is thread-safe and
    lazily starts the thread, ``stop`` is idempotent, and the object is
    restartable (``App.stop``/``start`` cycles, like the offload pool).
    """

    def __init__(self, name: str = "res-timer") -> None:
        self._name = name
        self._cond = threading.Condition()
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self._thread: Optional[threading.Thread] = None
        self._stop = False

    def push(self, deadline: float, fn: Callable[[], None]) -> None:
        """Run ``fn()`` on the timer thread at monotonic time ``deadline``."""
        with self._cond:
            heapq.heappush(self._heap, (deadline, next(self._seq), fn))
            if self._thread is None or not self._thread.is_alive():
                self._stop = False
                self._thread = threading.Thread(
                    target=self._loop, name=self._name, daemon=True)
                self._thread.start()
            else:
                self._cond.notify()  # may have become the new earliest
        h = instrument.hooks
        if h is not None:
            h.timer_arm(self, deadline)

    def stop(self, fire_pending: bool = False) -> None:
        """Stop the timer thread (idempotent).

        With ``fire_pending=False`` pending entries are silently dropped —
        acceptable only when nothing downstream is waiting on them.  With
        ``fire_pending=True`` every pending callback runs *now* (early, on
        the stopping thread): shutdown paths use this so a pending retry
        backoff still fires, observes the stopped app, and fails the reply
        it owes instead of orphaning the caller (see ``App.stop``).
        """
        with self._cond:
            thread = self._thread
            self._stop = True
            pending = [entry[2] for entry in sorted(self._heap)]
            self._heap.clear()
            self._cond.notify_all()
        if thread is not None:
            thread.join(timeout=5.0)
        with self._cond:
            self._thread = None
        h = instrument.hooks
        if pending:
            if fire_pending:
                if h is not None:
                    h.timer_fire(self, len(pending))
                for fn in pending:
                    try:
                        fn()
                    except Exception:
                        pass  # same contract as _loop: callbacks never kill us
            elif h is not None:
                h.timer_cancel(self, len(pending))

    def _loop(self) -> None:
        while True:
            due: List[Callable[[], None]] = []
            with self._cond:
                if self._stop:
                    return
                now = time.monotonic()
                while self._heap and self._heap[0][0] <= now:
                    due.append(heapq.heappop(self._heap)[2])
                if not due:
                    timeout = (self._heap[0][0] - now) if self._heap else None
                    self._cond.wait(timeout=timeout)
                    continue
            h = instrument.hooks
            if h is not None:
                h.timer_fire(self, len(due))
            for fn in due:
                try:
                    fn()
                except Exception:
                    pass  # a timer callback must never kill the wheel
