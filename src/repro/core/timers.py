"""Shared timer wheel for the cooperative schedulers.

Both single-threaded scheduler families — :class:`~repro.core.fiber.
FiberScheduler` (fibers on a ready deque) and :class:`~repro.core.eventloop.
EventLoopExecutor` (continuations on a run queue) — park timed waits
(``Sleep`` effects, batched-submission flush deadlines) on the same
structure: a monotonic-deadline min-heap with FIFO tie-breaking.  It was
originally embedded in ``fiber.py``; it lives here so every cooperative
backend shares one implementation and one set of ordering guarantees:

* entries pop in deadline order;
* entries with *identical* deadlines pop in push order (without the
  sequence field, ``heapq`` would fall through to comparing payloads,
  which are unorderable scheduler internals);
* the wheel is **owner-thread-only** — exactly one scheduler thread pushes
  and pops; cross-thread wakeups go through the scheduler's own injection
  queue, never through the wheel.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Any, List, Optional, Tuple


class TimerWheel:
    """Deadline-ordered queue of opaque payloads (min-heap + FIFO ties)."""

    __slots__ = ("_heap", "_seq")

    def __init__(self) -> None:
        self._heap: List[Tuple[float, int, Any]] = []
        self._seq = itertools.count()

    def push(self, deadline: float, item: Any) -> None:
        """Schedule ``item`` to become due at monotonic time ``deadline``."""
        heapq.heappush(self._heap, (deadline, next(self._seq), item))

    def pop_due(self, now: float) -> List[Any]:
        """Remove and return every item whose deadline has passed, in
        deadline order (FIFO among equal deadlines)."""
        due: List[Any] = []
        while self._heap and self._heap[0][0] <= now:
            due.append(heapq.heappop(self._heap)[2])
        return due

    def next_deadline(self) -> Optional[float]:
        """Earliest pending deadline; None when the wheel is empty."""
        return self._heap[0][0] if self._heap else None

    def seconds_until_next(self, now: float) -> Optional[float]:
        """Non-negative sleep budget until the next deadline; None if empty."""
        if not self._heap:
            return None
        return max(self._heap[0][0] - now, 0.0)

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
