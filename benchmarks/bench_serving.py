"""Beyond-paper integration: LLM serving engine, thread vs fiber orchestration.

A tiny decoder LM served with continuous batching; request orchestration
(api -> tokenizer -> engine.submit -> detokenizer) runs on either backend.
Reports sustained request throughput and p99 latency at a fixed offered rate.
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np


def run(quick: bool = False) -> List[str]:
    from repro.configs import get_smoke_config
    from repro.models import Model
    from repro.serving import ServeConfig, build_llm_app

    cfg = get_smoke_config("qwen2-0.5b").with_(remat=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    scfg = ServeConfig(max_batch=4, max_len=64, prefill_bucket=16,
                       max_new_tokens=4)
    n_requests = 16 if quick else 48
    rows = []
    for backend in ("thread", "fiber"):
        app = build_llm_app(model, params, scfg, backend=backend)
        with app:
            app.send("engine", "run", None)
            # warmup (compile)
            app.send("api", "generate", {"text": "warmup"}).wait(timeout=120)
            t0 = time.perf_counter()
            lat: List[float] = []
            futs = []
            for i in range(n_requests):
                ts = time.perf_counter()
                fut = app.send("api", "generate", {"text": f"request {i}"})
                fut.add_done_callback(
                    lambda f, ts=ts: lat.append(time.perf_counter() - ts))
                futs.append(fut)
                time.sleep(0.002)
            for f in futs:
                f.wait(timeout=240)
            dt = time.perf_counter() - t0
            eng = app.services["engine"].state["engine"]
            rows.append(
                f"serving/{backend},{dt / n_requests * 1e6:.1f},"
                f"rps={n_requests / dt:.1f};p99_ms="
                f"{np.percentile(lat, 99) * 1e3:.1f};"
                f"tokens={eng.generated}")
            app.services["engine"].state["stop"] = True
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
