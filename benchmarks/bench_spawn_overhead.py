"""Microbenchmark: async-call spawn/join cost, across every backend.

Paper analogue: "the ComposePost service spends 23% of its time in clone and
exit system calls".  We measure the raw cost of spawning+joining async no-op
carriers under each registered backend: thread pays a ``clone()`` per call,
thread-pool a queue push to pre-spawned carriers, fiber/fiber-steal a heap
allocation + deque push, fiber-batch/fiber-batch-cq a ring append (one
carrier per flushed batch; the cq variant also returns replies through a
completion ring), event-loop a bare run-queue append on its single loop
thread, event-loop-shard the same on the request's hashed shard.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import (App, AsyncRpc, BACKEND_NAMES, ServiceSpec, WaitAll)


def _noop(svc, payload):
    return payload
    yield  # pragma: no cover - marks this as a generator


def _fan(svc, payload):
    futs = []
    for i in range(payload):
        f = yield AsyncRpc("noop", "go", i)
        futs.append(f)
    yield WaitAll(futs)
    return payload


def _build(backend: str) -> App:
    app = App(backend=backend)
    app.add_service(ServiceSpec("noop", {"go": _noop}, n_workers=2))
    app.add_service(ServiceSpec("fan", {"fan": _fan}, n_workers=2))
    return app


def measure_spawn_cost(backend: str, *, fanout: int = 8,
                       iters: int = 200) -> Dict[str, float]:
    """Wall time per async call for a fanout-of-N no-op RPC pattern."""
    with _build(backend) as app:
        # warmup
        for _ in range(10):
            app.send("fan", "fan", fanout).wait(timeout=10)
        t0 = time.perf_counter()
        for _ in range(iters):
            app.send("fan", "fan", fanout).wait(timeout=10)
        dt = time.perf_counter() - t0
        spawns = app.total_spawns()
    return {
        "us_per_request": dt / iters * 1e6,
        "us_per_async_call": dt / (iters * fanout) * 1e6,
        "spawns": spawns,
    }


def run(quick: bool = False) -> List[str]:
    rows = []
    iters = 50 if quick else 200
    res = {}
    for backend in BACKEND_NAMES:
        r = measure_spawn_cost(backend, iters=iters)
        res[backend] = r
        rows.append(f"spawn_overhead/{backend},{r['us_per_async_call']:.2f},"
                    f"req_us={r['us_per_request']:.1f}")
    base = res["thread"]["us_per_async_call"]
    for backend in BACKEND_NAMES:
        if backend == "thread":
            continue
        ratio = base / max(res[backend]["us_per_async_call"], 1e-9)
        rows.append(f"spawn_overhead/thread_over_{backend},{ratio:.2f},x")
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
