"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

Tables:
  1. spawn_overhead   — paper's "23% of time in clone/exit" analogue
  2. peak_throughput  — paper Figure 1 (peak rps, 4 workloads × 2 backends)
  3. p99_latency      — paper Figure 2 (p99 vs offered rate)
  4. serving          — beyond-paper: LLM serving engine, thread vs fiber
  5. roofline         — dry-run roofline terms (reads launch/dryrun results)

Env:
  BENCH_QUICK=1   shorter trials (CI)
  BENCH_ONLY=a,b  run a subset by prefix
"""
from __future__ import annotations

import os
import sys
import time
import traceback


def main() -> None:
    quick = os.environ.get("BENCH_QUICK", "0") == "1"
    only = os.environ.get("BENCH_ONLY", "")
    selected = [s.strip() for s in only.split(",") if s.strip()]

    benches = []
    from . import bench_spawn_overhead, bench_throughput, bench_latency
    benches.append(("spawn_overhead", bench_spawn_overhead.run))
    benches.append(("peak_throughput", bench_throughput.run))
    benches.append(("p99_latency", bench_latency.run))
    try:
        from . import bench_serving
        benches.append(("serving", bench_serving.run))
    except ImportError:
        pass
    try:
        from . import bench_roofline
        benches.append(("roofline", bench_roofline.run))
    except ImportError:
        pass

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        if selected and not any(name.startswith(s) for s in selected):
            continue
        t0 = time.perf_counter()
        try:
            for row in fn(quick=quick):
                print(row, flush=True)
        except Exception:
            failures += 1
            print(f"{name}/ERROR,0,failed", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} took {time.perf_counter() - t0:.1f}s",
              file=sys.stderr, flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
