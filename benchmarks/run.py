"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

Tables:
  1. spawn_overhead   — paper's "23% of time in clone/exit" analogue
  2. rpc_path         — per-RPC dispatch cost, zero-handoff fast path on/off
  3. peak_throughput  — paper Figure 1 (peak rps, app x workload x backend)
  4. p99_latency      — paper Figure 2 (p99 vs offered rate)
  5. overload         — 2-5x collapse-knee sweep (goodput-vs-offered curve
                        + knee multiple per cell), time-to-recover, and the
                        uncapped-budget retry-storm amplification table,
                        resilience layer on (bench_overload; also writes
                        launch_results/overload_sweep.json)
  6. faults           — deterministic sick-dependency scenarios: breaker
                        A/B win, per-edge blast radius and time-to-recover
                        per app x backend cell (bench_faults; also writes
                        launch_results/faults_sweep.json)
  7. serving          — beyond-paper: LLM serving engine, thread vs fiber
  8. roofline         — dry-run roofline terms (reads launch/dryrun results)

The microservice tables (2, 3) sweep every app in ``repro.apps.REGISTRY``
crossed with every backend in ``repro.apps.BENCH_BACKENDS``; restrict with
``--app`` (repeatable / comma-separated).

``--smoke`` switches to the CI bench-smoke matrix instead (tiny trials for
every app × backend cell across the 8-backend matrix, parity + steal and
design-point probes, JSON artifact via ``--json``; see ``bench_smoke.py``).  ``--smoke --update-baseline``
additionally rewrites the committed trend baseline
(``launch_results/baseline_smoke.json``) when the run is fully green, so
refreshing the CI trend gate's fallback baseline is one reviewed command
instead of hand-edited JSON.

Usage:
  PYTHONPATH=src python -m benchmarks.run [--quick] [--only peak,p99]
      [--app socialnetwork --app hotelreservation]
  PYTHONPATH=src python -m benchmarks.run --smoke --json smoke.json
  PYTHONPATH=src python -m benchmarks.run --smoke --update-baseline

Env (equivalent to the flags, kept for CI wrappers):
  BENCH_QUICK=1   shorter trials
  BENCH_ONLY=a,b  run a subset by prefix
  BENCH_APPS=a,b  restrict the app sweep
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback


def _csv_list(vals) -> list:
    out = []
    for v in vals or []:
        out.extend(s.strip() for s in v.split(",") if s.strip())
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    default=os.environ.get("BENCH_QUICK", "0") == "1")
    ap.add_argument("--only", action="append", default=None,
                    help="benchmark name prefixes to run (comma-separated)")
    ap.add_argument("--app", action="append", default=None,
                    help="apps to sweep in the microservice tables "
                         "(default: all registered)")
    ap.add_argument("--smoke", action="store_true",
                    help="run the CI bench-smoke matrix (app x backend "
                         "cells, parity + steal probe) instead of the "
                         "full benchmarks")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="with --smoke: write the JSON artifact here")
    ap.add_argument("--update-baseline", action="store_true",
                    help="with --smoke: on a green run, rewrite the "
                         "committed trend baseline "
                         "(launch_results/baseline_smoke.json)")
    args = ap.parse_args(argv)

    quick = args.quick
    selected = _csv_list(args.only) or \
        _csv_list([os.environ.get("BENCH_ONLY", "")])
    apps = _csv_list(args.app) or \
        _csv_list([os.environ.get("BENCH_APPS", "")]) or None
    if apps:
        from repro.apps import get_app_def
        try:
            for a in apps:
                get_app_def(a)  # fail fast on typos
        except ValueError as e:
            ap.error(str(e))

    if args.json and not args.smoke:
        ap.error("--json only applies to --smoke (the full benchmarks "
                 "emit CSV on stdout)")
    if args.update_baseline and not args.smoke:
        ap.error("--update-baseline only applies to --smoke (the baseline "
                 "is a smoke artifact)")
    if args.update_baseline and apps:
        ap.error("--update-baseline requires the full app matrix: a "
                 "partial artifact would leave the omitted apps' cells "
                 "without baseline records, silently disabling their "
                 "committed-baseline trend gate (drop --app/BENCH_APPS)")
    if args.smoke:
        if selected:
            ap.error("--only/BENCH_ONLY does not apply to --smoke "
                     "(the smoke matrix always runs every backend cell)")
        baseline_path = None
        if args.update_baseline:
            baseline_path = os.path.join(
                os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                "launch_results", "baseline_smoke.json")
        from .bench_smoke import run_smoke
        sys.exit(run_smoke(apps=apps, json_path=args.json, quick=quick,
                           baseline_path=baseline_path))

    benches = []
    from . import (bench_latency, bench_rpc_path, bench_spawn_overhead,
                   bench_throughput)
    benches.append(("spawn_overhead",
                    lambda quick: bench_spawn_overhead.run(quick=quick)))
    benches.append(("rpc_path",
                    lambda quick: bench_rpc_path.run(quick=quick)))
    benches.append(("peak_throughput",
                    lambda quick: bench_throughput.run(quick=quick,
                                                       apps=apps)))
    benches.append(("p99_latency",
                    lambda quick: bench_latency.run(quick=quick, apps=apps)))
    from . import bench_overload
    benches.append(("overload",
                    lambda quick: bench_overload.run(quick=quick,
                                                     apps=apps)))
    from . import bench_faults
    benches.append(("faults",
                    lambda quick: bench_faults.run(quick=quick,
                                                   apps=apps)))
    try:
        from . import bench_serving
        benches.append(("serving", lambda quick: bench_serving.run(quick=quick)))
    except ImportError:
        pass
    try:
        from . import bench_roofline
        benches.append(("roofline", lambda quick: bench_roofline.run(quick=quick)))
    except ImportError:
        pass

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        if selected and not any(name.startswith(s) for s in selected):
            continue
        t0 = time.perf_counter()
        try:
            for row in fn(quick):
                print(row, flush=True)
        except Exception:
            failures += 1
            print(f"{name}/ERROR,0,failed", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} took {time.perf_counter() - t0:.1f}s",
              file=sys.stderr, flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
