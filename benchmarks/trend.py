"""Trend gate: diff a bench-smoke artifact against a baseline artifact.

``bench_smoke.py`` gates each run on *internal* invariants (errors, parity
vs the thread baseline).  This comparator adds the *cross-run* axis: per
app x backend cell (the full 8-backend matrix — new backends' records flow
through here with no comparator changes), has throughput regressed since
the previous successful run on this branch (or, failing that, the
committed ``launch_results/baseline_smoke.json``)?

    python benchmarks/trend.py current.json baseline.json... [--md trend.md]

Several baselines may be given; the gate fails if *any* of them shows a
regression.  CI passes both the previous run's artifact **and** the
committed baseline: previous-run-only comparison would let a slowdown
ratchet — each push loses 30%, each diff stays inside the noise band, every
run goes green and becomes the next baseline.  The committed baseline only
moves via the reviewed ``run.py --smoke --update-baseline`` command, so
compounding drift eventually trips it.

Noise band
----------
Smoke trials on shared CI runners are wall-clock noisy, so a raw
``current < baseline`` check would flap.  The band follows the repo's
paired-trial protocol (see the steal probe in ``bench_smoke.py``): never
compare two noisy numbers without a same-run noise measurement.  Each
artifact records ``SMOKE_TRIALS`` repeated trials per cell; the per-cell
relative spread ``(max - min) / max`` of each run estimates that run's
noise, and the band is::

    band = clamp(spread_current + spread_baseline, NOISE_FLOOR, MAX_BAND)

* ``NOISE_FLOOR`` absorbs runner-weather variance the short trials cannot
  see (two quiet trials on a machine that is 25% slower than yesterday's).
* ``MAX_BAND`` caps the band so a genuinely unstable cell cannot talk its
  way out of gating — a 2x regression (ratio 0.5) always fails.

Records carry a ``direction`` (``higher`` is better — throughput — or
``lower`` is better — p99 latency, ns/call).  A higher-better cell
**fails** when ``current_best < baseline_best * (1 - band)``; a
lower-better cell fails when ``current_best > baseline_best * (1 + band)``.
Either way, a cell on the wrong side of baseline but inside the band only
**warns**.  Lower-better cells use wider floor/cap constants
(``LOWER_NOISE_FLOOR``/``LOWER_MAX_BAND``): tail latency on shared runners
is far noisier than throughput at a fixed offered rate, and a cap of 1.0
still guarantees that a worse-than-2x latency regression always fails.
Machine-absolute ns/call micro cells (unit ``ns`` or ``noise: micro``) get
the widest clamps (``MICRO_*``, fail beyond 2.5x): they do not transfer
across hardware, while the regression they exist to catch — losing the
inline fast path — is a ~40x move.  Records tagged ``gate: warn-only``
(the smoke-scale p99 cells, whose ~hundred-sample tails swing several-x
run-over-run on identical code) surface out-of-band moves as warnings but
never fail the run.
The exit code is non-zero iff some cell fails, which is what turns the CI
bench-smoke job from a parity check into a regression trend gate.

``--from-csv`` switches the inputs from smoke artifacts to full-benchmark
CSVs (the ``name,us_per_call,derived`` rows ``benchmarks/run.py`` prints):
``p99_latency``/``peak_throughput``/``rpc_path``/``spawn_overhead`` rows
become lower-is-better records (the value column is microseconds for all
of them) and are diffed with the same noise-band protocol — this is how a
full-bench run on one machine is compared against a previous full-bench
run, catching the tail-latency regressions the smoke rps gate misses.

Stdlib-only on purpose: the CI bench lane installs nothing but numpy, and
the script must also run standalone (``python benchmarks/trend.py``).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional, Sequence

# Must match bench_smoke.SCHEMA_VERSION (not imported: this script runs
# standalone, without PYTHONPATH=src or the benchmarks package).
SCHEMA_VERSION = 2

NOISE_FLOOR = 0.35
MAX_BAND = 0.45
# lower-is-better cells (latency tails) breathe much more than throughput
# at a fixed offered rate on shared runners
LOWER_NOISE_FLOOR = 0.50
LOWER_MAX_BAND = 1.00
# ns/call micro cells (unit "ns") are *absolute CPU-speed* numbers: unlike
# rps-at-fixed-rate or sleep-dominated p99, they do not transfer across
# machines, and the committed baseline may come from different hardware
# than the CI runner.  Gate only beyond 2.5x — a genuine fast-path
# regression (losing inline execution) is a 40x move, far outside it.
MICRO_NOISE_FLOOR = 1.00
MICRO_MAX_BAND = 1.50
# goodput-past-peak cells (noise "overload", direction higher): the metric
# is by construction measured in a saturated, backlogged regime — the one
# regime where wall-clock weather on a shared runner moves the number most
# (observed several-x run-over-run on identical code: whether a breaker
# trips inside the short window is effectively a coin flip).  The cells
# are additionally tagged ``gate: warn-only`` by bench_smoke, so these
# clamps only shape when the warning is worded as out-of-band.
OVERLOAD_NOISE_FLOOR = 0.50
OVERLOAD_MAX_BAND = 0.90

# full-bench CSV prefixes ingested by --from-csv; ratio rows (derived "x",
# "x_vs_noinline") and error rows are skipped
_CSV_PREFIXES = ("p99_latency/", "peak_throughput/", "rpc_path/",
                 "spawn_overhead/")


class TrendError(ValueError):
    """Malformed *current* artifact — a usage error, not a regression."""


def _records_by_key(artifact: Dict[str, Any]) -> Dict[str, Dict[str, Any]]:
    return {r["key"]: r for r in artifact.get("records", [])}


def rel_spread(trials: Optional[Sequence[float]]) -> float:
    """(max - min) / max of a cell's repeated trials; 0 when degenerate."""
    if not trials:
        return 0.0
    hi = max(trials)
    if hi <= 0:
        return 0.0
    return (hi - min(trials)) / hi


def noise_band(cur_rec: Dict[str, Any], base_rec: Dict[str, Any], *,
               floor: float = NOISE_FLOOR, cap: float = MAX_BAND) -> float:
    """Relative regression tolerance for one cell (see module docstring)."""
    spread = rel_spread(cur_rec.get("trials")) \
        + rel_spread(base_rec.get("trials"))
    return min(cap, max(floor, spread))


def compare(current: Dict[str, Any], baseline: Dict[str, Any], *,
            floor: float = NOISE_FLOOR) -> Dict[str, Any]:
    """Diff two smoke artifacts; returns a report dict (never exits).

    Report keys: ``rows`` (per-cell dicts with status ok/warn/regression/
    new), ``regressions``, ``warnings``, ``notes``, ``comparable`` (False
    when the baseline cannot be diffed — schema drift or a pre-records
    artifact — in which case the gate passes vacuously and says why).
    """
    report: Dict[str, Any] = {"rows": [], "regressions": [], "warnings": [],
                              "notes": [], "comparable": True}
    if current.get("schema_version") != SCHEMA_VERSION \
            or not current.get("records"):
        raise TrendError(
            f"current artifact has schema_version="
            f"{current.get('schema_version')!r} and "
            f"{len(current.get('records', []))} records; expected "
            f"schema_version={SCHEMA_VERSION} with records — was it written "
            f"by this tree's bench_smoke.py?")
    if baseline.get("schema_version") != SCHEMA_VERSION \
            or not baseline.get("records"):
        report["comparable"] = False
        report["notes"].append(
            f"baseline not comparable (schema_version="
            f"{baseline.get('schema_version')!r}, "
            f"{len(baseline.get('records', []))} records) — trend gate "
            f"passes vacuously; it will engage on the next run")
        return report

    cur_recs = _records_by_key(current)
    base_recs = _records_by_key(baseline)
    cur_apps = set(current.get("apps", []))

    for key in sorted(cur_recs):
        cur = cur_recs[key]
        base = base_recs.get(key)
        if base is None:
            report["rows"].append({"key": key, "status": "new",
                                   "current": cur["value"]})
            report["notes"].append(f"{key}: new cell (no baseline)")
            continue
        direction = cur.get("direction", "higher")
        unit = cur.get("unit", "rps")
        micro = cur.get("noise") == "micro" or unit == "ns"
        if direction == "lower":
            lo, cap = ((MICRO_NOISE_FLOOR, MICRO_MAX_BAND) if micro
                       else (LOWER_NOISE_FLOOR, LOWER_MAX_BAND))
            band = noise_band(cur, base, floor=max(floor, lo), cap=cap)
        elif cur.get("noise") == "overload":
            # goodput measured past the peak: saturated-regime numbers
            # breathe more than rps-at-fixed-rate (see constants above)
            band = noise_band(cur, base,
                              floor=max(floor, OVERLOAD_NOISE_FLOOR),
                              cap=OVERLOAD_MAX_BAND)
        else:
            band = noise_band(cur, base, floor=floor)
        base_v = float(base["value"])
        cur_v = float(cur["value"])
        ratio = cur_v / base_v if base_v > 0 else float("inf")
        if direction == "lower":
            regressed = base_v > 0 and cur_v > base_v * (1.0 + band)
            worse = cur_v > base_v
            why = f"ratio {ratio:.2f} > 1 + band {band:.2f}"
        else:
            regressed = ratio < 1.0 - band
            worse = ratio < 1.0
            why = f"ratio {ratio:.2f} < 1 - band {band:.2f}"
        row = {"key": key, "status": "ok", "current": cur_v,
               "baseline": base_v, "ratio": round(ratio, 3),
               "band": round(band, 3), "direction": direction}
        if regressed and cur.get("gate") == "warn-only":
            # cells whose metric cannot support a hard cross-run gate
            # (smoke-scale p99: ~hundred-sample tails swing several-x
            # run-over-run even on identical code) are surfaced loudly
            # but never fail the run
            row["status"] = "warn"
            report["warnings"].append(
                f"{key}: {cur_v:.1f} {unit} vs baseline {base_v:.1f} {unit} "
                f"({why}; warn-only cell)")
        elif regressed:
            row["status"] = "regression"
            report["regressions"].append(
                f"{key}: {cur_v:.1f} {unit} vs baseline {base_v:.1f} {unit} "
                f"({why})")
        elif worse:
            row["status"] = "warn"
            report["warnings"].append(
                f"{key}: {cur_v:.1f} {unit} vs baseline {base_v:.1f} {unit} "
                f"(ratio {ratio:.2f}, inside noise band {band:.2f})")
        report["rows"].append(row)

    # baseline cells this run should have produced but did not
    for key in sorted(base_recs):
        if key in cur_recs:
            continue
        if base_recs[key].get("app") in cur_apps:
            report["warnings"].append(
                f"{key}: present in baseline but missing from current run")
    return report


def artifact_from_csv(path: str) -> Dict[str, Any]:
    """Turn a full-benchmark CSV (``name,us_per_call,derived`` rows from
    ``benchmarks/run.py``) into a records artifact :func:`compare` accepts.

    Only the measurement rows under ``_CSV_PREFIXES`` are ingested — the
    value column is microseconds for all of them, so every record is
    direction ``lower``.  Ratio rows (``derived`` of ``x...``), error rows
    and the header are skipped.  CSV rows carry no repeated trials, so the
    per-cell spread is 0 and the lower-better noise floor does the gating;
    the machine-absolute micro rows (``rpc_path``/``spawn_overhead``) are
    tagged ``noise: micro`` so they get the wide cross-hardware clamps.

    ``apps`` is populated from the ingested rows (per-app segment for the
    app-parameterized benches, a ``_<bench>`` pseudo-app for the micros) so
    :func:`compare`'s missing-cell warning fires when a bench that produced
    a baseline row errors out of the current run.
    """
    records: List[Dict[str, Any]] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#") \
                    or line.startswith("name,"):
                continue
            parts = line.split(",")
            if len(parts) < 2:
                continue
            name, value = parts[0], parts[1]
            derived = parts[2] if len(parts) > 2 else ""
            if not name.startswith(_CSV_PREFIXES):
                continue
            if "/ERROR" in name or derived == "x" \
                    or derived.startswith("x_vs_"):
                continue
            try:
                val = float(value)
            except ValueError:
                continue
            segments = name.split("/")
            if name.startswith(("p99_latency/", "peak_throughput/")) \
                    and len(segments) >= 3:
                app = segments[1]        # p99_latency/<app>/<workload>/...
            else:
                app = "_" + segments[0]  # micro rows: pseudo-app per bench
            rec = {
                "key": name,
                "app": app,
                "metric": "us_per_call",
                "unit": "us",
                "direction": "lower",
                "value": val,
                "trials": [val],
            }
            if name.startswith(("rpc_path/", "spawn_overhead/")):
                rec["noise"] = "micro"   # machine-absolute: wide clamps
            records.append(rec)
    return {"schema_version": SCHEMA_VERSION, "records": records,
            "apps": sorted({r["app"] for r in records}), "from_csv": path}


def render_markdown(report: Dict[str, Any], *, current_name: str = "current",
                    baseline_name: str = "baseline") -> str:
    """Human summary for the CI artifact (``trend-<app>.md``)."""
    lines = [f"# Bench-smoke trend: `{current_name}` vs `{baseline_name}`",
             ""]
    badge = {"ok": "✅", "warn": "⚠️", "regression": "❌", "new": "🆕"}
    if report["rows"]:
        lines += ["| cell | dir | baseline | current | ratio | band | "
                  "status |",
                  "|---|---|---:|---:|---:|---:|---|"]
        for row in report["rows"]:
            arrow = "↓" if row.get("direction") == "lower" else "↑"
            lines.append(
                f"| {row['key']} "
                f"| {arrow} "
                f"| {row.get('baseline', float('nan')):.1f} "
                f"| {row['current']:.1f} "
                f"| {row.get('ratio', float('nan')):.2f} "
                f"| {row.get('band', float('nan')):.2f} "
                f"| {badge.get(row['status'], '')} {row['status']} |")
        lines.append("")
    for title, key in (("Regressions", "regressions"),
                       ("Warnings", "warnings"), ("Notes", "notes")):
        if report[key]:
            lines.append(f"## {title}")
            lines += [f"- {item}" for item in report[key]]
            lines.append("")
    if not report["regressions"]:
        lines.append("No regressions outside the noise band.")
    return "\n".join(lines) + "\n"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("current", help="smoke JSON from this run")
    ap.add_argument("baselines", nargs="+", metavar="baseline",
                    help="smoke JSON(s) to gate against — typically the "
                         "previous run's artifact AND the committed "
                         "baseline; a regression vs any of them fails")
    ap.add_argument("--md", default=None, metavar="PATH",
                    help="write a markdown summary here")
    ap.add_argument("--noise-floor", type=float, default=NOISE_FLOOR,
                    help=f"minimum relative band (default {NOISE_FLOOR})")
    ap.add_argument("--from-csv", action="store_true",
                    help="inputs are full-benchmark CSVs "
                         "(name,us_per_call,derived) instead of smoke "
                         "artifacts; p99/peak/rpc-path/spawn rows are "
                         "diffed lower-is-better")
    args = ap.parse_args(argv)

    if args.from_csv:
        current = artifact_from_csv(args.current)
    else:
        with open(args.current) as f:
            current = json.load(f)
    # a path given twice (prev-run lookup fell back to the committed file)
    # is compared once
    seen = set()
    baselines = [b for b in args.baselines
                 if not (b in seen or seen.add(b))]

    failed = False
    md_parts: List[str] = []
    for bpath in baselines:
        if args.from_csv:
            baseline = artifact_from_csv(bpath)
        else:
            with open(bpath) as f:
                baseline = json.load(f)
        try:
            report = compare(current, baseline, floor=args.noise_floor)
        except TrendError as exc:
            print(f"trend: {exc}", file=sys.stderr)
            return 2
        tag = f"[vs {bpath}]"
        md_parts.append(render_markdown(report, current_name=args.current,
                                        baseline_name=bpath))
        for note in report["notes"]:
            print(f"trend NOTE {tag}: {note}")
        for warn in report["warnings"]:
            print(f"trend WARN {tag}: {warn}")
        for reg in report["regressions"]:
            print(f"trend REGRESSION {tag}: {reg}", file=sys.stderr)
        n_ok = sum(1 for r in report["rows"] if r["status"] == "ok")
        print(f"trend {tag}: {len(report['rows'])} cells compared, "
              f"{n_ok} ok, {len(report['warnings'])} warn, "
              f"{len(report['regressions'])} regression(s)")
        failed = failed or bool(report["regressions"])

    if args.md:
        with open(args.md, "w") as f:
            f.write("\n---\n\n".join(md_parts))
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
