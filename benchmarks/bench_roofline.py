"""Roofline table from the multi-pod dry-run results.

Reads ``launch_results/dryrun.json`` (produced by
``python -m repro.launch.dryrun --all``) and emits one CSV row per
(arch x shape x mesh) cell: the bound step time, the dominant term, and the
roofline fraction.
"""
from __future__ import annotations

import json
import os
from typing import List

RESULTS = os.path.join(os.path.dirname(__file__), "..", "launch_results",
                       "dryrun.json")


def run(quick: bool = False) -> List[str]:
    path = os.path.abspath(RESULTS)
    if not os.path.exists(path):
        return ["roofline/missing,0,run launch.dryrun first"]
    with open(path) as f:
        results = json.load(f)
    rows = []
    for key in sorted(results):
        rec = results[key]
        name = key.replace("|", "/")
        if rec.get("status") == "skip":
            rows.append(f"roofline/{name},0,skip:{rec['reason'][:40]}")
            continue
        if rec.get("status") != "ok":
            rows.append(f"roofline/{name},0,error")
            continue
        r = rec["roofline"]
        t_bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        mem = rec.get("memory_tpu_corrected",
                      rec.get("memory", {})).get("per_device_total_bytes", 0)
        rows.append(
            f"roofline/{name},{t_bound * 1e6:.1f},"
            f"dom={r['dominant']};frac={r['roofline_fraction']:.3f};"
            f"mem_gib={mem / 2**30:.2f}")
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
