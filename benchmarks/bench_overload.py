"""Goodput past the peak: overload survival across the backend matrix.

The paper's protocol stops at the peak — "increase the request rate until
processed requests per second does not increase anymore".  This table asks
what happens *past* it: every app × backend cell is driven at a fixed
multiple of its own measured peak with per-request deadlines enforced, and
scored on

* **goodput** — completions within the deadline per second (raw rps past
  the peak rewards finishing requests nobody is still waiting for), and
* **recovery time** — after the overload window, how long until a
  comfortably-sustainable probe rate is served at healthy goodput again
  (how fast the backlog drains).

Each cell runs with the full resilience layer (``repro.core.resilience``):
per-hop deadline propagation, budgeted retries, per-edge circuit breakers.
The breakers-on-vs-off A/B comparison (interleaved paired rounds, same
runner weather) lives in ``bench_smoke._overload_probe`` so CI re-measures
it every run.

Rows follow the harness convention (``name,us_per_call,derived``): goodput
rows report ``1e6 / goodput`` in the us column with ``goodput_rps=`` in
derived; recovery rows report the recovery time in us with ``s=`` derived
(``inf`` recovery is reported as 0 goodput-style sentinel ``recovered=no``).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.apps import (APP_NAMES, BENCH_BACKENDS, build_bench_app,
                        get_app_def)
from repro.core import (ResiliencePolicy, RetryPolicy, find_peak_throughput,
                        run_overload, warmup)

MULTIPLE = 3.0        # overload rate = MULTIPLE x the cell's measured peak
WORKLOAD = "mixed"


def _policy(deadline: float) -> ResiliencePolicy:
    return ResiliencePolicy(deadline=deadline, retry=RetryPolicy(),
                            breakers=True)


def measure_overload(app_name: str, backend: str, *,
                     workload: str = WORKLOAD, multiple: float = MULTIPLE,
                     peak_duration: float = 0.4, duration: float = 1.0,
                     recovery_timeout: float = 5.0,
                     verbose: bool = False):
    """One cell: quick peak ramp, then ``multiple``x overload + recovery."""
    d = get_app_def(app_name)
    factory = d.make_request_factory(workload)
    deadline = d.deadlines.get(workload, 0.08)
    # peak measured on the app under test — the resilience-configured one.
    # A policy with breakers/retries routes nested hops through App.send
    # (per-edge accounting; tier-1 inlining steps aside), so its peak is
    # genuinely lower than the plain app's: overloading at a multiple of
    # the *plain* peak would start several-x past this system's capacity
    # and the recovery probe would never be sustainable.  3x *its own*
    # peak is the protocol; the plain-vs-policy capacity gap is quoted by
    # the ordinary peak_throughput table.
    with build_bench_app(app_name, backend,
                         resilience=_policy(deadline)) as app:
        warmup(app, factory)
        pk = find_peak_throughput(app, factory, start_rate=200, growth=1.7,
                                  duration=peak_duration, max_trials=10,
                                  verbose=verbose)
    # fresh app for the overload phase: ramp-phase breaker state and
    # counters must not leak into the reported cell
    with build_bench_app(app_name, backend,
                         resilience=_policy(deadline)) as app:
        warmup(app, factory)
        res = run_overload(app, factory, peak_rps=pk.peak_rps,
                           deadline=deadline, multiple=multiple,
                           duration=duration,
                           recovery_timeout=recovery_timeout,
                           verbose=verbose)
        stats = app.backend_stats()
    return res, stats


def run(quick: bool = False,
        apps: Optional[Sequence[str]] = None) -> List[str]:
    peak_duration = 0.25 if quick else 0.4
    duration = 0.5 if quick else 1.0
    recovery_timeout = 3.0 if quick else 5.0
    apps = list(apps) if apps else list(APP_NAMES)
    rows: List[str] = []
    for app_name in apps:
        for backend in BENCH_BACKENDS:
            res, stats = measure_overload(
                app_name, backend, peak_duration=peak_duration,
                duration=duration, recovery_timeout=recovery_timeout)
            g = res.overload.goodput_rps
            derived = (f"goodput_rps={g:.0f};peak_rps={res.peak_rps:.0f};"
                       f"offered_rps={res.overload_rps:.0f};"
                       f"to={stats.timeouts};rtry={stats.retries};"
                       f"brko={stats.breaker_opens};rej={stats.rejections}")
            rows.append(f"overload/{app_name}/{WORKLOAD}/{backend}/goodput,"
                        f"{1e6 / max(g, 1e-9):.2f},{derived}")
            rec = res.recovery_time if res.recovered else float("inf")
            rec_derived = (f"s={rec:.3f};recovered="
                           f"{'yes' if res.recovered else 'no'};"
                           f"probes={len(res.probes)}")
            rec_us = rec * 1e6 if res.recovered else 0.0
            rows.append(f"overload/{app_name}/{WORKLOAD}/{backend}/recovery,"
                        f"{rec_us:.0f},{rec_derived}")
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
