"""Goodput past the peak: overload survival across the backend matrix.

The paper's protocol stops at the peak — "increase the request rate until
processed requests per second does not increase anymore".  This table asks
what happens *past* it, in three movements:

* **collapse-knee sweep** — every app × backend cell is driven at 2x, 3x,
  4x and 5x its own measured peak with per-request deadlines enforced,
  producing a goodput-vs-offered curve.  The **knee** is the largest
  multiple whose goodput still holds ``KNEE_FRACTION`` of the cell's best
  goodput across the sweep: the last sustainable point before congestion
  collapse.  A cell whose goodput never drops below the fraction reports
  the top of the sweep range (``collapsed=no`` — its knee is >= 5x).
* **recovery** — after a 3x overload window, how long until a
  comfortably-sustainable probe rate is served at healthy goodput again
  (how fast the backlog drains; same protocol as PR 6).
* **retry storm** — one app driven past its peak with an effectively
  *uncapped* retry budget and no breakers: the metastable-failure
  ingredient.  Scored on **amplification** (delivered attempts per offered
  request, ``1 + retries/offered``) per queueing discipline — the
  mailbox/carrier design each backend uses is exactly what shapes how a
  storm feeds on itself.  The same recipe also runs on two synthetic
  topologies (``STORM_SHAPES``: a ``deep-chain`` of serial hops and a
  ``wide-fan`` of parallel leaves) so the artifact separates what the
  *graph shape* contributes to amplification from what the backend does —
  socialnetwork/mixed sits between the extremes.

Each sweep/recovery cell runs the full resilience layer
(``repro.core.resilience``): per-hop deadline propagation, budgeted
retries, per-edge circuit breakers.  The breakers-on-vs-off A/B comparison
(interleaved paired rounds, same runner weather) lives in
``bench_smoke._overload_probe``; the smoke lane also records a warn-only
knee trend cell via ``measure_collapse_sweep`` at smoke scale.

Rows follow the harness convention (``name,us_per_call,derived``): goodput
rows report ``1e6 / goodput`` in the us column with ``goodput_rps=`` in
derived (one row per sweep multiple, plus the legacy bare ``goodput`` row
for the 3x point); ``knee`` rows put the knee *multiple* in the value
column; recovery rows report the recovery time in us with ``s=`` derived
(``inf`` recovery is reported as 0 goodput-style sentinel
``recovered=no``); ``retry_storm`` rows put the amplification factor in
the value column.  The whole sweep is also written as a JSON artifact
(default ``launch_results/overload_sweep.json``) so the curves survive
with more structure than the CSV rows carry.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.apps import (APP_NAMES, BENCH_BACKENDS, build_bench_app,
                        get_app_def)
from repro.core import (App, AsyncRpc, Compute, ResiliencePolicy, RetryPolicy,
                        ServiceSpec, Sleep, Wait, WaitAll,
                        find_peak_throughput, run_overload, run_trial, warmup)

MULTIPLE = 3.0        # the recovery phase's overload rate (PR 6 protocol)
SWEEP_MULTIPLES = (2.0, 3.0, 4.0, 5.0)
KNEE_FRACTION = 0.7   # goodput >= this fraction of the sweep's best => held
WORKLOAD = "mixed"
STORM_APP = "socialnetwork"   # the retry storm runs on one app, per backend

# Synthetic graph shapes for the storm's topology axis: retry traffic
# compounds differently down a serial chain (every hop's retry re-offers
# the whole tail of the chain) than across a parallel fan (leaf retries
# are independent; one slow leaf only stalls its own join slot), and the
# real apps sit between the two extremes.  socialnetwork/mixed stays in
# the sweep as the mixed-topology reference point.
STORM_SHAPES = ("deep-chain", "wide-fan")
SHAPE_DEPTH = 4       # hops under the frontend in the deep chain
SHAPE_WIDTH = 8       # leaves under the frontend in the wide fan
SHAPE_DEADLINE = 0.08
_SHAPE_CPU = 20e-6
_SHAPE_IO = 300e-6

ARTIFACT_DEFAULT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "launch_results", "overload_sweep.json")


def _policy(deadline: float) -> ResiliencePolicy:
    return ResiliencePolicy(deadline=deadline, retry=RetryPolicy(),
                            breakers=True)


def _storm_policy(deadline: float) -> ResiliencePolicy:
    """The metastable configuration: bounded mailboxes + retries with an
    effectively unbounded token budget and no breakers.  Deadline expiries
    are never retried by design, so an *unbounded* queue under overload
    produces no retry traffic at all; the bound converts excess arrivals
    into ``Rejected`` — a retryable failure — and with the budget
    uncapped every rejection is re-sent up to the attempt cap.  Nothing
    fails fast, nothing extinguishes the storm: each retry is another
    arrival at the same full mailbox."""
    return ResiliencePolicy(
        deadline=deadline, breakers=False, mailbox_bound=128,
        retry=RetryPolicy(max_attempts=4, base_backoff=0.001,
                          max_backoff=0.004,
                          budget_initial=1e9, budget_ratio=1.0,
                          budget_cap=1e9))


def _shape_leaf(svc: Any, payload: Any):
    yield Compute(_SHAPE_CPU)
    yield Sleep(_SHAPE_IO)
    return {"ok": True}


def _chain_stage(nxt: str):
    def stage(svc: Any, payload: Any):
        yield Compute(_SHAPE_CPU)
        f = yield AsyncRpc(nxt, "call", payload)
        return (yield Wait(f))
    return stage


def _fan_root(leaves: Sequence[str]):
    def root(svc: Any, payload: Any):
        yield Compute(_SHAPE_CPU)
        futs = []
        for leaf in leaves:
            futs.append((yield AsyncRpc(leaf, "call", payload)))
        yield WaitAll(futs)
        return {"ok": True}
    return root


def build_shape_app(shape: str, backend: str, *,
                    resilience: Any = None) -> App:
    """Wire one synthetic storm topology with build_bench_app's sizing."""
    if backend.startswith("thread"):
        workers, fe_workers = 8, 16
    elif backend == "event-loop":
        workers, fe_workers = 1, 1
    elif backend == "event-loop-shard":
        workers, fe_workers = 1, 4
    else:
        workers, fe_workers = 2, 2
    app = App(backend=backend, resilience=resilience)
    if shape == "deep-chain":
        hops = [f"hop{i}" for i in range(1, SHAPE_DEPTH + 1)]
        app.add_service(ServiceSpec(
            name="frontend", handlers={"call": _chain_stage(hops[0])},
            n_workers=fe_workers))
        for i, name in enumerate(hops):
            h = (_shape_leaf if i == len(hops) - 1
                 else _chain_stage(hops[i + 1]))
            app.add_service(ServiceSpec(
                name=name, handlers={"call": h}, n_workers=workers))
    elif shape == "wide-fan":
        leaves = [f"leaf{i}" for i in range(SHAPE_WIDTH)]
        app.add_service(ServiceSpec(
            name="frontend", handlers={"call": _fan_root(leaves)},
            n_workers=fe_workers))
        for name in leaves:
            app.add_service(ServiceSpec(
                name=name, handlers={"call": _shape_leaf},
                n_workers=workers))
    else:
        raise ValueError(
            f"unknown shape {shape!r} (want one of {STORM_SHAPES})")
    return app


def _shape_factory(rng):
    return ("frontend", "call", {})


def _measure_peak(app_name: str, backend: str, policy: ResiliencePolicy,
                  factory, *, peak_duration: float,
                  verbose: bool = False) -> float:
    # peak measured on the app under test — the resilience-configured one.
    # Overloading at a multiple of the *plain* peak would start several-x
    # past this system's capacity; the plain-vs-policy capacity gap is
    # quoted by the ordinary peak_throughput table.
    with build_bench_app(app_name, backend, resilience=policy) as app:
        warmup(app, factory)
        pk = find_peak_throughput(app, factory, start_rate=200, growth=1.7,
                                  duration=peak_duration, max_trials=10,
                                  verbose=verbose)
    return pk.peak_rps


def collapse_knee(curve: List[Dict[str, Any]],
                  fraction: float = KNEE_FRACTION) -> Tuple[float, bool]:
    """Locate the collapse knee on a goodput-vs-offered curve.

    ``curve`` is a list of ``{"multiple", "goodput_rps", ...}`` points.
    Returns ``(knee_multiple, collapsed)``: the largest multiple whose
    goodput holds ``fraction`` of the best goodput anywhere on the sweep,
    and whether any point fell below it (``collapsed=False`` means the
    knee lies at or beyond the top of the sweep range).  If even the
    lowest multiple is below the fraction (a cell already drowning at 2x),
    the knee reports one notch *below* the sweep — the smallest multiple
    minus 1 — so the artifact still carries a number and the trend line
    still moves when the cell degrades further.
    """
    if not curve:
        return float("nan"), False
    best = max(p["goodput_rps"] for p in curve)
    held = [p["multiple"] for p in curve
            if best > 0 and p["goodput_rps"] >= fraction * best]
    collapsed = len(held) < len(curve)
    if not held:
        return min(p["multiple"] for p in curve) - 1.0, True
    return max(held), collapsed


def measure_collapse_sweep(app_name: str, backend: str, *,
                           workload: str = WORKLOAD,
                           multiples: Sequence[float] = SWEEP_MULTIPLES,
                           peak_duration: float = 0.4, duration: float = 1.0,
                           verbose: bool = False) -> Dict[str, Any]:
    """One cell's goodput-vs-offered curve + knee.

    Each multiple runs on a *fresh* app (same build, same policy): breaker
    state and executor counters from one overload point must not leak into
    the next, and the curve should be four independent measurements of
    "what does this system do at m x peak", not a history-dependent ramp.
    """
    d = get_app_def(app_name)
    factory = d.make_request_factory(workload)
    deadline = d.deadlines.get(workload, 0.08)
    peak = _measure_peak(app_name, backend, _policy(deadline), factory,
                         peak_duration=peak_duration, verbose=verbose)
    curve: List[Dict[str, Any]] = []
    for m in multiples:
        with build_bench_app(app_name, backend,
                             resilience=_policy(deadline)) as app:
            warmup(app, factory)
            tr = run_trial(app, factory, m * peak, duration, seed=7,
                           drain=0.25, deadline=deadline,
                           enforce_deadline=True, settle=1.0)
        bs = tr.backend_stats
        curve.append({
            "multiple": m,
            "offered_rps": round(m * peak, 1),
            "achieved_rps": round(tr.achieved_rps, 1),
            "goodput_rps": round(tr.goodput_rps, 1),
            "timeouts": int(bs.get("timeouts", 0)),
            "retries": int(bs.get("retries", 0)),
            "breaker_opens": int(bs.get("breaker_opens", 0)),
            "rejections": int(bs.get("rejections", 0)),
            "bulkhead_rejections": int(bs.get("bulkhead_rejections", 0)),
        })
        if verbose:
            print(f"    sweep {m:g}x", tr.row(), flush=True)
    knee, collapsed = collapse_knee(curve)
    return {
        "app": app_name,
        "backend": backend,
        "workload": workload,
        "peak_rps": round(peak, 1),
        "deadline_s": deadline,
        "knee_fraction": KNEE_FRACTION,
        "curve": curve,
        "knee_multiple": knee,
        "collapsed": collapsed,
    }


def measure_overload(app_name: str, backend: str, *,
                     workload: str = WORKLOAD, multiple: float = MULTIPLE,
                     peak_duration: float = 0.4, duration: float = 1.0,
                     recovery_timeout: float = 5.0,
                     peak_rps: Optional[float] = None,
                     verbose: bool = False):
    """One cell: ``multiple``x overload + recovery (quick peak ramp first
    unless the caller already measured ``peak_rps``)."""
    d = get_app_def(app_name)
    factory = d.make_request_factory(workload)
    deadline = d.deadlines.get(workload, 0.08)
    if peak_rps is None:
        peak_rps = _measure_peak(app_name, backend, _policy(deadline),
                                 factory, peak_duration=peak_duration,
                                 verbose=verbose)
    # fresh app for the overload phase: ramp-phase breaker state and
    # counters must not leak into the reported cell
    with build_bench_app(app_name, backend,
                         resilience=_policy(deadline)) as app:
        warmup(app, factory)
        res = run_overload(app, factory, peak_rps=peak_rps,
                           deadline=deadline, multiple=multiple,
                           duration=duration,
                           recovery_timeout=recovery_timeout,
                           verbose=verbose)
        stats = app.backend_stats()
    return res, stats


def measure_retry_storm(app_name: str, backend: str, *,
                        workload: str = WORKLOAD, multiple: float = MULTIPLE,
                        peak_duration: float = 0.4, duration: float = 1.0,
                        verbose: bool = False) -> Dict[str, Any]:
    """Retry amplification past the peak with an uncapped budget.

    Amplification = delivered attempts per offered request
    (``1 + retries / offered``).  With the token budget effectively
    infinite, the only damper left is the attempt cap — how close each
    queueing discipline gets to that ceiling under the same overload is
    the metastability exposure being measured.
    """
    d = get_app_def(app_name)
    factory = d.make_request_factory(workload)
    deadline = d.deadlines.get(workload, 0.08)
    build = (lambda: build_bench_app(app_name, backend,
                                     resilience=_storm_policy(deadline)))
    cell = _storm_cell(build, factory, deadline, multiple=multiple,
                       peak_duration=peak_duration, duration=duration,
                       verbose=verbose)
    return {"app": app_name, "backend": backend, "workload": workload, **cell}


def measure_shape_storm(shape: str, backend: str, *,
                        multiple: float = MULTIPLE,
                        peak_duration: float = 0.4, duration: float = 1.0,
                        verbose: bool = False) -> Dict[str, Any]:
    """Retry amplification on one synthetic topology (see STORM_SHAPES)."""
    deadline = SHAPE_DEADLINE
    build = (lambda: build_shape_app(shape, backend,
                                     resilience=_storm_policy(deadline)))
    cell = _storm_cell(build, _shape_factory, deadline, multiple=multiple,
                       peak_duration=peak_duration, duration=duration,
                       verbose=verbose)
    return {"shape": shape, "backend": backend,
            "depth": SHAPE_DEPTH if shape == "deep-chain" else 1,
            "width": SHAPE_WIDTH if shape == "wide-fan" else 1, **cell}


def _storm_cell(build, factory, deadline: float, *, multiple: float,
                peak_duration: float, duration: float,
                verbose: bool = False) -> Dict[str, Any]:
    with build() as app:
        warmup(app, factory)
        pk = find_peak_throughput(app, factory, start_rate=200, growth=1.7,
                                  duration=peak_duration, max_trials=10,
                                  verbose=verbose)
    peak = pk.peak_rps
    with build() as app:
        warmup(app, factory)
        tr = run_trial(app, factory, multiple * peak, duration, seed=9,
                       drain=0.25, deadline=deadline,
                       enforce_deadline=True, settle=1.0)
    bs = tr.backend_stats
    retries = int(bs.get("retries", 0))
    offered = max(tr.offered, 1)
    return {
        "peak_rps": round(peak, 1),
        "multiple": multiple,
        "offered": tr.offered,
        "retries": retries,
        "timeouts": int(bs.get("timeouts", 0)),
        "amplification": round(1.0 + retries / offered, 3),
        "goodput_rps": round(tr.goodput_rps, 1),
    }


def run(quick: bool = False,
        apps: Optional[Sequence[str]] = None,
        json_path: Optional[str] = ARTIFACT_DEFAULT) -> List[str]:
    peak_duration = 0.25 if quick else 0.4
    duration = 0.5 if quick else 1.0
    recovery_timeout = 3.0 if quick else 5.0
    apps = list(apps) if apps else list(APP_NAMES)
    rows: List[str] = []
    artifact: Dict[str, Any] = {
        "schema_version": 1,
        "workload": WORKLOAD,
        "multiples": list(SWEEP_MULTIPLES),
        "knee_fraction": KNEE_FRACTION,
        "cells": {},
        "retry_storm": {},
        "retry_storm_shapes": {},
    }
    for app_name in apps:
        for backend in BENCH_BACKENDS:
            cell = measure_collapse_sweep(
                app_name, backend, peak_duration=peak_duration,
                duration=duration)
            key = f"{app_name}/{backend}"
            base = f"overload/{app_name}/{WORKLOAD}/{backend}"
            for p in cell["curve"]:
                g = p["goodput_rps"]
                derived = (f"goodput_rps={g:.0f};"
                           f"peak_rps={cell['peak_rps']:.0f};"
                           f"offered_rps={p['offered_rps']:.0f};"
                           f"to={p['timeouts']};rtry={p['retries']};"
                           f"brko={p['breaker_opens']};"
                           f"rej={p['rejections']};"
                           f"bhrej={p['bulkhead_rejections']}")
                rows.append(f"{base}/goodput@{p['multiple']:g}x,"
                            f"{1e6 / max(g, 1e-9):.2f},{derived}")
                if p["multiple"] == MULTIPLE:
                    # legacy PR 6 row name for CSV continuity
                    rows.append(f"{base}/goodput,"
                                f"{1e6 / max(g, 1e-9):.2f},{derived}")
            knee_derived = (f"knee_multiple={cell['knee_multiple']:g};"
                            f"collapsed="
                            f"{'yes' if cell['collapsed'] else 'no'};"
                            f"curve=" + "|".join(
                                f"{p['multiple']:g}:{p['goodput_rps']:.0f}"
                                for p in cell["curve"]))
            rows.append(f"{base}/knee,{cell['knee_multiple']:g},"
                        f"{knee_derived}")
            # recovery continuity row (3x overload + probe-until-healthy),
            # reusing the sweep's peak so the ramp is paid once per cell
            res, stats = measure_overload(
                app_name, backend, duration=duration,
                recovery_timeout=recovery_timeout,
                peak_rps=cell["peak_rps"])
            rec = res.recovery_time if res.recovered else float("inf")
            rec_derived = (f"s={rec:.3f};recovered="
                           f"{'yes' if res.recovered else 'no'};"
                           f"probes={len(res.probes)}")
            rec_us = rec * 1e6 if res.recovered else 0.0
            rows.append(f"{base}/recovery,{rec_us:.0f},{rec_derived}")
            cell["recovery"] = {
                "recovered": res.recovered,
                "recovery_time_s": (round(res.recovery_time, 3)
                                    if res.recovered else None),
                "probes": len(res.probes),
                "overload_goodput_rps": round(res.overload.goodput_rps, 1),
            }
            artifact["cells"][key] = cell
    if STORM_APP in apps:
        for backend in BENCH_BACKENDS:
            storm = measure_retry_storm(
                STORM_APP, backend, peak_duration=peak_duration,
                duration=duration)
            rows.append(
                f"overload/{STORM_APP}/{WORKLOAD}/{backend}/retry_storm,"
                f"{storm['amplification']:.3f},"
                f"amplification={storm['amplification']:.3f};"
                f"retries={storm['retries']};offered={storm['offered']};"
                f"to={storm['timeouts']};"
                f"goodput_rps={storm['goodput_rps']:.0f}")
            artifact["retry_storm"][backend] = storm
        # topology axis: the same storm recipe on synthetic extremes
        # (serial chain vs parallel fan; socialnetwork/mixed above is the
        # in-between reference).  Rows live under overload/shape/ so the
        # app-keyed rows above keep their PR 6 names.
        for shape in STORM_SHAPES:
            for backend in BENCH_BACKENDS:
                storm = measure_shape_storm(
                    shape, backend, peak_duration=peak_duration,
                    duration=duration)
                rows.append(
                    f"overload/shape/{shape}/{backend}/retry_storm,"
                    f"{storm['amplification']:.3f},"
                    f"amplification={storm['amplification']:.3f};"
                    f"retries={storm['retries']};offered={storm['offered']};"
                    f"to={storm['timeouts']};"
                    f"goodput_rps={storm['goodput_rps']:.0f}")
                artifact["retry_storm_shapes"][f"{shape}/{backend}"] = storm
    if json_path:
        os.makedirs(os.path.dirname(json_path), exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
