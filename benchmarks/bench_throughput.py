"""Paper Figure 1: peak throughput per workload, thread vs fiber.

Protocol follows the paper: ramp the open-loop request rate until processed
requests/s stops increasing; report the best achieved rate.  Worker pools are
sized generously for the thread backend (DSB's thread-per-connection Thrift
servers) so that async-call spawn cost — not pool size — is the binding
constraint, as in the paper's setup.
"""
from __future__ import annotations

from typing import Dict, List

from repro.apps import WORKLOADS, build_socialnetwork, make_request_factory
from repro.core import find_peak_throughput, run_trial


def _app_for(backend: str):
    if backend == "thread":
        return build_socialnetwork("thread", n_workers=8, frontend_workers=16)
    return build_socialnetwork("fiber", n_workers=2, frontend_workers=2)


def measure_peak(backend: str, workload: str, *, duration: float = 1.0,
                 verbose: bool = False) -> float:
    with _app_for(backend) as app:
        # warmup (calibration + code paths)
        run_trial(app, make_request_factory(workload), rate=100,
                  duration=0.3, seed=99)
        pk = find_peak_throughput(app, make_request_factory(workload),
                                  start_rate=200, duration=duration,
                                  growth=1.7, verbose=verbose)
    return pk.peak_rps


def run(quick: bool = False) -> List[str]:
    duration = 0.5 if quick else 1.0
    rows: List[str] = []
    peaks: Dict[str, Dict[str, float]] = {}
    for workload in WORKLOADS:
        peaks[workload] = {}
        for backend in ("thread", "fiber"):
            p = measure_peak(backend, workload, duration=duration)
            peaks[workload][backend] = p
            rows.append(f"peak_throughput/{workload}/{backend},"
                        f"{1e6 / max(p, 1e-9):.2f},rps={p:.0f}")
        gain = peaks[workload]["fiber"] / max(peaks[workload]["thread"], 1e-9)
        rows.append(f"peak_throughput/{workload}/fiber_gain,{gain:.2f},x")
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
