"""Paper Figure 1: peak throughput per workload, across every backend.

Protocol follows the paper: ramp the open-loop request rate until processed
requests/s stops increasing; report the best achieved rate.  Runs every app
in ``repro.apps.REGISTRY`` (SocialNetwork, HotelReservation, MediaService)
crossed with every registered execution backend (``BENCH_BACKENDS``: thread,
thread-pool, fiber, fiber-steal, fiber-batch, fiber-batch-cq, event-loop,
event-loop-shard), so the headline
claim is measured across service-graph shapes *and* dispatch mechanisms,
not one hand-picked pair.
Worker pools are sized generously for the thread-family backends (DSB's
thread-per-connection Thrift servers) so that async-call spawn cost — not
pool size — is the binding constraint.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.apps import APP_NAMES, BENCH_BACKENDS, build_bench_app, get_app_def
from repro.core import find_peak_throughput, warmup

BACKENDS = BENCH_BACKENDS
BASELINE = "thread"  # gains are reported relative to the paper's baseline


def measure_peak(app_name: str, backend: str, workload: str, *,
                 duration: float = 1.0, verbose: bool = False) -> float:
    d = get_app_def(app_name)
    with build_bench_app(app_name, backend) as app:
        warmup(app, d.make_request_factory(workload))
        pk = find_peak_throughput(app, d.make_request_factory(workload),
                                  start_rate=200, duration=duration,
                                  growth=1.7, verbose=verbose)
    return pk.peak_rps


def run(quick: bool = False,
        apps: Optional[Sequence[str]] = None) -> List[str]:
    duration = 0.5 if quick else 1.0
    apps = list(apps) if apps else list(APP_NAMES)
    rows: List[str] = []
    for app_name in apps:
        d = get_app_def(app_name)
        peaks: Dict[str, Dict[str, float]] = {}
        for workload in d.workloads:
            peaks[workload] = {}
            for backend in BACKENDS:
                p = measure_peak(app_name, backend, workload,
                                 duration=duration)
                peaks[workload][backend] = p
                rows.append(f"peak_throughput/{app_name}/{workload}/{backend},"
                            f"{1e6 / max(p, 1e-9):.2f},rps={p:.0f}")
            base = max(peaks[workload][BASELINE], 1e-9)
            for backend in BACKENDS:
                if backend == BASELINE:
                    continue
                gain = peaks[workload][backend] / base
                rows.append(f"peak_throughput/{app_name}/{workload}/"
                            f"{backend}_gain,{gain:.2f},x")
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
