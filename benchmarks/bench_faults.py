"""Sick-dependency scenarios: the resilience layer against its design target.

PR 6 measured the resilience layer under *uniform* overload and got an
honest but bimodal breaker verdict; its diagnosis — "breakers pay off
against a sick dependency, not uniform pressure" — is exactly what this
harness makes measurable.  Every app names a **sick** write-path storage
edge and a **healthy** read-path method of the same service
(``AppDef.fault_targets``), and each app × backend cell runs three
movements on the ``mixed`` workload at a comfortably-sustainable rate
(``RATE_FRACTION`` of the cell's measured healthy peak):

* **breaker A/B** — a seeded :class:`~repro.core.faults.FaultPlan` brownout
  (``SICK_FACTOR``× service time, far past the request deadline) degrades
  the sick edge for the whole trial; goodput with breakers vs without, same
  arrival seed.  Without breakers every write burns ``SICK_FACTOR``× CPU
  and a worker slot before dying at its deadline — dead work that starves
  the read path; with breakers the sick edge trips after
  ``breaker_min_volume`` failures and writes fail fast instead.  The
  scenario is deterministic by construction (no probabilistic rules, seeded
  arrivals), so the win direction is reproducible — the result PR 6 could
  only glimpse.
* **blast radius** — the same sick trial, breakers on, against a no-fault
  reference at the same rate: how much healthy-edge goodput is retained,
  and ``App.resilience_by_edge()`` showing the sick edge tripping while the
  healthy read method of the *same service* stays closed.
* **recovery** — the fault window closes at a known instant (the trial
  clock makes "lifts at t=duration" exact); probes at half rate measure the
  time until goodput is healthy again, against PR 6's 0.25–0.6 s
  uniform-overload baseline (dominated by ``breaker_reset``, since the
  dependency is genuinely healthy the moment the fault lifts).

Rows follow the harness convention (``name,value,derived``):
``breaker_win`` rows put the on/off goodput ratio in the value column,
``blast_radius`` rows the healthy-goodput-retained fraction, ``recovery``
rows the time-to-recover in us (``recovered=no`` reports the 0 sentinel,
as in bench_overload).  The full matrix is also written as a JSON artifact
(default ``launch_results/faults_sweep.json``).
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Optional, Sequence

from repro.apps import (APP_NAMES, BENCH_BACKENDS, build_bench_app,
                        get_app_def)
from repro.core import (FaultPlan, FaultRule, ResiliencePolicy,
                        find_peak_throughput, run_trial, warmup)

WORKLOAD = "mixed"
SICK_FACTOR = 600.0     # brownout multiplier on the sick edge: ~800us of
                        # storage sleep blows past every deadline and ~20us
                        # of CPU becomes ~12ms of dead burn per write — on
                        # this repo's 1-core CI box that burn is the poison
                        # breakers-off keeps paying and breakers-on stops
                        # after breaker_min_volume failures + rare probes
RATE_FRACTION = 0.6     # offered rate as a fraction of the healthy peak
SICK_SEED = 42          # FaultPlan seed (bit-reproducible schedule)
TRIAL_SEED = 11         # arrival seed, shared by all three movements
RECOVERY_THRESHOLD = 0.9
RECOVERY_RATE_FRACTION = 0.5

ARTIFACT_DEFAULT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "launch_results", "faults_sweep.json")


def _policy(deadline: float, breakers: bool) -> ResiliencePolicy:
    """Deadline + breakers on/off, no retries: the A/B isolates the breaker
    contribution (retry storms are bench_overload's axis)."""
    return ResiliencePolicy(deadline=deadline, retry=None, breakers=breakers)


def _sick_plan(app_name: str, *, stop: float = float("inf")) -> FaultPlan:
    """The scenario's seeded plan: one brownout rule on the app's
    registered sick edge, active from trial start to ``stop``."""
    dest, method = get_app_def(app_name).fault_targets["sick"]
    return FaultPlan([FaultRule(dest=dest, method=method, kind="brownout",
                                factor=SICK_FACTOR, stop=stop)],
                     seed=SICK_SEED)


def _measure_peak(app_name: str, backend: str, policy: ResiliencePolicy,
                  factory, *, peak_duration: float,
                  verbose: bool = False) -> float:
    with build_bench_app(app_name, backend, resilience=policy) as app:
        warmup(app, factory)
        pk = find_peak_throughput(app, factory, start_rate=200, growth=1.7,
                                  duration=peak_duration, max_trials=10,
                                  verbose=verbose)
    return pk.peak_rps


def measure_sick_cell(app_name: str, backend: str, *,
                      peak_duration: float = 0.4, duration: float = 2.0,
                      recovery_timeout: float = 3.0,
                      verbose: bool = False) -> Dict[str, Any]:
    """One app × backend cell: healthy reference, breaker A/B under the
    sick-edge brownout, per-edge blast radius, and time-to-recover after
    the fault lifts.  All trials share the arrival seed, so the A/B and
    the reference see the identical offered sequence."""
    d = get_app_def(app_name)
    factory = d.make_request_factory(WORKLOAD)
    deadline = d.deadlines.get(WORKLOAD, 0.08)
    sick_edge = tuple(d.fault_targets["sick"])
    healthy_edge = tuple(d.fault_targets["healthy"])
    peak = _measure_peak(app_name, backend, _policy(deadline, True), factory,
                         peak_duration=peak_duration, verbose=verbose)
    rate = max(RATE_FRACTION * peak, 50.0)

    def _trial(app, dur: float, *, arm: Optional[bool], seed: int = TRIAL_SEED,
               r: float = rate, drain: float = 1.0):
        return run_trial(app, factory, r, dur, seed=seed, drain=drain,
                         deadline=deadline, enforce_deadline=True,
                         settle=1.0, arm_faults=arm)

    # healthy reference: breakers on, no faults — the blast-radius yardstick
    with build_bench_app(app_name, backend,
                         resilience=_policy(deadline, True)) as app:
        warmup(app, factory)
        healthy_tr = _trial(app, duration, arm=None)
    if verbose:
        print("    healthy     ", healthy_tr.row(), flush=True)

    # breaker A/B under the sick-edge brownout (fresh app per side; the
    # plan is armed on each measured trial's clock, after a healthy warmup)
    sides: Dict[str, Any] = {}
    for label, breakers in (("on", True), ("off", False)):
        app = build_bench_app(app_name, backend,
                              resilience=_policy(deadline, breakers))
        with app:
            warmup(app, factory)
            app.set_faults(_sick_plan(app_name))
            tr = _trial(app, duration, arm=True)
            by_edge = app.resilience_by_edge()
        bs = tr.backend_stats
        sides[label] = {
            "goodput_rps": round(tr.goodput_rps, 1),
            "good": tr.good,
            "errors": tr.errors,
            "timeouts": int(bs.get("timeouts", 0)),
            "breaker_opens": int(bs.get("breaker_opens", 0)),
            "faults_injected": int(bs.get("faults_injected", 0)),
            "faults_brownout": int(bs.get("faults_brownout", 0)),
            "sick_edge_opens": int(by_edge.get(sick_edge,
                                               {}).get("opens", 0)),
            "healthy_edge_opens": int(by_edge.get(healthy_edge,
                                                  {}).get("opens", 0)),
        }
        if verbose:
            print(f"    breakers-{label:3s}", tr.row(), flush=True)

    on_g = sides["on"]["goodput_rps"]
    off_g = sides["off"]["goodput_rps"]
    healthy_g = healthy_tr.goodput_rps

    # recovery: same sick scenario, breakers on, but the rule's window
    # closes exactly at the end of the offered window — then probe at half
    # rate until goodput is healthy again (PR 6 protocol, short drain so
    # the backlog persists into the probes)
    app = build_bench_app(app_name, backend,
                          resilience=_policy(deadline, True))
    probes = 0
    recovered = False
    recovery_time = float("inf")
    with app:
        warmup(app, factory)
        app.set_faults(_sick_plan(app_name, stop=duration))
        _trial(app, duration, arm=True, drain=0.25)
        t_lift = time.monotonic()
        rrate = RECOVERY_RATE_FRACTION * rate
        i = 0
        while time.monotonic() - t_lift < recovery_timeout:
            p = _trial(app, 0.25, arm=False, seed=TRIAL_SEED + 100 + i,
                       r=rrate, drain=0.25)
            probes += 1
            if p.goodput_rps >= RECOVERY_THRESHOLD * rrate:
                recovered = True
                recovery_time = time.monotonic() - t_lift
                break
            i += 1

    return {
        "app": app_name,
        "backend": backend,
        "workload": WORKLOAD,
        "deadline_s": deadline,
        "peak_rps": round(peak, 1),
        "rate_rps": round(rate, 1),
        "sick_edge": list(sick_edge),
        "healthy_edge": list(healthy_edge),
        "sick_factor": SICK_FACTOR,
        "seed": SICK_SEED,
        "healthy_goodput_rps": round(healthy_g, 1),
        "breakers": sides,
        # capped: when the off side's goodput hits zero the raw ratio is a
        # division by epsilon, and "9999x" already reads as "off side dead"
        "breaker_win": round(min(on_g / max(off_g, 1e-9), 9999.0), 3),
        "healthy_retained": round(on_g / max(healthy_g, 1e-9), 3),
        "recovery": {
            "recovered": recovered,
            "recovery_time_s": (round(recovery_time, 3)
                                if recovered else None),
            "probes": probes,
        },
    }


def run(quick: bool = False,
        apps: Optional[Sequence[str]] = None,
        json_path: Optional[str] = ARTIFACT_DEFAULT) -> List[str]:
    # the measured trial must dwarf the breakers-on side's fixed startup
    # collateral (the pre-trip brownout burns) or the A/B margin shrinks
    peak_duration = 0.3 if quick else 0.4
    duration = 1.0 if quick else 2.0
    recovery_timeout = 2.0 if quick else 3.0
    apps = list(apps) if apps else list(APP_NAMES)
    rows: List[str] = []
    artifact: Dict[str, Any] = {
        "schema_version": 1,
        "workload": WORKLOAD,
        "sick_factor": SICK_FACTOR,
        "rate_fraction": RATE_FRACTION,
        "seed": SICK_SEED,
        "cells": {},
    }
    for app_name in apps:
        for backend in BENCH_BACKENDS:
            cell = measure_sick_cell(
                app_name, backend, peak_duration=peak_duration,
                duration=duration, recovery_timeout=recovery_timeout)
            artifact["cells"][f"{app_name}/{backend}"] = cell
            base = f"faults/{app_name}/{WORKLOAD}/{backend}"
            on, off = cell["breakers"]["on"], cell["breakers"]["off"]
            rows.append(
                f"{base}/breaker_win,{cell['breaker_win']:.3f},"
                f"on_goodput={on['goodput_rps']:.0f};"
                f"off_goodput={off['goodput_rps']:.0f};"
                f"rate={cell['rate_rps']:.0f};"
                f"sick_opens={on['sick_edge_opens']};"
                f"flt={on['faults_injected']}")
            rows.append(
                f"{base}/blast_radius,{cell['healthy_retained']:.3f},"
                f"healthy_goodput={cell['healthy_goodput_rps']:.0f};"
                f"on_goodput={on['goodput_rps']:.0f};"
                f"sick_opens={on['sick_edge_opens']};"
                f"healthy_opens={on['healthy_edge_opens']}")
            rec = cell["recovery"]
            rec_s = rec["recovery_time_s"]
            rec_us = rec_s * 1e6 if rec["recovered"] else 0.0
            rows.append(
                f"{base}/recovery,{rec_us:.0f},"
                f"s={rec_s if rec_s is not None else float('inf'):.3f};"
                f"recovered={'yes' if rec['recovered'] else 'no'};"
                f"probes={rec['probes']}")
    if json_path:
        os.makedirs(os.path.dirname(json_path), exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
